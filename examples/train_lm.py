"""End-to-end training driver: a small LM trained with the full MG-WFBP
stack — schedule computation, bucket-segmented scan, variadic-psum
gradient sync inside shard_map, synthetic data pipeline, async atomic
checkpointing, and fault-tolerant restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200            # ~25M params
    PYTHONPATH=src python examples/train_lm.py --steps 300 --full     # ~110M params
    PYTHONPATH=src python examples/train_lm.py --steps 40 --tiny      # smoke

The loss must fall well below the unigram entropy of the synthetic
mixture — the stream embeds a repeated motif (data/pipeline.py) so a
working model reaches ~half the initial loss within a few hundred steps.
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs import get_reduced
from repro.core import tpu_psum_model
from repro.core.trainer import MGWFBPEngine
from repro.data import DataConfig, make_stream
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.launch.mesh import make_mesh
from repro.launch.specs import param_specs
from repro.models.common import Attention
from repro.models.transformer import init_params
from repro.optim import make_optimizer


def build_cfg(size: str):
    cfg = get_reduced("tinyllama-1.1b")
    if size == "tiny":
        return dataclasses.replace(cfg, param_dtype=jnp.float32)
    if size == "full":  # ~110M params
        return dataclasses.replace(
            cfg,
            name="tinyllama-110m",
            n_layers=8,
            d_model=768,
            d_ff=2048,
            vocab=8192,
            attention=Attention(n_heads=12, n_kv_heads=4, head_dim=64),
            param_dtype=jnp.float32,
            q_chunk=64,
        )
    return dataclasses.replace(  # default ~25M
        cfg,
        name="tinyllama-25m",
        n_layers=6,
        d_model=384,
        d_ff=1024,
        vocab=4096,
        attention=Attention(n_heads=6, n_kv_heads=2, head_dim=64),
        param_dtype=jnp.float32,
        q_chunk=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--method", default="mg_wfbp",
                    choices=["mg_wfbp", "dp_optimal", "wfbp", "synceasgd", "fixed"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = build_cfg("tiny" if args.tiny else "full" if args.full else "mid")
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev, 1), ("data", "model"))

    shapes = param_specs(cfg)
    eng = MGWFBPEngine.build(
        cfg, shapes,
        dp_axes=("data",),
        ar_model=tpu_psum_model({"data": max(n_dev, 2)}),
        tokens_per_device=args.batch * args.seq // n_dev,
        method=args.method,
    )
    print(f"schedule: {eng.schedule.describe()}")
    print(f"scan segments: {eng.segments}")

    opt = make_optimizer("adamw", weight_decay=0.01)
    step_fn = eng.make_train_step(opt, mesh, lr=args.lr)

    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params: {n_params / 1e6:.1f}M")
    opt_state = opt.init(params)

    start = 0
    ck = latest_step(args.ckpt_dir)
    if ck is not None:
        tree, extra = restore(args.ckpt_dir, ck, {"params": params, "opt_state": opt_state})
        params, opt_state = tree["params"], tree["opt_state"]
        start = ck
        print(f"resumed from checkpoint step {ck}")

    data = make_stream(DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    t0 = time.time()
    first_loss = None
    with set_mesh(mesh):
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                if first_loss is None:
                    first_loss = loss
                dt = time.time() - t0
                print(f"step {step:4d}  loss {loss:.4f}  ({dt:.1f}s)")
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt_state": opt_state})
    ckpt.wait()
    final = float(metrics["loss"])
    print(f"\nloss: {first_loss:.4f} -> {final:.4f} "
          f"({'OK: learned' if final < 0.7 * first_loss else 'WARNING: check'})")


if __name__ == "__main__":
    main()
