"""Fault-tolerance + elasticity demo: train, inject a failure, restore
from the atomic checkpoint, and — the elastic part — recompute the
MG-WFBP schedule for a different cluster size.  The checkpoint layout is
schedule-agnostic, so the same weights resume under a different bucket
structure (paper Algorithm 1 reruns with the new N's α–β model).

Phase 3 is the serving mirror: snapshot a mid-generation ServingEngine,
"kill" it, restore into a fresh engine, and verify the resumed run emits
exactly the tokens the uninterrupted run would have.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import dataclasses
import shutil
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs import get_reduced
from repro.core import tpu_psum_model
from repro.core.trainer import MGWFBPEngine
from repro.data import DataConfig, make_stream
from repro.checkpoint import latest_step, load_plan, restore
from repro.launch.mesh import make_mesh
from repro.launch.specs import param_specs
from repro.models.transformer import init_params
from repro.optim import make_optimizer
from repro.runtime import RunState, StragglerMonitor, resilient_loop
from repro.serving import (
    Request,
    ServingEngine,
    restore_latest_snapshot,
    save_snapshot,
)

CKPT = "/tmp/repro_elastic_ckpt"
SERVE_SNAP = "/tmp/repro_elastic_serve_snap"


def make_engine(cfg, shapes, n_virtual: int):
    """Schedule as it would be on an n_virtual-chip DP group."""
    return MGWFBPEngine.build(
        cfg, shapes, dp_axes=("data",),
        ar_model=tpu_psum_model({"data": n_virtual}),
        tokens_per_device=2048 // max(jax.device_count(), 1),
        method="mg_wfbp",
    )


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    shapes = param_specs(cfg)
    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
    opt = make_optimizer("adamw")
    data = make_stream(DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8))

    # phase 1: "16-chip" schedule
    eng16 = make_engine(cfg, shapes, 16)
    print("schedule @ N=16:", eng16.schedule.describe())
    step16 = eng16.make_train_step(opt, mesh, lr=1e-3)

    def init_state():
        params = init_params(jax.random.PRNGKey(0), cfg)
        return RunState(step=0, params=params, opt_state=opt.init(params))

    crashes = {25}

    def fault(step):
        if step in crashes:
            crashes.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")

    def do_step(state, step):
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        with set_mesh(mesh):
            p, o, m = step16(state.params, state.opt_state, batch)
        return RunState(step=state.step, params=p, opt_state=o, restarts=state.restarts)

    mon = StragglerMonitor(factor=3.0, patience=3)
    state = resilient_loop(
        num_steps=40, init_state=init_state, train_step=do_step,
        checkpoint_dir=CKPT, checkpoint_every=10,
        fault_injector=fault, straggler=mon,
        # plan-aware checkpointing: every checkpoint carries the active plan
        plan_provider=lambda: eng16.plan,
    )
    print(f"phase 1 done: step={state.step} restarts={state.restarts} "
          f"(failure at 25 -> restored from step 20)")

    # Same-N restart: the plan rides beside the weights — reload it instead
    # of recomputing Algorithm 1, and resume under the *exact* schedule the
    # run crashed with.
    ck = latest_step(CKPT)
    stored = load_plan(CKPT, ck)
    assert stored == eng16.plan
    eng_resumed = MGWFBPEngine.build(cfg, None, dp_axes=("data",), plan=stored)
    assert eng_resumed.schedule.groups == eng16.schedule.groups
    print(f"plan restored from checkpoint step {ck}: {stored.describe()}")

    # phase 2: the cluster grew to "64 chips" — elastic restart: same
    # checkpoint (weights are schedule-agnostic), but the stored plan's
    # α–β model is the old N's, so the same policy re-plans at the new N
    eng64 = make_engine(cfg, shapes, 64)
    print("schedule @ N=64:", eng64.schedule.describe())
    assert eng64.schedule.groups != eng16.schedule.groups or True  # may differ
    fresh = init_state()
    tree, _ = restore(CKPT, ck, {"params": fresh.params, "opt_state": fresh.opt_state})
    step64 = eng64.make_train_step(opt, mesh, lr=1e-3)
    params, opt_state = tree["params"], tree["opt_state"]
    with set_mesh(mesh):
        for s in range(ck, ck + 5):
            batch = jax.tree.map(jnp.asarray, data.batch_at(s))
            params, opt_state, m = step64(params, opt_state, batch)
    print(f"phase 2: resumed step {ck} under the N=64 schedule, "
          f"5 more steps OK (loss {float(m['loss']):.3f})")

    # phase 3: serve-side elastic restart — snapshot mid-generation, kill
    # the engine, restore into a fresh one, and the resumed decode emits
    # token-for-token what the uninterrupted run would have
    shutil.rmtree(SERVE_SNAP, ignore_errors=True)
    serve_params = init_params(jax.random.PRNGKey(0), cfg)

    def make_serve_engine():
        return ServingEngine(cfg, serve_params, slots=2, max_seq=64)

    def submit_all(eng):
        rng = np.random.default_rng(0)
        for rid in range(3):
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=8, dtype=np.int32),
                max_new_tokens=12,
            ))

    ref = make_serve_engine()
    submit_all(ref)
    expected = {r.rid: r.generated for r in ref.run_to_completion()}

    eng = make_serve_engine()
    submit_all(eng)
    for _ in range(5):
        eng.step()
    save_snapshot(eng, SERVE_SNAP, 5)
    del eng  # the "kill": the mid-generation engine is gone

    fresh = make_serve_engine()
    step, _ = restore_latest_snapshot(fresh, SERVE_SNAP)
    while fresh.active or fresh.waiting:
        fresh.step()
    resumed = {r.rid: r.generated for r in fresh.completed}
    assert resumed == expected, "restored decode diverged from baseline"
    print(f"phase 3: serve snapshot at step {step} restored into a fresh "
          f"engine; all {len(resumed)} requests token-identical to the "
          f"uninterrupted run")


if __name__ == "__main__":
    main()
