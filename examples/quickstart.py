"""Quickstart: compute MG-WFBP schedules and compare them against WFBP /
SyncEASGD / fixed-bucket baselines on the paper's cluster model and on a
TPU v5e pod — no devices needed, pure cost-model math.

    PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import ARCH_NAMES, get_config
from repro.configs.cnn_profiles import cnn_layer_costs
from repro.core import paper_cluster_model, tpu_psum_model
from repro.core.cost_model import K80_CALIBRATED, TPU_V5E
from repro.core.trainer import lm_unit_costs
from repro.launch.specs import param_specs
from repro.planning import build_schedule



def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    args = ap.parse_args()

    print("=== Paper setting: ResNet-50, 8-node 10GbE K80 cluster ===")
    costs = cnn_layer_costs("resnet50", 32)
    ar = paper_cluster_model(8)
    for method in ("wfbp", "synceasgd", "fixed", "mg_wfbp", "dp_optimal"):
        s = build_schedule(method, costs, ar, hw=K80_CALIBRATED)
        print(f"  {s.describe()}")

    print(f"\n=== {args.arch} on a 2x16x16 v5e multi-pod mesh (DP axes pod+data) ===")
    cfg = get_config(args.arch)
    shapes = param_specs(cfg)
    lm_costs = lm_unit_costs(cfg, shapes, tokens_per_device=8192, model_shards=16)
    ar = tpu_psum_model({"pod": 2, "data": 16})
    print(f"  units: {len(lm_costs)} (embed + {cfg.n_stages} stages"
          f"{' + tail' if cfg.tail_pattern else ''} + head)")
    print(f"  α = {ar.a * 1e6:.1f} µs, β = {ar.b * 1e9:.3f} ns/B")
    for method in ("wfbp", "synceasgd", "mg_wfbp", "dp_optimal"):
        s = build_schedule(method, lm_costs, ar, hw=TPU_V5E)
        print(f"  {s.describe()}")


if __name__ == "__main__":
    main()
