"""Serving example: fabric-priced decode plans + continuous batching.

Builds the decode-side ServePlan for two interconnect presets on one
arch and prints how the chosen fabric moves the merge set — the TPU's
microsecond startup keeps per-stage KV all-gathers separate, while
NCCL-class launch overhead merges them (Eq. 10: the merge gain IS α) —
then runs the request batch through the one serving code path
(``serving.ServingEngine``) under the selected fabric's plan.

    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b \\
        --fabric gpu_nccl --tokens 12
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.launch.specs import param_specs
from repro.models.transformer import init_params
from repro.planning import build_serve_plan
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--fabric", default="tpu_v5e",
                    help="fabric preset the engine's plan is priced on")
    ap.add_argument("--compare", default="gpu_nccl",
                    help="second preset for the plan-difference table")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    # Plan differences are shown at the FULL arch scale (per-stage decode
    # compute large enough that fabric startup moves the merge set); the
    # engine then runs the reduced config so the demo stays CPU-friendly.
    full_cfg = get_config(args.arch)
    full_shapes = param_specs(full_cfg)
    print(f"== decode plans, {args.arch} @ 16 rows, TP=8 ==")
    plans = {}
    for preset in dict.fromkeys((args.fabric, args.compare, "tpu_v5e")):
        plan = build_serve_plan(full_cfg, full_shapes, preset, {"model": 8},
                                batch_rows=16)
        plans[preset] = plan
        r = plan.schedule.result
        print(f"  {preset:12s} α={plan.model.a:.2e}s  "
              f"{len(plan.schedule.groups):2d} groups  "
              f"t_step={r.t_iter * 1e6:7.1f}µs  "
              f"exposed_comm={r.t_comm_exposed * 1e6:6.1f}µs  ({plan.op})")
    a, b = args.fabric, args.compare
    if len(plans[a].schedule.groups) != len(plans[b].schedule.groups):
        print(f"  -> {a} and {b} pick different merge sets from the SAME "
              f"cost vector: only the fabric's (α, β) moved.")

    cfg = dataclasses.replace(get_reduced(args.arch), param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan = build_serve_plan(cfg, param_specs(cfg), args.fabric, {"model": 8},
                            batch_rows=args.slots)
    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_seq=args.prompt_len + args.tokens + 1, plan=plan)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=args.prompt_len, dtype=np.int32),
            max_new_tokens=args.tokens,
        ))
    t0 = time.time()
    completed = engine.run_to_completion()
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in completed)
    print(f"\n== engine ({args.fabric} plan, reduced arch) ==")
    print(f"{len(completed)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s)")
    print("sample request 0:", completed[0].generated)


if __name__ == "__main__":
    main()
