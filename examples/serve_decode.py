"""Serving example: fabric-priced decode plans, continuous batching, and
the plan *executed* — the same prompts decoded sharded and unsharded.

Builds the decode-side ServePlan for two interconnect presets on one
arch and prints how the chosen fabric moves the merge set — the TPU's
microsecond startup keeps per-stage KV all-gathers separate, while
NCCL-class launch overhead merges them (Eq. 10: the merge gain IS α) —
then runs the request batch through the one serving code path
(``serving.ServingEngine``) twice: unsharded, and sharded over a virtual
TP mesh where every scheduled serve group issues exactly one fused
collective.  The tokens must match exactly; the closing table leads
with the calibrated fixed-vs-wire step decomposition (probed
compute+dispatch + plan wire timeline — the honest predicted step) and
shows each group's predicted collective time next to a real measured
one (``planning.time_serve_groups``) — see docs/fabrics.md.

    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b \\
        --fabric gpu_nccl --tokens 12
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

# the sharded half of the demo wants a few virtual CPU devices; the flag
# must land before jax initializes its backend
from repro.compat import ensure_virtual_devices

ensure_virtual_devices(4)

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.launch.specs import param_specs
from repro.models.transformer import init_params
from repro.planning import (
    build_serve_plan,
    group_comparison_lines,
    time_serve_groups,
)
from repro.serving import Request, ServeTimer, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--fabric", default="tpu_v5e",
                    help="fabric preset the engine's plan is priced on")
    ap.add_argument("--compare", default="gpu_nccl",
                    help="second preset for the plan-difference table")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds params, prompts, and the engine's sampling "
                         "key — the whole demo is reproducible per seed")
    args = ap.parse_args()

    # Plan differences are shown at the FULL arch scale (per-stage decode
    # compute large enough that fabric startup moves the merge set); the
    # engine then runs the reduced config so the demo stays CPU-friendly.
    full_cfg = get_config(args.arch)
    full_shapes = param_specs(full_cfg)
    print(f"== decode plans, {args.arch} @ 16 rows, TP=8 ==")
    plans = {}
    for preset in dict.fromkeys((args.fabric, args.compare, "tpu_v5e")):
        plan = build_serve_plan(full_cfg, full_shapes, preset, {"model": 8},
                                batch_rows=16)
        plans[preset] = plan
        r = plan.schedule.result
        print(f"  {preset:12s} α={plan.model.a:.2e}s  "
              f"{len(plan.schedule.groups):2d} groups  "
              f"t_step={r.t_iter * 1e6:7.1f}µs  "
              f"exposed_comm={r.t_comm_exposed * 1e6:6.1f}µs  ({plan.op})")
    a, b = args.fabric, args.compare
    if len(plans[a].schedule.groups) != len(plans[b].schedule.groups):
        print(f"  -> {a} and {b} pick different merge sets from the SAME "
              f"cost vector: only the fabric's (α, β) moved.")

    cfg = dataclasses.replace(get_reduced(args.arch), param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    tp = min(4, jax.device_count())
    mesh = make_mesh((tp,), ("model",))
    # the reduced engine runs fp32 caches: price the wire at 4 bytes/elem
    # so the measured group collectives ship exactly the predicted bytes
    plan = build_serve_plan(cfg, param_specs(cfg), args.fabric, {"model": tp},
                            batch_rows=args.slots,
                            cache_dtype_bytes=4, act_dtype_bytes=4)

    def run(mesh_arg):
        engine = ServingEngine(
            cfg, params, slots=args.slots,
            max_seq=args.prompt_len + args.tokens + 1, plan=plan,
            sample_seed=args.seed, mesh=mesh_arg,
            timer=ServeTimer(skip_first=1),
        )
        # compile + probe before the timed loop: the printed tok/s and
        # step times are steady-state dispatch, never compilation
        engine.warmup()
        engine.calibrate_plan()
        rng = np.random.default_rng(args.seed)
        for rid in range(args.requests):
            engine.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=args.prompt_len, dtype=np.int32),
                max_new_tokens=args.tokens,
            ))
        t0 = time.time()
        completed = engine.run_to_completion()
        return completed, time.time() - t0, engine

    for label, mesh_arg in (("unsharded", None), (f"sharded TP={tp}", mesh)):
        completed, dt, engine = run(mesh_arg)
        n_tok = sum(len(r.generated) for r in completed)
        print(f"\n== engine, {label} ({args.fabric} plan, reduced arch) ==")
        print(f"{len(completed)} requests, {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / max(dt, 1e-9):.1f} tok/s)")
        print("sample request 0:", completed[0].generated)
        if mesh_arg is None:
            base = {r.rid: r.generated for r in completed}
        else:
            match = base == {r.rid: r.generated for r in completed}
            print(f"tokens match unsharded run: {match}")
            obs = engine.observed_step_time()
            cal = engine.plan  # calibrated copy: wire + probed fixed term
            pred = cal.predicted_step_time()
            wire = cal.schedule.result.t_iter
            print(f"step decomposition: fixed {cal.t_step_fixed * 1e3:.3f}ms "
                  f"(compute+dispatch, probed) + wire {wire * 1e3:.3f}ms "
                  f"(plan timeline) = {pred * 1e3:.3f}ms predicted")
            if obs is not None:
                print(f"observed step: {obs * 1e3:.3f}ms "
                      f"(observed/predicted = {obs / pred:.2f}x)")
            print("per-group predicted vs measured collective:")
            for line in group_comparison_lines(cal, time_serve_groups(cal, mesh)):
                print("  " + line)


if __name__ == "__main__":
    main()
