"""Serving example: batched prefill + incremental decode with KV caches
(ring buffers for windowed layers) and greedy/temperature sampling.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --tokens 24
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_reduced
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.tokens

    prefill = jax.jit(make_prefill_step(cfg, None, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg, None))

    key = jax.random.PRNGKey(1)
    if cfg.input_mode == "embeds":
        batch = {"embeds": jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32) * 0.02}
    else:
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)}

    t0 = time.time()
    logits, caches = prefill(params, batch)
    print(f"prefill: {args.batch}x{args.prompt_len} in {time.time() - t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1)[:, None]
    generated = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = args.prompt_len + i
        if cfg.input_mode == "embeds":
            # stub frontend: feed the embedding row of the sampled token
            step_in = {"embeds": params["embed"][tok[:, 0]][:, None].astype(jnp.float32)}
        else:
            step_in = {"tokens": tok}
        logits, caches = decode(params, caches, step_in, jnp.asarray(pos, jnp.int32))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.tokens} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s)")
    print("sample row 0:", out[0].tolist())


if __name__ == "__main__":
    main()
