"""Paper reproduction tables — one function per paper table/figure.

All numbers come from the timeline simulator (core.timeline) driven by
the paper's own setup: GoogleNet (batch 64) and ResNet-50 (batch 32)
layer profiles on K80-class compute and the measured 10GbE α–β all-reduce
model (paper §V-A).  This is the same methodology as the paper's §V-C
simulation, so the table to validate against is Fig. 9 (64-node) and the
8-node speedups of Figs. 6–7.
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs.cnn_profiles import cnn_layer_costs, total_params
from repro.core.cost_model import K80_CALIBRATED
from repro.core import (
    NVIDIA_K80,
    evaluate,
    evaluate_schedule,
    mg_wfbp_schedule,
    paper_cluster_model,
    synceasgd_schedule,
    wfbp_schedule,
)
from repro.core.schedule import dp_optimal_schedule


def _bench(which: str, batch: int, n: int) -> dict:
    costs = cnn_layer_costs(which, batch)
    ar = paper_cluster_model(n)
    L = len(costs)

    wf = evaluate([(l, l) for l in range(1, L + 1)], costs, ar, K80_CALIBRATED)
    se = evaluate([(1, L)], costs, ar, K80_CALIBRATED)
    # SyncEASGD does not overlap: its single message starts after backward
    # finishes, which the single-group schedule reproduces exactly.
    mg = mg_wfbp_schedule(costs, ar, K80_CALIBRATED)
    dp = dp_optimal_schedule(costs, ar, K80_CALIBRATED)
    return {
        "n": n,
        "wfbp": wf,
        "synceasgd": se,
        "mg_wfbp": mg.result,
        "dp_optimal": dp.result,
        "mg_groups": len(mg.groups),
    }


def table_fig5a_gradient_distribution() -> list[str]:
    """Fig. 5(a): layer-wise gradient-size distribution of the two CNNs."""
    rows = ["table=fig5a_gradient_distribution"]
    for which in ("googlenet", "resnet50"):
        costs = cnn_layer_costs(which, 1)
        sizes = [c.params for c in costs]
        rows.append(
            f"{which},layers={len(sizes)},total_params={total_params(which) / 1e6:.2f}M,"
            f"min={min(sizes)},median={sorted(sizes)[len(sizes) // 2]},max={max(sizes)}"
        )
    return rows


def table_fig5b_allreduce_model() -> list[str]:
    """Fig. 5(b): all-reduce time vs message size; startup intercepts must
    match the paper's measured 90.52/271.56/633.64 µs at N=2/4/8."""
    rows = ["table=fig5b_allreduce_model"]
    paper_measured = {2: 90.52e-6, 4: 271.56e-6, 8: 633.64e-6}
    for n, meas in paper_measured.items():
        ar = paper_cluster_model(n)
        rows.append(
            f"N={n},a_model={ar.a * 1e6:.2f}us,a_paper={meas * 1e6:.2f}us,"
            f"rel_err={abs(ar.a - meas) / meas:.3f},"
            f"T(200KB)={ar(200e3) * 1e3:.3f}ms,T(400KB)={ar(400e3) * 1e3:.3f}ms"
        )
    return rows


def table_fig6_7_8node_speedups() -> list[str]:
    """Figs. 6–7: 2/4/8-node speedups (weak scaling vs 1 worker)."""
    rows = ["table=fig6_7_8node_speedups"]
    for which, batch in (("googlenet", 64), ("resnet50", 32)):
        for n in (2, 4, 8):
            r = _bench(which, batch, n)
            wf, se, mg = r["wfbp"], r["synceasgd"], r["mg_wfbp"]
            rows.append(
                f"{which},N={n},"
                f"S_wfbp={wf.speedup(n):.2f},S_synceasgd={se.speedup(n):.2f},"
                f"S_mgwfbp={mg.speedup(n):.2f},"
                f"mg_vs_wfbp={wf.t_iter / mg.t_iter:.3f}x,"
                f"mg_vs_se={se.t_iter / mg.t_iter:.3f}x"
            )
    return rows


def table_fig8_comm_breakdown() -> list[str]:
    """Fig. 8: computation vs non-overlapped communication at 8 nodes."""
    rows = ["table=fig8_comm_breakdown"]
    for which, batch in (("googlenet", 64), ("resnet50", 32)):
        r = _bench(which, batch, 8)
        for name in ("wfbp", "synceasgd", "mg_wfbp"):
            res = r[name]
            rows.append(
                f"{which},{name},comp_ms={(res.t_f + res.t_b) * 1e3:.2f},"
                f"exposed_comm_ms={res.t_comm_exposed * 1e3:.2f},"
                f"r={res.comm_ratio:.3f}"
            )
    return rows


def table_fig9_64node_simulation() -> list[str]:
    """Fig. 9: 4..64-node simulated speedups; the paper's headline:
    GoogleNet 64-node MG-WFBP beats WFBP by >1.7x and SyncEASGD by >1.3x;
    ResNet-50 near-linear for MG-WFBP with ~55% efficiency baselines."""
    rows = ["table=fig9_64node_simulation"]
    for which, batch in (("googlenet", 64), ("resnet50", 32)):
        for n in (4, 8, 16, 32, 64):
            r = _bench(which, batch, n)
            wf, se, mg, dp = r["wfbp"], r["synceasgd"], r["mg_wfbp"], r["dp_optimal"]
            rows.append(
                f"{which},N={n},S_wfbp={wf.speedup(n):.2f},"
                f"S_synceasgd={se.speedup(n):.2f},S_mgwfbp={mg.speedup(n):.2f},"
                f"S_dp_optimal={dp.speedup(n):.2f},"
                f"mg_vs_wfbp={wf.t_iter / mg.t_iter:.3f}x,"
                f"mg_vs_se={se.t_iter / mg.t_iter:.3f}x,"
                f"dp_vs_mg={mg.t_iter / dp.t_iter:.4f}x"
            )
    return rows


def table_lm_schedules_v5e() -> list[str]:
    """Beyond-paper: MG-WFBP schedules for the assigned LM archs on the
    production v5e mesh (pod-axis DP all-reduce, multi-pod 2x16x16)."""
    rows = ["table=lm_schedules_v5e"]
    from repro.configs import ARCH_NAMES, get_config
    from repro.core import TPU_V5E, tpu_psum_model
    from repro.core.trainer import build_schedule, lm_unit_costs
    from repro.launch.specs import param_specs

    ar = tpu_psum_model({"pod": 2, "data": 16})  # DP axes of the 2-pod mesh
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        shapes = param_specs(cfg)
        costs = lm_unit_costs(cfg, shapes, tokens_per_device=8192, model_shards=16)
        for method in ("wfbp", "synceasgd", "mg_wfbp", "dp_optimal"):
            s = build_schedule(method, costs, ar)
            rows.append(
                f"{arch},{method},groups={len(s.groups)},"
                f"t_iter_ms={s.result.t_iter * 1e3:.3f},"
                f"exposed_ms={s.result.t_comm_exposed * 1e3:.3f}"
            )
    return rows


ALL_TABLES = [
    table_fig5a_gradient_distribution,
    table_fig5b_allreduce_model,
    table_fig6_7_8node_speedups,
    table_fig8_comm_breakdown,
    table_fig9_64node_simulation,
    table_lm_schedules_v5e,
]
