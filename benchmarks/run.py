"""Benchmark harness entry point: one function per paper table/figure plus
the roofline summary assembled from dry-run records.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract:
each table reports its wall time and emits its rows beneath it.

``--only planning_sweep,wire_layout`` restricts to named tables (CI runs
exactly that pair in smoke mode and uploads the BENCH_*.json artifacts).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

# The wire-layout and serve-exec sweeps run shard_map over 8 virtual
# devices; flags must land before jax initializes its backend.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, "src")

from repro.compat import ensure_virtual_devices

ensure_virtual_devices(8)


def write_bench(name: str, record, rows: list[str], gate=None) -> pathlib.Path:
    """Publish one suite's record: the shared stash-record-and-compare
    tail every table used to hand-roll.

    Writes ``benchmarks/results/BENCH_<name>.json`` and appends the
    ``wrote ...`` row.  When ``gate`` is given and ``BENCH_BASELINE_DIR``
    points at a stash of previously-committed records (the CI smoke jobs
    stash the checked-in JSON there before re-running a suite), the gate
    runs as ``gate(record, baseline_record)`` BEFORE the new record is
    written — a regressed run raises and never publishes, so the
    committed trajectory only ever moves forward.

    Records are serialized with sorted keys so a committed BENCH file
    round-trips byte-identically through ``json.loads`` + this writer —
    the schema test (tests/test_bench_records.py) pins that."""
    out = pathlib.Path(__file__).parent / "results" / f"BENCH_{name}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    base_dir = os.environ.get("BENCH_BASELINE_DIR")
    if gate is not None and base_dir:
        base_path = pathlib.Path(base_dir) / out.name
        if base_path.exists():
            gate(record, json.loads(base_path.read_text()))
            rows.append(f"gate vs {base_path}: ok")
        else:
            rows.append(f"gate skipped: no baseline at {base_path}")
    out.write_text(json.dumps(record, indent=1, sort_keys=True) + "\n")
    rows.append(f"wrote {out}")
    return out


def roofline_summary() -> list[str]:
    """Per-(arch x shape x mesh) roofline terms from the dry-run records."""
    rows = ["table=roofline_summary"]
    results = pathlib.Path(__file__).parent / "results" / "dryrun"
    if not results.exists():
        rows.append("no dry-run records yet; run python -m repro.launch.dryrun --all")
        return rows
    for f in sorted(results.glob("*.json")):
        rec = json.loads(f.read_text())
        t = rec.get("totals")
        mem = rec["memory"]["peak_per_device_gib"]
        if not t:
            rows.append(f"{rec['arch']},{rec['shape']},{rec['mesh']},mem_gib={mem},segments=skipped")
            continue
        rows.append(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},mem_gib={mem},"
            f"compute_s={t['compute_term_s']:.4f},memory_s={t['memory_term_s']:.4f},"
            f"collective_s={t['collective_term_s']:.4f},dominant={t['dominant']},"
            f"useful_ratio={t['useful_flops_ratio']:.3f},"
            f"roofline_fraction={t['roofline_fraction']:.4f}"
        )
    return rows


def _arch_sweep_inputs(arch: str):
    """(layout, analytic costs, measured_3x costs, n_scan_stages) for one
    arch — the shared setup of the planning/tuner sweeps."""
    from repro.configs import get_config
    from repro.core.bucketing import stacked_lm_layout
    from repro.core.cost_model import TPU_V5E
    from repro.core.trainer import lm_unit_costs
    from repro.launch.specs import param_specs
    from repro.planning import MeasuredCosts

    cfg = get_config(arch)
    shapes = param_specs(cfg)
    layout = stacked_lm_layout(shapes, cfg.n_stages, model_shards=16)
    analytic = lm_unit_costs(cfg, shapes, tokens_per_device=8192, model_shards=16)
    # Skewed measured profile: compute 3x the analytic belief — the
    # regime where re-planning pays (comm hides behind backward).
    measured = MeasuredCosts.from_unit_times(
        analytic,
        [c.t_b(TPU_V5E) * 3.0 for c in analytic],
        [c.t_f(TPU_V5E) * 3.0 for c in analytic],
        name="measured_3x",
    )
    return layout, analytic, measured, cfg.n_stages


def planning_sweep() -> list[str]:
    """Sweep scheduler policies × cost sources through the ``Tuner`` —
    the same registry-wide argmin-t_iter search the ``--autotune`` train
    loop runs (the sweep is load-bearing, not a report); rows go to
    stdout and the full records to
    ``benchmarks/results/BENCH_planning.json`` so future PRs have a perf
    trajectory (t_iter, exposed comm, group count per policy)."""
    from repro.core import tpu_psum_model
    from repro.core.cost_model import TPU_V5E
    from repro.planning import MEASURED_HW, Tuner

    rows = ["table=planning_sweep"]
    records = []
    ar = tpu_psum_model({"pod": 2, "data": 16})
    for arch in ("tinyllama-1.1b", "mixtral-8x7b", "recurrentgemma-9b"):
        layout, analytic, measured, n_scan = _arch_sweep_inputs(arch)
        tuner = Tuner(layout=layout, n_scan_stages=n_scan)
        sources = {
            "analytic": (analytic, TPU_V5E),
            "measured_3x": (measured.layer_costs(), MEASURED_HW),
        }
        for src, (costs, hw) in sources.items():
            tuner.sweep(costs, ar, hw, cost_source=src, trigger="bench")
            rec = tuner.last_record
            for c in rec.candidates:
                records.append(
                    {
                        "arch": arch,
                        "policy": c.policy,
                        "cost_source": src,
                        "chosen": c.policy == rec.chosen,
                        "n_groups": c.n_groups,
                        "t_iter_s": c.predicted_t_iter,
                        "t_comm_exposed_s": c.t_comm_exposed,
                    }
                )
                rows.append(
                    f"{arch},{c.policy},{src},groups={c.n_groups},"
                    f"t_iter_ms={c.predicted_t_iter * 1e3:.3f},"
                    f"exposed_ms={c.t_comm_exposed * 1e3:.3f}"
                    + (",chosen" if c.policy == rec.chosen else "")
                )
    write_bench("planning", records, rows)
    return rows


def tuner() -> list[str]:
    """Closed-loop auto-tuner acceptance table -> BENCH_tuner.json.

    Three cells, matching the PR's acceptance criteria:

      * ``sweep``        — registry-wide search per arch on measured
        costs; records every candidate and pins chosen ≤ per_tensor
        (wfbp) and ≤ every other candidate;
      * ``unit_profile`` — real per-unit segment probes on a CPU-mesh
        reduced arch; records measured-vs-analytic ratios per unit and
        their non-uniformity (a uniform whole-step rescale would be 1.0);
      * ``comm_drift``   — injected α×10 congestion into the CommRefitter
        (EWMA slim-sweep re-fit) and the checks-to-refit count, plus the
        re-plan the fresh fit triggers.
    """
    import jax
    from repro.configs import get_reduced
    from repro.core import tpu_psum_model
    from repro.core.comm_model import AllReduceModel
    from repro.core.cost_model import TPU_V5E
    from repro.models.transformer import init_params
    from repro.planning import (
        DEFAULT_COMM_SWEEP,
        MEASURED_HW,
        CommRefitter,
        MeasuredComm,
        MeasuredCosts,
        Tuner,
        build_plan,
        replan_if_comm_drifted,
    )
    from repro.runtime.timeline import probe_unit_times

    rows = ["table=tuner"]
    record: dict = {"sweeps": [], "unit_profile": None, "comm_drift": None}

    # -- 1. registry-wide sweep: chosen plan beats every candidate --------
    ar = tpu_psum_model({"pod": 2, "data": 16})
    for arch in ("tinyllama-1.1b", "mixtral-8x7b"):
        layout, _, measured, n_scan = _arch_sweep_inputs(arch)
        tun = Tuner(layout=layout, n_scan_stages=n_scan)
        tun.sweep(
            measured.layer_costs(), ar, MEASURED_HW,
            cost_source="measured_3x", trigger="bench",
        )
        rec = tun.last_record
        by_policy = {c.policy: c for c in rec.candidates}
        assert all(
            rec.predicted_t_iter <= c.predicted_t_iter for c in rec.candidates
        ), rec
        assert rec.predicted_t_iter <= by_policy["wfbp"].predicted_t_iter
        record["sweeps"].append(rec.to_json_dict() | {"arch": arch})
        rows.append(
            f"sweep,{arch},chosen={rec.chosen},"
            f"t_iter_ms={rec.predicted_t_iter * 1e3:.3f},"
            f"vs_per_tensor_ms={by_policy['wfbp'].predicted_t_iter * 1e3:.3f}"
        )

    # -- 2. per-unit measured profile: non-uniform drift (CPU mesh) -------
    cfg = get_reduced("tinyllama-1.1b")
    import dataclasses as _dc
    import jax.numpy as jnp
    cfg = _dc.replace(cfg, param_dtype=jnp.float32)
    from repro.core.bucketing import stacked_lm_layout
    from repro.core.trainer import lm_unit_costs
    from repro.launch.specs import param_specs

    shapes = param_specs(cfg)
    layout = stacked_lm_layout(shapes, cfg.n_stages)
    analytic = lm_unit_costs(cfg, shapes, tokens_per_device=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    batch = {"targets": jax.random.randint(key, (2, 64), 0, cfg.vocab)}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(key, (2, 64, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    profile = probe_unit_times(cfg, params, batch, layout)
    ratios = profile.ratios(analytic, TPU_V5E)
    nonuni = profile.nonuniformity(analytic, TPU_V5E)
    record["unit_profile"] = {
        "arch": cfg.name,
        "unit_seconds": profile.unit_seconds,
        "measured_over_analytic": ratios,
        "nonuniformity": nonuni,
    }
    rows.append(f"unit_profile,{cfg.name},nonuniformity={nonuni:.2f},"
                f"units={len(profile.unit_seconds)}")

    # -- 3. injected α×10 congestion -> re-fit + re-plan ------------------
    base_model = AllReduceModel(a=5e-5, b=1e-9, name="baseline")
    base = MeasuredComm(
        sizes_bytes=DEFAULT_COMM_SWEEP,
        times_s=tuple(base_model(s) for s in DEFAULT_COMM_SWEEP),
        name="baseline",
    )
    refitter = CommRefitter(base=base, threshold=0.5, weight=0.5)
    comm_refit_every = 5  # drift checked every N train steps
    congested = AllReduceModel(a=base_model.a * 10.0, b=base_model.b, name="congested")
    checks = 0
    drifted = False
    while not drifted and checks < 10:
        _fit, drift, drifted = refitter.check(lambda n: congested(n))
        checks += 1
    # the re-plan the fresh fit triggers on a plan built at baseline α
    measured = MeasuredCosts.from_unit_times(
        analytic, [c.t_b(TPU_V5E) for c in analytic],
        [c.t_f(TPU_V5E) for c in analytic],
    )
    plan = build_plan(
        layout, measured.layer_costs(), base_model,
        policy="mg_wfbp", hw=MEASURED_HW, n_scan_stages=cfg.n_stages,
    )
    new_plan, replanned = replan_if_comm_drifted(plan, refitter.reference, threshold=0.5)
    record["comm_drift"] = {
        "alpha_injection": 10.0,
        "comm_refit_every": comm_refit_every,
        "checks_to_refit": checks,
        "steps_to_refit": checks * comm_refit_every,
        "drift_at_refit": drift,
        "replanned": replanned,
        "groups_before": len(plan.schedule.groups),
        "groups_after": len(new_plan.schedule.groups),
    }
    assert drifted and checks == 1, (checks, drifted)  # fires on the first check
    assert replanned, "α×10 must trigger a comm re-plan"
    rows.append(f"comm_drift,alpha_x10,checks_to_refit={checks},"
                f"steps_to_refit={checks * comm_refit_every},replanned={replanned}")

    write_bench("tuner", record, rows)
    return rows


def fabric_sweep() -> list[str]:
    """One registry, all backends: t_iter per fabric preset × arch, the
    plan each fabric's (α, β) selects, and the decode-side serve plan —
    written to ``benchmarks/results/BENCH_fabric.json``.

    The sweep is load-bearing acceptance, not a report: every preset must
    yield a valid plan (schedule covers all units, evaluated timeline),
    and wherever a preset's startup cost is positive the merge gain of
    Eq. 10 must be positive too (the gain IS ``a``) — asserted per cell,
    and re-checked by the ``fabric-smoke`` CI job.
    """
    from repro.configs import get_reduced
    from repro.core.cost_model import TPU_V5E
    from repro.fabric import available_fabrics, get_fabric
    from repro.launch.specs import param_specs
    from repro.planning import Tuner, build_serve_plan

    rows = ["table=fabric_sweep"]
    records = []
    axis_sizes = {"pod": 2, "data": 16}
    serve_axis_sizes = {"model": 16}
    for arch in ("tinyllama-1.1b", "mixtral-8x7b"):
        layout, analytic, _, n_scan = _arch_sweep_inputs(arch)
        serve_cfg = get_reduced(arch)
        for preset in available_fabrics():
            fab = get_fabric(preset)
            ar = fab.cost("all_reduce", axis_sizes)
            tuner = Tuner(layout=layout, n_scan_stages=n_scan)
            plan = tuner.sweep_fabric(
                analytic, fab, axis_sizes, TPU_V5E,
                cost_source="analytic", trigger="fabric_bench",
            )
            rec_t = tuner.last_record
            res = plan.schedule.result
            assert res is not None and res.t_iter > 0, (preset, arch)
            assert plan.schedule.groups[-1][1] == layout.num_layers, (preset, arch)
            merge_gain = ar.merged_gain(1 << 20, 1 << 20)
            if ar.a > 0:
                assert merge_gain > 0, (preset, ar)  # Eq. 10: the gain IS a
            serve = build_serve_plan(
                serve_cfg, param_specs(serve_cfg), fab, serve_axis_sizes,
                batch_rows=16,
            )
            records.append(
                {
                    "arch": arch,
                    "fabric": preset,
                    "a": ar.a,
                    "b": ar.b,
                    "merge_gain_s": merge_gain,
                    "chosen": rec_t.chosen,
                    "comm_source": rec_t.comm_source,
                    "n_groups": len(plan.schedule.groups),
                    "t_iter_s": res.t_iter,
                    "t_comm_exposed_s": res.t_comm_exposed,
                    "serve_op": serve.op,
                    "serve_groups": len(serve.schedule.groups),
                    "serve_t_step_s": serve.schedule.result.t_iter,
                }
            )
            rows.append(
                f"{arch},{preset},a={ar.a:.2e},b={ar.b:.2e},"
                f"chosen={rec_t.chosen},groups={len(plan.schedule.groups)},"
                f"t_iter_ms={res.t_iter * 1e3:.3f},"
                f"serve={serve.op}/{len(serve.schedule.groups)}g"
            )
    write_bench("fabric", records, rows)
    return rows


def serve_exec() -> list[str]:
    """Executed-ServePlan acceptance -> ``BENCH_serve_exec.json``.

    Runs the plan-driven sharded decode (``serving.sharded``) on a
    virtual TP mesh and closes the serve measurement loop:

      * sharded-vs-unsharded token equality (the same requests decoded
        both ways must match token-for-token);
      * predicted (``ServePlan.predicted_step_time()``: probed fixed
        compute+dispatch term + wire timeline) vs observed (``ServeTimer``
        median) step time, gated at ``ratio_budget`` = 3x — the honest
        cost model must stay honest;
      * per-group measured collective seconds at the plan's exact wire
        payloads — the merged schedule's total must not exceed the
        per-stage (wfbp) baseline's on the same mesh (Eq. 10 executed,
        not just priced);
      * op-specific measured fits (``'all_gather@model'``) from real
        decode-gather sweeps, served back through a ``MeasuredFabric``.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import make_mesh
    from repro.configs import get_reduced
    from repro.fabric import MeasuredFabric
    from repro.launch.specs import param_specs
    from repro.models.transformer import init_params
    from repro.planning import build_serve_plan, serve_fabric_fits, time_serve_groups
    from repro.serving import Request, ServeTimer, ServingEngine

    rows = ["table=serve_exec"]
    tp = min(8, jax.device_count())
    mesh = make_mesh((tp,), ("model",))
    cfg = _dc.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    shapes = param_specs(cfg)
    slots, prompt_len, n_tokens = 2, 8, 6
    params = init_params(jax.random.PRNGKey(0), cfg)
    # fp32 engine caches: price the wire at the bytes the step ships
    wire_bytes = {"cache_dtype_bytes": 4, "act_dtype_bytes": 4}
    merged = build_serve_plan(cfg, shapes, "gpu_nccl", {"model": tp},
                              batch_rows=slots, policy="mg_wfbp", **wire_bytes)
    per_stage = build_serve_plan(cfg, shapes, "gpu_nccl", {"model": tp},
                                 batch_rows=slots, policy="wfbp", **wire_bytes)

    def run_engine(mesh_arg, plan):
        timer = ServeTimer(skip_first=2)
        eng = ServingEngine(cfg, params, slots=slots,
                            max_seq=prompt_len + n_tokens + 1,
                            plan=plan, mesh=mesh_arg, timer=timer)
        # compile + probe outside the timed region: the published
        # observed/predicted ratio must compare steady-state dispatch,
        # not XLA compile time
        eng.warmup()
        cal = eng.calibrate_plan()
        rng = np.random.default_rng(0)
        for rid in range(slots + 1):
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=prompt_len, dtype=np.int32),
                max_new_tokens=n_tokens,
            ))
        t0 = time.perf_counter()
        done = eng.run_to_completion()
        dt = time.perf_counter() - t0
        return {r.rid: r.generated for r in done}, timer, cal, dt

    base_tokens, _, _, _ = run_engine(None, merged)
    sharded_tokens, timer, cal_plan, wall_s = run_engine(mesh, merged)
    tokens_match = base_tokens == sharded_tokens
    observed = timer.median()
    predicted = cal_plan.predicted_step_time()
    ratio = observed / predicted
    ratio_budget = 3.0
    n_generated = sum(len(g) for g in sharded_tokens.values())
    tokens_per_s = n_generated / max(wall_s, 1e-9)

    # min-of-7 per group: the merged-vs-per-stage comparison below is a
    # hard acceptance gate, so squeeze scheduler jitter out of the samples
    merged_group_s = time_serve_groups(merged, mesh, repeats=7)
    per_stage_group_s = time_serve_groups(per_stage, mesh, repeats=7)
    fits = serve_fabric_fits(mesh, ops=("all_gather",), axes=("model",))
    fab = MeasuredFabric(models=fits, name="measured_serve")
    measured_plan = build_serve_plan(cfg, shapes, fab, {"model": tp},
                                     batch_rows=slots, **wire_bytes)

    assert tokens_match, "sharded decode diverged from unsharded"
    assert observed is not None and np.isfinite(ratio) and ratio > 0, (observed, ratio)
    assert ratio <= ratio_budget, (
        f"observed/predicted = {ratio:.1f}x exceeds the {ratio_budget:.0f}x "
        f"budget — the compute+dispatch cost model is no longer honest")
    assert sum(merged_group_s) <= sum(per_stage_group_s), (
        merged_group_s, per_stage_group_s)

    record = {
        "arch": cfg.name,
        "tp": tp,
        "slots": slots,
        "fabric": "gpu_nccl",
        "tokens_match": tokens_match,
        "predicted_step_s": predicted,
        "t_step_fixed_s": cal_plan.t_step_fixed,
        "t_wire_s": cal_plan.schedule.result.t_iter,
        "observed_step_s": observed,
        "observed_over_predicted": ratio,
        "ratio_budget": ratio_budget,
        "tokens_per_s": tokens_per_s,
        "merged": {
            "policy": merged.policy,
            "n_groups": len(merged.schedule.groups),
            "groups": [
                dict(g, measured_s=t)
                for g, t in zip(merged.group_summaries(), merged_group_s)
            ],
            "measured_total_s": sum(merged_group_s),
        },
        "per_stage": {
            "policy": per_stage.policy,
            "n_groups": len(per_stage.schedule.groups),
            "measured_total_s": sum(per_stage_group_s),
        },
        "measured_fits": {
            k: {"a": m.a, "b": m.b} for k, m in fits.items()
        },
        "measured_plan": {
            "fabric": measured_plan.fabric,
            "n_groups": len(measured_plan.schedule.groups),
            "t_iter_s": measured_plan.schedule.result.t_iter,
        },
    }
    rows.append(f"{cfg.name},tp={tp},tokens_match={tokens_match},"
                f"pred_ms={predicted * 1e3:.3f},obs_ms={observed * 1e3:.3f},"
                f"ratio={ratio:.2f},fixed_ms={cal_plan.t_step_fixed * 1e3:.3f},"
                f"tok_per_s={tokens_per_s:.1f}")
    rows.append(f"merged({merged.policy}),groups={len(merged.schedule.groups)},"
                f"gather_total_us={sum(merged_group_s) * 1e6:.1f}")
    rows.append(f"per_stage(wfbp),groups={len(per_stage.schedule.groups)},"
                f"gather_total_us={sum(per_stage_group_s) * 1e6:.1f}")
    for key, m in fits.items():
        rows.append(f"fit,{key},a={m.a:.3e},b={m.b:.3e}")
    def gate(rec, base):
        floor = 0.8 * base["tokens_per_s"]
        assert rec["tokens_per_s"] >= floor, (
            f"serve_exec throughput regressed: {rec['tokens_per_s']:.1f} "
            f"tok/s < 0.8x committed baseline {base['tokens_per_s']:.1f}")

    write_bench("serve_exec", record, rows, gate=gate)
    return rows


def wire_layout() -> list[str]:
    """Wire-layout sweep: concat vs variadic vs arena × fp32 vs bf16.

    Lowers + compiles the bucketed sync for each (fuse, comm dtype) cell
    under shard_map on 8 virtual devices, then reads the truth out of the
    compiled HLO with ``profiler.parse_collectives``: all-reduce op
    count, all-reduce payload bytes (bytes moved per device per step),
    and concatenate op count (the copy tax of the concat layout, zero on
    the arena path).  A numeric check (distinct per-rank scaling, exact
    expected average) rides along so a cell that mis-packs can never
    publish.  Full records go to
    ``benchmarks/results/BENCH_wire_layout.json``.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core import (
        AllReduceModel,
        SyncConfig,
        count_expected_allreduces,
        group_arenas,
        make_gradient_sync,
        parse_collectives,
        stacked_lm_layout,
    )
    from repro.planning import build_schedule

    n_stages = 4
    shapes = {
        "embed": {"tok": jnp.zeros((64, 32))},
        "stages": {"w1": jnp.zeros((n_stages, 32, 32)), "w2": jnp.zeros((n_stages, 32))},
        "final_norm": {"scale": jnp.zeros((32,))},
        "head": {"w": jnp.zeros((32, 65))},  # odd tail exercises exact packing
    }
    layout = stacked_lm_layout(shapes, n_stages)
    costs = layout.layer_costs(1 << 20, None)
    # α tuned so mg_wfbp lands on an intermediate grouping for these costs:
    # ((1,1), (2,6)) — a lone embed message plus a merged stages+head arena
    # whose slots include a [0:4) scan slice and an odd-sized head tail
    schedule = build_schedule("mg_wfbp", costs, AllReduceModel(a=5e-5, b=1e-9))
    # honor a pre-existing --xla_force_host_platform_device_count (the
    # module-top guard never overrides one): size the mesh to what exists
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",))
    key = jax.random.PRNGKey(0)
    grads = jax.tree.map(
        lambda s: jax.random.normal(jax.random.fold_in(key, s.size), s.shape), shapes
    )

    rows = ["table=wire_layout"]
    records = []
    for fuse in ("concat", "variadic", "arena"):
        for comp in (None, "bf16"):
            cfg = SyncConfig(fuse=fuse, compression=comp)
            sync = make_gradient_sync(layout, schedule, ("data",), cfg)

            def body(g):
                r = jax.lax.axis_index("data").astype(jnp.float32)
                return sync(jax.tree.map(lambda x: x * (r + 1.0), g))

            f = jax.jit(
                shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          axis_names={"data"}, check_vma=False)
            )
            # lowered (stablehlo) text: the wire dtype is truthful there
            # (compiled CPU modules upcast bf16 collectives to f32)
            stats = parse_collectives(f.lower(grads).as_text())
            got = f(grads)
            # rank r ships (r+1)·g, so the average is mean(1..n_dev)·g
            expect = jax.tree.map(lambda x: (n_dev + 1) / 2 * x, grads)
            max_diff = max(
                jax.tree.leaves(
                    jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), got, expect)
                )
            )
            rec = {
                "fuse": fuse,
                "comm_dtype": "bf16" if comp else "f32",
                "n_groups": len(schedule.groups),
                "allreduce_ops": stats.counts.get("all-reduce", 0),
                "expected_allreduce_ops": count_expected_allreduces(schedule, cfg, layout),
                "wire_bytes": stats.bytes_by_kind.get("all-reduce", 0),
                "concat_ops": stats.concat_ops,
                "max_diff": max_diff,
            }
            if fuse == "arena":
                rec["arena_bytes"] = sum(
                    a.nbytes
                    for a in group_arenas(
                        layout, schedule, shapes,
                        jnp.bfloat16 if comp else jnp.float32,
                    )
                )
            records.append(rec)
            rows.append(
                f"{fuse},{rec['comm_dtype']},groups={rec['n_groups']},"
                f"allreduce_ops={rec['allreduce_ops']},"
                f"wire_bytes={rec['wire_bytes']},concat_ops={rec['concat_ops']},"
                f"max_diff={max_diff:.2e}"
            )
    write_bench("wire_layout", records, rows)
    return rows


def overlap() -> list[str]:
    """Measured DAG-overlap acceptance -> ``BENCH_overlap.json``.

    Runs the same reduced arch through both communication issue orders —
    ``post`` (every merged all-reduce after the whole backward) and
    ``dag`` (each group's all-reduce at its last-gradient event inside
    backward) — under the span recorder, and prices the contrast from
    the PARSED TRACE, not the timeline model:

      * ``overlap_fraction`` (comm inside the backward window) must be
        > 0 for dag and 0 for post — re-asserted by the
        ``overlap-smoke`` CI job and the baseline gate;
      * every comm span must carry its group's exact wire bytes;
      * the dag step must still lower to ONE all-reduce per schedule
        group (small slack for the loss pmean etc.);
      * dag and post losses must agree bit-exactly — reordering the
        issue points must not change the arithmetic.
    """
    import dataclasses as _dc
    import re as _re

    import jax
    import jax.numpy as jnp

    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_reduced
    from repro.core.comm_model import AllReduceModel
    from repro.core.profiler import TraceRecorder, overlap_report
    from repro.core.sync import SyncConfig
    from repro.core.trainer import MGWFBPEngine
    from repro.launch.specs import param_specs
    from repro.models.transformer import init_params
    from repro.optim import make_optimizer

    rows = ["table=overlap"]
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",))
    cfg = _dc.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    eng = MGWFBPEngine.build(
        cfg, param_specs(cfg), dp_axes=("data",),
        ar_model=AllReduceModel(a=5e-5, b=1e-9),
        tokens_per_device=1024, method="wfbp",  # one group per unit
        sync_config=SyncConfig(fuse="arena"),
    )
    n_groups = len(eng.schedule.groups)
    opt = make_optimizer("sgd", momentum=0.9)
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    batch = {"targets": jax.random.randint(key, (8, 64), 0, cfg.vocab)}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(key, (8, 64, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (8, 64), 0, cfg.vocab)

    record: dict = {
        "arch": cfg.name,
        "policy": "wfbp",
        "fuse": "arena",
        "n_groups": n_groups,
        "n_devices": n_dev,
        "group_wire_bytes": [int(b) for b in eng.sync.group_wire_bytes],
    }
    reports = {}
    for issue in ("post", "dag"):
        rec = TraceRecorder()
        step = eng.make_train_step(opt, mesh, lr=1e-2, issue=issue, recorder=rec)

        def call(step=step):  # the step donates params/opt_state buffers
            p0 = jax.tree.map(jnp.array, params)
            return step(p0, opt.init(p0), batch)

        with set_mesh(mesh):
            hlo = step.lower(params, opt.init(params), batch).compile().as_text()
            n_ar = len(_re.findall(r" all-reduce\(", hlo))
            # steady-state trace: drop the compile step's spans
            p, o, m = call()
            jax.block_until_ready(p)
            jax.effects_barrier()
            rec.clear()
            p, o, m = call()
            jax.block_until_ready(p)
        jax.effects_barrier()
        rep = overlap_report(rec.spans())
        reports[issue] = rep
        record[issue] = {
            "loss": float(m["loss"]),
            "allreduce_ops": n_ar,
            **{k: rep[k] for k in (
                "n_comm_spans", "n_bwd_spans", "total_comm_us",
                "windowed_comm_us", "hidden_comm_us", "overlap_fraction",
                "hidden_fraction", "n_overlapped_starts",
            )},
            "groups": rep["groups"],
        }
        rows.append(
            f"{issue},groups={n_groups},allreduce_ops={n_ar},"
            f"overlap_fraction={rep['overlap_fraction']:.3f},"
            f"overlapped_starts={rep['n_overlapped_starts']}/{rep['n_comm_spans']},"
            f"loss={float(m['loss']):.6f}"
        )

    # trace-proved acceptance: the wire moved inside backward under dag
    assert record["dag"]["overlap_fraction"] > 0.0, record["dag"]
    assert record["dag"]["n_overlapped_starts"] > 0, record["dag"]
    assert record["post"]["n_overlapped_starts"] == 0, record["post"]
    assert record["dag"]["overlap_fraction"] > record["post"]["overlap_fraction"]
    # one merged all-reduce per group (slack: loss pmean & friends)
    for issue in ("post", "dag"):
        assert n_groups <= record[issue]["allreduce_ops"] <= n_groups + 4, record
    # per-group spans carry the exact wire bytes of their arena
    by_group: dict[int, int] = {}
    for g in reports["dag"]["groups"]:
        by_group.setdefault(g["group"], g["bytes"])
        assert g["bytes"] == by_group[g["group"]]
    assert sorted(by_group) == list(range(n_groups)), by_group
    for gi, nbytes in by_group.items():
        assert nbytes == record["group_wire_bytes"][gi], (gi, nbytes)
    # issue order must not change the arithmetic
    assert record["dag"]["loss"] == record["post"]["loss"], record
    record["loss_bit_identical"] = True
    rows.append(f"loss_bit_identical=True,"
                f"wire_bytes={sum(record['group_wire_bytes'])}")

    def gate(rec, base):
        assert rec["dag"]["overlap_fraction"] > 0.0
        assert rec["post"]["n_overlapped_starts"] == 0
        assert rec["loss_bit_identical"]

    write_bench("overlap", record, rows, gate=gate)
    return rows


def serve_resilience() -> list[str]:
    """Chaos-injected serving acceptance -> ``BENCH_serve_resilience.json``.

    Exercises the whole resilience layer end to end on the reduced arch:

      * seeded kill sweep (``kill_every`` in 0/5/3): every chaos run must
        finish with tokens bit-identical to the uninterrupted baseline,
        and records restarts, mean recovery seconds (backoff + snapshot
        restore + step re-warm) and goodput tok/s — the goodput floor is
        asserted by the ``serve-chaos-smoke`` CI job;
      * deadline cells on a fake clock (deterministic): one run that
        sheds unmeetable requests at admission, one whose in-flight
        request expires mid-generation with partial output;
      * degraded-fabric replan: sustained injected slowdown drives the
        StragglerMonitor -> serve (α, β) refit -> plan rebuild, and the
        full-size planning cell pins that the degraded constants change
        the merge decision itself (fewer, larger serve groups).
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_reduced
    from repro.core.comm_model import AllReduceModel
    from repro.launch.specs import param_specs
    from repro.models.transformer import init_params
    from repro.planning import build_serve_plan, rebuild_serve_plan
    from repro.runtime import StragglerMonitor
    from repro.serving import (
        ChaosConfig,
        ChaosInjector,
        Request,
        ServingEngine,
        resilient_serve_loop,
    )

    rows = ["table=serve_resilience"]
    records = []
    cfg = _dc.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    slots, prompt_len, n_tokens, n_requests = 2, 8, 8, 4
    max_seq = prompt_len + n_tokens + 1

    def make_engine(**kw):
        kw.setdefault("slots", slots)
        kw.setdefault("max_seq", max_seq)
        return ServingEngine(cfg, params, **kw)

    def submit_all(eng, deadlines=None):
        rng = np.random.default_rng(0)
        for rid in range(n_requests):
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=prompt_len, dtype=np.int32),
                max_new_tokens=n_tokens,
                deadline_s=None if deadlines is None else deadlines[rid],
            ))

    import tempfile

    # -- seeded kill sweep: recovery must be token-identical ---------------
    baseline_tokens = None
    for kill_every in (0, 5, 3):
        eng = make_engine()
        eng.warmup()
        submit_all(eng)
        chaos = (ChaosInjector(ChaosConfig(seed=7, kill_every=kill_every))
                 if kill_every else None)
        with tempfile.TemporaryDirectory() as snap_dir:
            report = resilient_serve_loop(
                eng, snapshot_dir=snap_dir, snapshot_every=2,
                backoff_base_s=0.0, chaos=chaos,
            )
        tokens = {r.rid: r.generated for r in report.completed}
        if baseline_tokens is None:
            baseline_tokens = tokens
        match = tokens == baseline_tokens
        assert match, f"kill_every={kill_every}: tokens diverged after recovery"
        mean_rec = (sum(report.recovery_times_s) / len(report.recovery_times_s)
                    if report.recovery_times_s else 0.0)
        records.append({
            "case": "kill_sweep", "kill_every": kill_every,
            "restarts": report.restarts,
            "recovery_time_s": mean_rec,
            "goodput_tok_s": report.goodput_tok_per_s,
            "tokens_match": match,
        })
        rows.append(
            f"kill_every={kill_every},restarts={report.restarts},"
            f"recovery_s={mean_rec:.3f},"
            f"goodput_tok_s={report.goodput_tok_per_s:.1f},tokens_match={match}"
        )

    # -- deadline shed/expire on a deterministic fake clock ----------------
    class FakeClock:
        def __init__(self, dt):
            self.t, self.dt = 0.0, dt

        def __call__(self):
            self.t += self.dt
            return self.t

    # shed: deadlines already in the past at admission
    eng = make_engine()
    submit_all(eng, deadlines=[-1.0] * n_requests)
    with tempfile.TemporaryDirectory() as snap_dir:
        report = resilient_serve_loop(
            eng, snapshot_dir=snap_dir, snapshot_every=100,
            backoff_base_s=0.0, clock=FakeClock(0.25),
        )
    assert report.shed == n_requests
    records.append({"case": "deadline_shed", "shed": report.shed,
                    "expired": report.expired,
                    "goodput_tokens": report.goodput_tokens})
    rows.append(f"deadline_shed,shed={report.shed},expired={report.expired}")

    # expire: one request's deadline lands mid-generation -> partial output
    eng = make_engine()
    submit_all(eng, deadlines=[1000.0, 4.0, 1000.0, 1000.0])
    with tempfile.TemporaryDirectory() as snap_dir:
        report = resilient_serve_loop(
            eng, snapshot_dir=snap_dir, snapshot_every=100,
            backoff_base_s=0.0, clock=FakeClock(0.25),
        )
    expired = [r for r in report.completed if r.expired]
    assert len(expired) == 1 and 0 < len(expired[0].generated) < n_tokens
    records.append({"case": "deadline_expire", "expired": report.expired,
                    "partial_tokens": len(expired[0].generated),
                    "max_new_tokens": n_tokens})
    rows.append(f"deadline_expire,expired={report.expired},"
                f"partial_tokens={len(expired[0].generated)}/{n_tokens}")

    # -- degraded-fabric replan: loop-level + full-size merge shift --------
    plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e", {"model": 8},
                            batch_rows=slots)
    eng = make_engine(max_seq=128, plan=plan)
    for rid in range(slots):
        eng.submit(Request(rid=rid,
                           prompt=np.arange(4, dtype=np.int32) + 1,
                           max_new_tokens=40))
    chaos = ChaosInjector(ChaosConfig(seed=3, slow_factor=30.0, slow_after=12))
    with tempfile.TemporaryDirectory() as snap_dir:
        report = resilient_serve_loop(
            eng, snapshot_dir=snap_dir, snapshot_every=50,
            backoff_base_s=0.0, chaos=chaos,
            straggler=StragglerMonitor(window=16, factor=2.0, patience=2),
        )
    assert report.replans >= 1 and eng.plan.model.a > plan.model.a
    records.append({
        "case": "degraded_replan", "replans": report.replans,
        "a_before": plan.model.a, "a_after": eng.plan.model.a,
        "pred_step_before_s": plan.predicted_step_time(),
        "pred_step_after_s": eng.plan.predicted_step_time(),
    })
    rows.append(f"degraded_replan,replans={report.replans},"
                f"a={plan.model.a:.2e}->{eng.plan.model.a:.2e}")

    # full-size arch, analytic only: the degraded wire changes the merge
    # decision itself — MG-WFBP's merge set is a function of (a, b)
    cfg_full = get_config("tinyllama-1.1b")
    full = build_serve_plan(cfg_full, param_specs(cfg_full), "tpu_v5e",
                            {"model": 8}, batch_rows=64)
    degraded_model = AllReduceModel(a=full.model.a * 50, b=full.model.b * 10,
                                    name="degraded")
    shifted = rebuild_serve_plan(full, degraded_model)
    assert len(shifted.schedule.groups) < len(full.schedule.groups)
    records.append({
        "case": "merge_shift", "arch": cfg_full.name,
        "groups_before": len(full.schedule.groups),
        "groups_after": len(shifted.schedule.groups),
        "pred_step_before_s": full.predicted_step_time(),
        "pred_step_after_s": shifted.predicted_step_time(),
    })
    rows.append(f"merge_shift,groups={len(full.schedule.groups)}->"
                f"{len(shifted.schedule.groups)},"
                f"pred_s={full.predicted_step_time():.2e}->"
                f"{shifted.predicted_step_time():.2e}")

    write_bench("serve_resilience", records, rows)
    return rows


def serve_fleet() -> list[str]:
    """Fleet-under-chaos acceptance -> ``BENCH_serve_fleet.json``.

    Drives the 4-replica serving fleet (``serving.fleet``) through the
    SAME seeded offered load with and without kill chaos and publishes
    p50/p99 latency and goodput vs offered load:

      * ``fault_free``  — 4 replicas, seeded Poisson load, no faults;
      * ``kill_chaos``  — identical load, replica 0's fault domain kills
        it with no restore budget, so its in-flight requests fail over.
        Hard acceptance (re-asserted by the ``serve-fleet-smoke`` CI
        job): goodput ≥ 70% of the fault-free run and ZERO failed-over
        requests whose final tokens diverge from their partial prefix;
      * ``load_sweep``  — offered rate × replica count grid: p50/p99
        latency and goodput per cell, the saturation curve;
      * ``slo_shed``    — a deadline no plan-priced replica can meet:
        everything sheds at admission, costing zero decode steps.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.launch.specs import param_specs
    from repro.models.transformer import init_params
    from repro.planning import build_serve_plan
    from repro.serving import (
        ChaosConfig,
        FleetConfig,
        FleetController,
        LoadGenerator,
        LoadSpec,
        ServingEngine,
    )

    rows = ["table=serve_fleet"]
    record: dict = {}
    cfg = _dc.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    slots, prompt_len, n_tokens = 2, 8, 8
    max_seq = prompt_len + n_tokens + 1
    plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e", {"model": 8},
                            batch_rows=slots, cache_dtype_bytes=4,
                            act_dtype_bytes=4)

    def factory(rid: int) -> ServingEngine:
        eng = ServingEngine(cfg, params, slots=slots, max_seq=max_seq,
                            plan=plan)
        eng.warmup()
        return eng

    import tempfile

    def run_cell(*, replicas, n_requests, rate=1e6, deadline_s=None,
                 chaos=None, chaos_replicas=None, seed=0):
        load = LoadGenerator(LoadSpec(
            n_requests=n_requests, prompt_len=prompt_len,
            max_new_tokens=n_tokens, rate_rps=rate, deadline_s=deadline_s,
            seed=seed, vocab=cfg.vocab,
        ))
        with tempfile.TemporaryDirectory() as snap_root:
            fleet = FleetController(
                engine_factory=factory,
                config=FleetConfig(replicas=replicas, snapshot_every=4,
                                   max_restores=0, backoff_base_s=0.0),
                snapshot_root=snap_root,
                chaos=chaos, chaos_replicas=chaos_replicas,
            )
            return fleet.run(load)

    # -- fault-free vs single-replica kill chaos, same seeded load ---------
    n_requests = 16
    ff = run_cell(replicas=4, n_requests=n_requests)
    ko = run_cell(replicas=4, n_requests=n_requests,
                  chaos=ChaosConfig(seed=7, kill_at=(2,)),
                  chaos_replicas=(0,))
    goodput_ratio = ko.goodput_tokens / max(ff.goodput_tokens, 1)
    record["fault_free"] = ff.summary()
    record["kill_chaos"] = ko.summary() | {
        "goodput_ratio_vs_fault_free": goodput_ratio,
    }
    assert ff.failover_token_mismatches == 0
    assert ko.replica_deaths == 1 and ko.failovers >= 1
    assert ko.failover_token_mismatches == 0, (
        "failed-over requests diverged from their partial prefix")
    assert goodput_ratio >= 0.7, (
        f"kill chaos retained only {goodput_ratio:.0%} of fault-free goodput")
    for name, rep in (("fault_free", ff), ("kill_chaos", ko)):
        s = rep.summary()
        rows.append(
            f"{name},replicas=4,offered={s['offered']},"
            f"completed={s['completed']},p50_ms={s['p50_latency_s'] * 1e3:.1f},"
            f"p99_ms={s['p99_latency_s'] * 1e3:.1f},"
            f"goodput_tokens={s['goodput_tokens']},"
            f"failovers={s['failovers']},"
            f"mismatches={s['failover_token_mismatches']}"
        )
    rows.append(f"kill_chaos_goodput_ratio={goodput_ratio:.3f} (floor 0.7)")

    # -- p50/p99/goodput vs offered load -----------------------------------
    record["load_sweep"] = []
    for replicas in (1, 2):
        for rate in (50.0, 400.0):
            rep = run_cell(replicas=replicas, n_requests=8, rate=rate, seed=1)
            cell = rep.summary() | {"replicas": replicas, "rate_rps": rate}
            record["load_sweep"].append(cell)
            rows.append(
                f"load,replicas={replicas},rate={rate:.0f},"
                f"p50_ms={cell['p50_latency_s'] * 1e3:.1f},"
                f"p99_ms={cell['p99_latency_s'] * 1e3:.1f},"
                f"goodput_tok_s={cell['goodput_tok_per_s']:.1f}"
            )

    # -- SLO shed: no replica's plan can meet the deadline -----------------
    shed = run_cell(replicas=2, n_requests=6, deadline_s=1e-9, seed=2)
    assert shed.shed == 6 and shed.goodput_tokens == 0
    record["slo_shed"] = shed.summary()
    rows.append(f"slo_shed,offered=6,shed={shed.shed},"
                f"goodput_tokens={shed.goodput_tokens}")

    def gate(rec, base):
        ratio = rec["kill_chaos"]["goodput_ratio_vs_fault_free"]
        assert ratio >= 0.7, f"chaos goodput ratio {ratio:.2f} < 0.7 floor"
        assert rec["kill_chaos"]["failover_token_mismatches"] == 0
        base_ratio = base["kill_chaos"]["goodput_ratio_vs_fault_free"]
        assert ratio >= 0.9 * base_ratio, (
            f"chaos goodput ratio regressed: {ratio:.2f} vs committed "
            f"{base_ratio:.2f}")

    write_bench("serve_fleet", record, rows, gate=gate)
    return rows


def sim() -> list[str]:
    """Fleet-scale what-if simulator suite (``repro.sim``): calibration
    against the committed BENCH records, the paper's Figs. 6-8 scaling
    ordering on the simulated 10GbE cluster, a policies x fleets x
    fabrics what-if sweep to 512 hosts, straggler/elastic/serve replay
    cells, and a two-run byte-determinism check.  Record goes to
    ``benchmarks/results/BENCH_sim.json``.

    Paper-ordering note: at the paper's own batches (googlenet 64,
    resnet50 32) the 64-node WFBP cell falls *below* SyncEASGD — per-layer
    ring startup 2(N-1)α dominates at N=64, the exact crossover MG-WFBP
    exists to fix (and MG-WFBP stays on top).  Those cells are recorded
    unasserted; the strict MG-WFBP > WFBP > SyncEASGD chain is asserted
    at 8 nodes with paper batches and at 64 nodes in the compute-balanced
    regime (googlenet 256 / resnet50 128)."""
    import hashlib

    from repro.configs.cnn_profiles import cnn_layer_costs
    from repro.core.cost_model import K80_CALIBRATED
    from repro.serving.fleet import LoadSpec
    from repro.sim import (
        ClusterEvent,
        ClusterSpec,
        SimReport,
        calibrate_serve,
        calibrate_train,
        replay_serve,
        replay_train,
        row_from_replay,
    )

    rows = ["table=sim"]
    record = {}
    POLICIES = ("synceasgd", "wfbp", "mg_wfbp")

    # -- calibration: the simulator must reproduce the committed records ---
    cal = {}
    for rep in (calibrate_train(), calibrate_serve()):
        cal[rep.kind] = rep.to_json_dict()
        assert rep.ok, (
            f"calibration/{rep.kind}: max ratio {rep.max_ratio:.4f} blew "
            f"the {rep.budget}x budget — what-ifs would be untrustworthy")
        rows.append(
            f"calibration,{rep.kind},rows={len(rep.rows)},"
            f"max_ratio={rep.max_ratio:.6f},budget={rep.budget}"
        )
    record["calibration"] = cal

    # -- paper reproduction: Figs. 6-8 scaling-efficiency ordering ---------
    def eff_cells(arch: str, batch: int, n: int) -> dict:
        cluster = ClusterSpec(n_hosts=n, fabric="paper_10gbe")
        costs = cnn_layer_costs(arch, batch)
        return {
            p: row_from_replay(
                replay_train(cluster, list(costs), p, hw=K80_CALIBRATED),
                arch, "paper_10gbe", n,
            ).to_json_dict()
            for p in POLICIES
        }

    paper = {"asserted": [], "crossover_unasserted": []}
    for arch, batch, n in (
        ("googlenet", 64, 8), ("resnet50", 32, 8),       # paper batches
        ("googlenet", 256, 64), ("resnet50", 128, 64),   # compute-balanced
    ):
        cells = eff_cells(arch, batch, n)
        effs = {p: cells[p]["efficiency"] for p in POLICIES}
        assert effs["mg_wfbp"] > effs["wfbp"] > effs["synceasgd"], (
            f"{arch} b{batch} n={n}: MG-WFBP > WFBP > SyncEASGD ordering "
            f"broken: {effs}")
        paper["asserted"].append(
            {"arch": arch, "batch": batch, "n_hosts": n, "cells": cells})
        rows.append(
            f"paper,{arch},b{batch},n={n},"
            + ",".join(f"{p}={effs[p]:.4f}" for p in POLICIES)
            + ",ordering=ok"
        )
    for arch, batch in (("googlenet", 64), ("resnet50", 32)):
        cells = eff_cells(arch, batch, 64)
        effs = {p: cells[p]["efficiency"] for p in POLICIES}
        assert effs["mg_wfbp"] == max(effs.values())  # MG-WFBP still wins
        paper["crossover_unasserted"].append(
            {"arch": arch, "batch": batch, "n_hosts": 64, "cells": cells})
        rows.append(
            f"paper_crossover,{arch},b{batch},n=64,"
            + ",".join(f"{p}={effs[p]:.4f}" for p in POLICIES)
            + ",wfbp_startup_bound=unasserted"
        )
    record["paper"] = paper

    # -- what-if sweep: policies x fleets x fabrics, run twice for the -----
    # -- byte-determinism contract -----------------------------------------
    FABRICS = ("paper_10gbe", "tree_10gbe", "pipeline_10gbe", "tpu_v5e_tree_dcn")
    HOSTS = (8, 64, 512)
    wcosts = cnn_layer_costs("googlenet", 64)

    def build_report() -> SimReport:
        srows = []
        for fabric in FABRICS:
            ici = 16 if fabric == "tpu_v5e_tree_dcn" else 0
            for n in HOSTS:
                cluster = ClusterSpec(n_hosts=n, ici_size=ici, fabric=fabric)
                for p in POLICIES:
                    res = replay_train(cluster, list(wcosts), p,
                                       hw=K80_CALIBRATED)
                    srows.append(row_from_replay(res, "googlenet", fabric, n))
        return SimReport(
            rows=tuple(srows),
            calibration=cal,
            provenance={"arch": "googlenet", "batch": "64",
                        "source": "benchmarks.run/sim"},
        )

    report, report2 = build_report(), build_report()
    j1, j2 = report.to_json(), report2.to_json()
    assert j1 == j2, "identical specs produced different SimReport bytes"
    record["whatif"] = [r.to_json_dict() for r in report.rows]
    record["determinism"] = {
        "identical": j1 == j2,
        "sha256": hashlib.sha256(j1.encode()).hexdigest(),
    }
    for fabric in FABRICS:
        for n in HOSTS:
            best = report.best_policy(fabric=fabric, n_hosts=n)
            eff = report.select(fabric=fabric, n_hosts=n, policy=best)[0].efficiency
            rows.append(f"whatif,{fabric},n={n},best={best},eff={eff:.4f}")
    rows.append(f"determinism,two_runs,identical=True,"
                f"sha256={record['determinism']['sha256'][:16]}")

    # -- stragglers: heterogeneous fleets can only get slower --------------
    strag = []
    for spread in (0.0, 0.2, 0.5):
        cluster = ClusterSpec(n_hosts=64, fabric="paper_10gbe",
                              straggler_spread=spread, seed=3)
        res = replay_train(cluster, list(wcosts), "mg_wfbp", hw=K80_CALIBRATED)
        strag.append({"spread": spread, "t_iter_s": res.mean_t_iter,
                      "efficiency": res.mean_efficiency})
    assert strag[0]["t_iter_s"] <= strag[1]["t_iter_s"] <= strag[2]["t_iter_s"], (
        f"t_iter must be monotone in straggler spread: {strag}")
    record["straggler"] = strag
    rows.append("straggler,n=64,"
                + ",".join(f"spread{s['spread']}={s['t_iter_s'] * 1e3:.3f}ms"
                           for s in strag) + ",monotone=ok")

    # -- elastic fleet: shrink/grow/kill re-plans the merge set ------------
    elastic_cluster = ClusterSpec(
        n_hosts=64, fabric="paper_10gbe",
        events=(ClusterEvent(at_iter=2, kind="shrink", count=32),
                ClusterEvent(at_iter=4, kind="grow", count=32),
                ClusterEvent(at_iter=6, kind="kill", count=8)),
    )
    el = replay_train(elastic_cluster, list(wcosts), "mg_wfbp",
                      hw=K80_CALIBRATED, n_iters=8)
    assert el.n_replans == 3 and el.n_kills == 8, (el.n_replans, el.n_kills)
    alive = [it["n_alive"] for it in el.iterations]
    assert alive == [64, 64, 32, 32, 64, 64, 56, 56], alive
    record["elastic"] = {"n_replans": el.n_replans, "n_kills": el.n_kills,
                         "iterations": list(el.iterations)}
    rows.append(f"elastic,n0=64,replans={el.n_replans},kills={el.n_kills},"
                f"alive={'/'.join(map(str, alive))}")

    # -- serve replay: min-ETA routing, kill failover, SLO shed ------------
    load = LoadSpec(n_requests=12, prompt_len=1, max_new_tokens=16,
                    kind="trace", trace_arrivals_s=(0.0,) * 12, seed=0)
    sv = replay_serve(load, 0.01, n_replicas=2, slots=4,
                      kill_at_s={0: 0.05})
    assert sv.failovers >= 1 and sv.lost == 0 and sv.completed == 12, (
        sv.to_json_dict())
    shed = replay_serve(
        LoadSpec(n_requests=6, prompt_len=1, max_new_tokens=16, kind="trace",
                 trace_arrivals_s=(0.0,) * 6, deadline_s=1e-9, seed=0),
        0.01, n_replicas=2, slots=4,
    )
    assert shed.shed == 6 and shed.completed == 0, shed.to_json_dict()
    record["serve_sim"] = {"kill_failover": sv.to_json_dict(),
                           "slo_shed": shed.to_json_dict()}
    rows.append(f"serve,kill_failover,completed={sv.completed},"
                f"failovers={sv.failovers},tok_s={sv.tokens_per_s:.1f},"
                f"p99_ms={sv.latency_percentile(99) * 1e3:.1f}")
    rows.append(f"serve,slo_shed,offered=6,shed={shed.shed}")

    def gate(rec, base):
        for kind in ("train", "serve"):
            c = rec["calibration"][kind]
            assert c["ok"], f"calibration/{kind} out of budget: {c['max_ratio']}"
            b = base["calibration"][kind]
            assert c["max_ratio"] <= max(b["max_ratio"] * 1.05, 1.0 + 1e-9), (
                f"calibration/{kind} regressed: {c['max_ratio']:.4f} vs "
                f"committed {b['max_ratio']:.4f}")
        assert rec["determinism"]["identical"]
        for cell in rec["paper"]["asserted"]:
            effs = {p: cell["cells"][p]["efficiency"] for p in POLICIES}
            assert effs["mg_wfbp"] > effs["wfbp"] > effs["synceasgd"], (
                f"paper ordering broken in {cell['arch']} b{cell['batch']} "
                f"n={cell['n_hosts']}: {effs}")

    write_bench("sim", record, rows, gate=gate)
    return rows


def main() -> None:
    from benchmarks.paper_tables import ALL_TABLES

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated table names (default: all)")
    args = ap.parse_args()

    tables = list(ALL_TABLES) + [
        planning_sweep, wire_layout, tuner, fabric_sweep, serve_exec,
        overlap, serve_resilience, serve_fleet, sim, roofline_summary,
    ]
    if args.only:
        wanted = {n.strip() for n in args.only.split(",")}
        unknown = wanted - {fn.__name__ for fn in tables}
        if unknown:
            raise SystemExit(f"unknown tables {sorted(unknown)}; "
                             f"have {[fn.__name__ for fn in tables]}")
        tables = [fn for fn in tables if fn.__name__ in wanted]
    for fn in tables:
        t0 = time.perf_counter()
        rows = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        print(f"{fn.__name__},{dt_us:.0f},rows={len(rows) - 1}")
        for r in rows:
            print("  " + r)
        print()


if __name__ == "__main__":
    main()
