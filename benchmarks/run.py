"""Benchmark harness entry point: one function per paper table/figure plus
the roofline summary assembled from dry-run records.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract:
each table reports its wall time and emits its rows beneath it.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

sys.path.insert(0, "src")


def roofline_summary() -> list[str]:
    """Per-(arch x shape x mesh) roofline terms from the dry-run records."""
    rows = ["table=roofline_summary"]
    results = pathlib.Path(__file__).parent / "results" / "dryrun"
    if not results.exists():
        rows.append("no dry-run records yet; run python -m repro.launch.dryrun --all")
        return rows
    for f in sorted(results.glob("*.json")):
        rec = json.loads(f.read_text())
        t = rec.get("totals")
        mem = rec["memory"]["peak_per_device_gib"]
        if not t:
            rows.append(f"{rec['arch']},{rec['shape']},{rec['mesh']},mem_gib={mem},segments=skipped")
            continue
        rows.append(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},mem_gib={mem},"
            f"compute_s={t['compute_term_s']:.4f},memory_s={t['memory_term_s']:.4f},"
            f"collective_s={t['collective_term_s']:.4f},dominant={t['dominant']},"
            f"useful_ratio={t['useful_flops_ratio']:.3f},"
            f"roofline_fraction={t['roofline_fraction']:.4f}"
        )
    return rows


def planning_sweep() -> list[str]:
    """Sweep scheduler policies × cost sources through the planning
    registry; rows go to stdout and the full records to
    ``benchmarks/results/BENCH_planning.json`` so future PRs have a perf
    trajectory (t_iter, exposed comm, group count per policy)."""
    from repro.configs import get_config
    from repro.core import tpu_psum_model
    from repro.core.cost_model import TPU_V5E
    from repro.core.trainer import lm_unit_costs
    from repro.launch.specs import param_specs
    from repro.planning import (
        MEASURED_HW,
        MeasuredCosts,
        available_policies,
        build_schedule,
    )

    rows = ["table=planning_sweep"]
    records = []
    ar = tpu_psum_model({"pod": 2, "data": 16})
    policies = sorted(set(available_policies()) - {"optimal"})  # 2^(L-1) — skip
    for arch in ("tinyllama-1.1b", "mixtral-8x7b", "recurrentgemma-9b"):
        cfg = get_config(arch)
        analytic = lm_unit_costs(
            cfg, param_specs(cfg), tokens_per_device=8192, model_shards=16
        )
        # Skewed measured profile: compute 3x the analytic belief — the
        # regime where re-planning pays (comm hides behind backward).
        measured = MeasuredCosts.from_unit_times(
            analytic,
            [c.t_b(TPU_V5E) * 3.0 for c in analytic],
            [c.t_f(TPU_V5E) * 3.0 for c in analytic],
            name="measured_3x",
        )
        sources = {
            "analytic": (analytic, TPU_V5E),
            "measured_3x": (measured.layer_costs(), MEASURED_HW),
        }
        for policy in policies:
            for src, (costs, hw) in sources.items():
                s = build_schedule(policy, costs, ar, hw=hw)
                r = s.result
                records.append(
                    {
                        "arch": arch,
                        "policy": policy,
                        "cost_source": src,
                        "n_groups": len(s.groups),
                        "t_iter_s": r.t_iter,
                        "t_comm_exposed_s": r.t_comm_exposed,
                        "t_comm_total_s": r.t_comm_total,
                    }
                )
                rows.append(
                    f"{arch},{policy},{src},groups={len(s.groups)},"
                    f"t_iter_ms={r.t_iter * 1e3:.3f},"
                    f"exposed_ms={r.t_comm_exposed * 1e3:.3f}"
                )
    out = pathlib.Path(__file__).parent / "results" / "BENCH_planning.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(records, indent=1))
    rows.append(f"wrote {out}")
    return rows


def main() -> None:
    from benchmarks.paper_tables import ALL_TABLES

    tables = list(ALL_TABLES) + [planning_sweep, roofline_summary]
    for fn in tables:
        t0 = time.perf_counter()
        rows = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        print(f"{fn.__name__},{dt_us:.0f},rows={len(rows) - 1}")
        for r in rows:
            print("  " + r)
        print()


if __name__ == "__main__":
    main()
