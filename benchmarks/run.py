"""Benchmark harness entry point: one function per paper table/figure plus
the roofline summary assembled from dry-run records.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract:
each table reports its wall time and emits its rows beneath it.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

sys.path.insert(0, "src")


def roofline_summary() -> list[str]:
    """Per-(arch x shape x mesh) roofline terms from the dry-run records."""
    rows = ["table=roofline_summary"]
    results = pathlib.Path(__file__).parent / "results" / "dryrun"
    if not results.exists():
        rows.append("no dry-run records yet; run python -m repro.launch.dryrun --all")
        return rows
    for f in sorted(results.glob("*.json")):
        rec = json.loads(f.read_text())
        t = rec.get("totals")
        mem = rec["memory"]["peak_per_device_gib"]
        if not t:
            rows.append(f"{rec['arch']},{rec['shape']},{rec['mesh']},mem_gib={mem},segments=skipped")
            continue
        rows.append(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},mem_gib={mem},"
            f"compute_s={t['compute_term_s']:.4f},memory_s={t['memory_term_s']:.4f},"
            f"collective_s={t['collective_term_s']:.4f},dominant={t['dominant']},"
            f"useful_ratio={t['useful_flops_ratio']:.3f},"
            f"roofline_fraction={t['roofline_fraction']:.4f}"
        )
    return rows


def main() -> None:
    from benchmarks.paper_tables import ALL_TABLES

    tables = list(ALL_TABLES) + [roofline_summary]
    for fn in tables:
        t0 = time.perf_counter()
        rows = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        print(f"{fn.__name__},{dt_us:.0f},rows={len(rows) - 1}")
        for r in rows:
            print("  " + r)
        print()


if __name__ == "__main__":
    main()
