"""Roofline report generator: reads dry-run JSON records and emits the
EXPERIMENTS.md §Roofline table (markdown) plus per-cell one-line analyses.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--md]
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).parent / "results" / "dryrun"

MOVE_HINTS = {
    ("memory", "train"): "fuse attention score chain (Pallas flash on TPU) and raise q_chunk — HLO per-op bytes over-count unfused elementwise chains",
    ("memory", "prefill"): "sequence-shard q-chunks over the idle model axis; score buffers in bf16",
    ("memory", "decode"): "decode is cache-read bound by nature; shrink KV via window/ring buffers or quantized cache",
    ("collective", "train"): "MG-WFBP bucket schedule on the DP axis + bf16 wire dtype; overlap weight gathers with compute",
    ("collective", "prefill"): "recurrent-state archs: batch the state exchanges; gather K/V once per layer not per chunk",
    ("collective", "decode"): "stop FSDP-gathering weights per token: shard serving params over 'model' only (or EP)",
    ("compute", "train"): "reduce remat recompute (dots-saveable policy); shard idle mesh axes into the batch",
    ("compute", "prefill"): "use the model axis: TP heads or sequence-sharded chunks",
    ("compute", "decode"): "decode flops are trivial; compute never binds here",
}


def load(mesh: str) -> list[dict]:
    out = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        out.append(json.loads(f.read_text()))
    return out


def emit(mesh: str, md: bool) -> None:
    recs = load(mesh)
    kind_of = {"train_4k": "train", "prefill_32k": "prefill",
               "decode_32k": "decode", "long_500k": "decode"}
    if md:
        print(f"| arch | shape | mem GiB | compute s | memory s | collective s | dominant | useful | fraction |")
        print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        t = r.get("totals")
        if not t:
            print(f"| {r['arch']} | {r['shape']} | {r['memory']['peak_per_device_gib']} "
                  f"| - | - | - | (multi-pod compile proof) | - | - |" if md else
                  f"{r['arch']},{r['shape']},mem={r['memory']['peak_per_device_gib']}")
            continue
        line = (
            f"| {r['arch']} | {r['shape']} | {r['memory']['peak_per_device_gib']:.2f} "
            f"| {t['compute_term_s']:.4f} | {t['memory_term_s']:.4f} "
            f"| {t['collective_term_s']:.4f} | {t['dominant']} "
            f"| {t['useful_flops_ratio']:.3f} | {t['roofline_fraction']:.4f} |"
            if md else
            f"{r['arch']},{r['shape']},{t['compute_term_s']:.4f},{t['memory_term_s']:.4f},"
            f"{t['collective_term_s']:.4f},{t['dominant']},{t['roofline_fraction']:.4f}"
        )
        print(line)
    if md:
        print()
        print("**What would move the dominant term (per family):**")
        seen = set()
        for r in recs:
            t = r.get("totals")
            if not t:
                continue
            key = (t["dominant"], kind_of[r["shape"]])
            if key in seen:
                continue
            seen.add(key)
            print(f"- *{key[0]} × {key[1]}*: {MOVE_HINTS.get(key, 'n/a')}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16"])
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    emit(args.mesh, args.md)


if __name__ == "__main__":
    main()
