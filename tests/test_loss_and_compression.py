"""Chunked-CE equivalence and int8 error-feedback compression."""

import dataclasses
import json
import subprocess
import sys
import textwrap
from _env import REPO_ROOT, SUBPROC_ENV  # shared subprocess env

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params, loss_fn
from repro.models import transformer as tf_mod


class TestChunkedCE:
    def test_chunked_matches_full(self, monkeypatch):
        """Sequence-chunked CE must equal the full-logits CE (values and
        gradients) — it is a pure memory transformation."""
        cfg = dataclasses.replace(
            get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32
        )
        params = init_params(jax.random.PRNGKey(0), cfg)
        B, S = 2, 64
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab),
            "targets": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
        }
        full_loss, _ = loss_fn(params, batch, cfg)
        g_full = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)

        monkeypatch.setattr(tf_mod, "CHUNKED_CE_VOCAB", 1)
        monkeypatch.setattr(tf_mod, "CE_SEQ_CHUNK", 16)
        chunk_loss, _ = loss_fn(params, batch, cfg)
        g_chunk = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)

        np.testing.assert_allclose(float(full_loss), float(chunk_loss), rtol=1e-6)
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), g_full, g_chunk
        )
        assert max(jax.tree.leaves(diffs)) < 1e-5


COMPRESSION_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, set_mesh, shard_map
    from repro.runtime.compression import compressed_psum_rs_ag

    mesh = make_mesh((8,), ("dp",))

    def body(g, res):
        return compressed_psum_rs_ag(g, "dp", res)

    f = jax.jit(shard_map(body, mesh=mesh, axis_names={"dp"},
                 in_specs=(P("dp"), P("dp")), out_specs=(P("dp"), P("dp")),
                 check_vma=False))

    key = jax.random.PRNGKey(0)
    # per-device distinct gradients: (8, n) rows = one per device
    g = jax.random.normal(key, (8, 1024), jnp.float32)
    res = jnp.zeros_like(g)
    with set_mesh(mesh):
        out, new_res = f(g, res)
    exact = jnp.sum(g, axis=0)
    out_rows = np.asarray(out)
    # every device row should hold (approximately) the exact sum
    err = float(np.max(np.abs(out_rows - np.asarray(exact)[None, :])))
    scale = float(np.max(np.abs(np.asarray(exact))))
    # error feedback: residual captures the quantization error
    res_norm = float(np.max(np.abs(np.asarray(new_res))))

    # second round with error feedback reduces accumulated bias:
    with set_mesh(mesh):
        out2, res2 = f(g, new_res)
    two_step = np.asarray(out) + np.asarray(out2)
    exact2 = 2 * np.asarray(exact)
    err2 = float(np.max(np.abs(two_step - exact2[None, :])))

    print(json.dumps({"err": err, "scale": scale, "res_norm": res_norm,
                      "err2_accum": err2}))
""")


def test_int8_rs_ag_compression():
    out = subprocess.run(
        [sys.executable, "-c", COMPRESSION_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env=SUBPROC_ENV,
        cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # int8 quantization error bounded by ~scale/127 per shard
    assert rec["err"] <= rec["scale"] / 127 * 3 + 1e-6, rec
    # residual is nonzero (error feedback captured something)
    assert rec["res_norm"] > 0, rec
    # with EF, two accumulated steps stay within ~the same bound (no drift)
    assert rec["err2_accum"] <= rec["scale"] / 127 * 6 + 1e-6, rec
