"""Golden-trace regression suite for the span/overlap pipeline.

``tests/data/overlap_trace.json`` is a committed Chrome trace recorded by
a real ``--dryrun 2 --issue-order dag`` launcher run (tinyllama-1.1b
reduced, wfbp policy, fuse=arena, 8 virtual devices).  The suite pins:

  * span parsing (dict / JSON string / path / gzip round-trips);
  * ``wfbp_group{gi}_l{lo}_{hi}`` attribution: group indices, layer
    ranges, per-device counts;
  * per-group wire bytes in the trace == ``sync.group_wire_bytes`` of
    the same (arch, policy, fuse) rebuilt from the planning stack — the
    trace's payload accounting must stay tied to the arena layout;
  * the overlap-report arithmetic, to the float (the fixture is static,
    so the report is a pure function with golden outputs);
  * ``TraceRecorder`` pairing/serialization on an injected fake clock
    (hand-checkable interval arithmetic, no wall clock).
"""

from __future__ import annotations

import dataclasses
import gzip
import json
import pathlib

import pytest

import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import stacked_lm_layout
from repro.core.comm_model import AllReduceModel
from repro.core.profiler import (
    GROUP_SPAN_RE,
    TraceRecorder,
    overlap_report,
    parse_trace_spans,
)
from repro.core.sync import SyncConfig, make_gradient_sync
from repro.launch.specs import param_specs
from repro.planning import build_schedule

FIXTURE = pathlib.Path(__file__).parent / "data" / "overlap_trace.json"

# The run that recorded the fixture: 6 wfbp groups on 8 data shards.
N_DEVICES = 8
N_GROUPS = 6
GROUP_BYTES = [131584, 738304, 738304, 738304, 738304, 131072]


@pytest.fixture(scope="module")
def spans():
    return parse_trace_spans(FIXTURE)


class TestParsing:
    def test_all_input_forms_agree(self, spans, tmp_path):
        raw = FIXTURE.read_text()
        assert parse_trace_spans(json.loads(raw)) == spans  # dict
        assert parse_trace_spans(raw) == spans  # JSON string
        gz = tmp_path / "trace.json.gz"
        gz.write_bytes(gzip.compress(raw.encode()))
        assert parse_trace_spans(gz) == spans  # gzip path

    def test_span_population(self, spans):
        comm = [s for s in spans if GROUP_SPAN_RE.match(s.name)]
        bwd = [s for s in spans if s.name.startswith("bwd_")]
        assert len(spans) == 96
        assert len(comm) == N_DEVICES * N_GROUPS == 48
        assert len(bwd) == 48
        assert {s.device for s in spans} == set(range(N_DEVICES))
        assert all(s.dur_us > 0 for s in spans)

    def test_group_attribution(self, spans):
        """wfbp groups issue in backward order: group 0 is layers (6,6),
        group 5 is layers (1,1) — every device agrees."""
        for s in spans:
            m = GROUP_SPAN_RE.match(s.name)
            if not m:
                continue
            gi, lo, hi = int(m.group(1)), int(m.group(2)), int(m.group(3))
            assert (lo, hi) == (N_GROUPS - gi, N_GROUPS - gi), s.name
            assert int(s.args["bytes"]) == GROUP_BYTES[gi], s.name


class TestWireBytes:
    def test_trace_bytes_match_arena_layout(self, spans):
        """The bytes each span carries must equal the group's arena wire
        bytes rebuilt from the same (arch, policy, fuse) planning path."""
        cfg = dataclasses.replace(
            get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32
        )
        shapes = param_specs(cfg)
        layout = stacked_lm_layout(shapes, cfg.n_stages)
        costs = layout.layer_costs(8 * 64 // 8, None)
        sched = build_schedule("wfbp", costs, AllReduceModel(a=5e-5, b=1e-9))
        sync = make_gradient_sync(
            layout, sched, ("data",), SyncConfig(fuse="arena")
        )
        assert list(sync.group_wire_bytes) == GROUP_BYTES
        for s in spans:
            m = GROUP_SPAN_RE.match(s.name)
            if m:
                assert int(s.args["bytes"]) == sync.group_wire_bytes[int(m.group(1))]


class TestOverlapReport:
    def test_golden_numbers(self, spans):
        rep = overlap_report(spans)
        assert rep["n_devices"] == N_DEVICES
        assert rep["n_comm_spans"] == 48
        assert rep["n_bwd_spans"] == 48
        assert rep["n_overlapped_starts"] == 40
        assert rep["total_comm_us"] == pytest.approx(42091.329, abs=1e-6)
        assert rep["windowed_comm_us"] == pytest.approx(18515.952991, abs=1e-5)
        assert rep["overlap_fraction"] == pytest.approx(0.4398994622, abs=1e-9)
        # serial CPU backend: comm executes in the gaps between backward
        # segments, so strict concurrency is honestly zero
        assert rep["hidden_comm_us"] == 0.0
        assert rep["hidden_fraction"] == 0.0

    def test_group_rows(self, spans):
        rep = overlap_report(spans)
        # one steady-state step x 6 groups on the first device (the
        # dryrun drops the warm-up/compile step's spans)
        assert len(rep["groups"]) == 6
        assert [g["group"] for g in rep["groups"]] == sorted(
            g["group"] for g in rep["groups"]
        )
        for g in rep["groups"]:
            assert g["layers"] == [N_GROUPS - g["group"]] * 2
            assert g["bytes"] == GROUP_BYTES[g["group"]]
            # trace durations are rounded to 3 decimals; allow that slack
            assert g["window_us"] <= g["dur_us"] + 1e-3
        # at least one non-final group demonstrably starts inside backward
        assert any(
            g["starts_before_bwd_end"] for g in rep["groups"] if g["group"] < N_GROUPS - 1
        )

    def test_empty_trace_reports_zeros(self):
        rep = overlap_report([])
        assert rep["n_comm_spans"] == 0
        assert rep["overlap_fraction"] == 0.0
        assert rep["groups"] == []


class TestRecorderFakeClock:
    def test_pairing_and_arithmetic(self, tmp_path):
        """Deterministic recorder run on an injected ns clock: spans pair
        FIFO per (name, device) and the report arithmetic is checkable by
        hand (all times in µs after the 1e3 conversion)."""
        ticks = iter([0, 100_000, 10_000, 60_000, 120_000, 150_000])
        rec = TraceRecorder(clock_ns=lambda: next(ticks))
        # backward 0..100us; comm group0 10..60us (inside), group1
        # 120..150us (after backward ends)
        rec._mark("bwd_backward", "B", 0, 0)
        rec._mark("bwd_backward", "E", 0, 0)
        rec._mark("wfbp_group0_l2_2", "B", 64, 0)
        rec._mark("wfbp_group0_l2_2", "E", 64, 0)
        rec._mark("wfbp_group1_l1_1", "B", 32, 0)
        rec._mark("wfbp_group1_l1_1", "E", 32, 0)
        spans = rec.spans()
        assert len(spans) == 3 and len(rec) == 6
        rep = overlap_report(spans)
        assert rep["total_comm_us"] == pytest.approx(80.0)
        assert rep["windowed_comm_us"] == pytest.approx(50.0)
        assert rep["hidden_comm_us"] == pytest.approx(50.0)
        assert rep["overlap_fraction"] == pytest.approx(50.0 / 80.0)
        assert rep["n_overlapped_starts"] == 1
        g0, g1 = rep["groups"]
        assert g0["starts_before_bwd_end"] and not g1["starts_before_bwd_end"]
        assert g0["bytes"] == 64 and g1["bytes"] == 32
        # chrome-trace round trip (plain + gzip) preserves the spans
        for name in ("t.json", "t.json.gz"):
            p = tmp_path / name
            rec.save(p)
            assert parse_trace_spans(p) == spans

    def test_clear_resets(self):
        ticks = iter(range(0, 10_000_000, 1_000))
        rec = TraceRecorder(clock_ns=lambda: next(ticks))
        rec._mark("wfbp_group0_l1_1", "B", 8, 0)
        rec._mark("wfbp_group0_l1_1", "E", 8, 0)
        assert len(rec.spans()) == 1
        rec.clear()
        assert len(rec) == 0 and rec.spans() == []
