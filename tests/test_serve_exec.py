"""Serve execution: the ServePlan driven inside ``ServingEngine.step`` —
sharded-vs-unsharded decode numerics, the engine-step lowering invariant
(one fused collective per scheduled serve group), measured serve fabrics
(op-specific fits round-tripping through ``MeasuredFabric``), and the
reviewable ``ServePlan.describe()`` output."""

import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _env import REPO_ROOT, SUBPROC_ENV

from repro.compat import make_mesh
from repro.configs import get_reduced
from repro.core.comm_model import AllReduceModel, fit_affine
from repro.fabric import MeasuredFabric
from repro.launch.specs import param_specs
from repro.models.transformer import init_caches, init_params
from repro.planning import (
    build_serve_plan,
    measure_serve_comm,
    serve_fabric_fits,
)
from repro.serving import (
    ServeTimer,
    serving_cache_pspecs,
    serving_param_pspecs,
    stack_fresh_rows,
    write_fresh_rows,
)


def _reduced_cfg(arch="tinyllama-1.1b"):
    return dataclasses.replace(get_reduced(arch), param_dtype=jnp.float32)


class TestFreshRows:
    def test_stack_write_round_trip(self):
        """write(stack(caches)) is the identity: the wire payload covers
        exactly the rows it is spliced back into."""
        cfg = _reduced_cfg()
        caches = init_caches(cfg, batch=2, max_seq=16, dtype=jnp.float32)
        # make the cache contents distinctive
        caches = jax.tree.map(
            lambda x: x + jnp.arange(x.size, dtype=x.dtype).reshape(x.shape)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            caches,
        )
        pos = jnp.asarray(3, jnp.int32)
        stacked = stack_fresh_rows(cfg, caches, pos)
        att = cfg.attention
        assert stacked.shape == (cfg.n_stages,
                                 2 * 2 * att.n_kv_heads * att.head_dim)
        rt = write_fresh_rows(cfg, caches, stacked, pos)
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(rt)):
            assert jnp.array_equal(a, b)

    def test_recurrent_arch_has_no_payload(self):
        cfg = _reduced_cfg("rwkv6-7b")
        caches = init_caches(cfg, batch=2, max_seq=16, dtype=jnp.float32)
        assert stack_fresh_rows(cfg, caches, jnp.asarray(0, jnp.int32)) is None


class TestServeTimer:
    def test_skip_then_median(self):
        t = ServeTimer(skip_first=2)
        for dt in (9.0, 9.0, 1.0, 2.0, 3.0):
            t.observe(dt)
        assert len(t) == 3
        assert t.median() == 2.0
        assert t.group_times == ()
        t.group_times = (1e-4, 2e-4)
        assert t.group_times == (1e-4, 2e-4)


class TestDescribe:
    def test_describe_includes_group_times_and_bytes(self):
        """Satellite fix: --plan-out artifacts are reviewable without
        loading JSON — per-group predicted time + wire bytes."""
        cfg = _reduced_cfg()
        plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 4}, batch_rows=2, policy="wfbp")
        text = plan.describe()
        lines = text.splitlines()
        assert len(lines) == 1 + len(plan.schedule.groups)
        for g, line in zip(plan.group_summaries(), lines[1:]):
            lo, hi = g["stages"]
            assert f"group[{lo}..{hi}]" in line
            assert f"wire={g['nbytes']}B" in line
            assert "t_pred=" in line
        # summaries price each group at the plan's affine model
        for g in plan.group_summaries():
            assert g["t_pred_s"] == pytest.approx(plan.model(g["nbytes"]))


class TestMeasuredServeFabric:
    def test_fit_round_trip_through_measured_fabric(self):
        """Acceptance: an 'all_gather@model' override recovered from
        synthetic timings prices the plan with the injected constants."""
        true = AllReduceModel(a=3e-5, b=2e-9)
        sizes = tuple(4096 * 8**i for i in range(5))
        fit = fit_affine(sizes, tuple(true(s) for s in sizes),
                         name="all_gather@model")
        fab = MeasuredFabric(models={"all_gather@model": fit},
                             name="measured_serve")
        got = fab.cost("all_gather", {"model": 8})
        assert got.a == pytest.approx(true.a, rel=1e-6)
        assert got.b == pytest.approx(true.b, rel=1e-6)
        cfg = _reduced_cfg()
        plan = build_serve_plan(cfg, param_specs(cfg), fab, {"model": 8},
                                batch_rows=2)
        assert plan.fabric == "measured_serve"
        assert plan.model.a == pytest.approx(true.a, rel=1e-6)
        assert plan.model.b == pytest.approx(true.b, rel=1e-6)

    def test_with_fits_overrides(self):
        base = MeasuredFabric(models={"model": AllReduceModel(a=1e-5, b=1e-9)})
        override = AllReduceModel(a=9e-6, b=3e-10)
        fab = base.with_fits({"all_gather@model": override})
        assert fab.cost("all_gather", {"model": 8}).a == override.a
        # base untouched (frozen dataclass semantics)
        assert "all_gather@model" not in base.models

    def test_measure_serve_comm_runs_on_trivial_mesh(self):
        """The timing path itself needs no virtual devices: a 1-wide
        model axis still times the jitted collective."""
        mesh = make_mesh((1,), ("model",))
        mc = measure_serve_comm(mesh, "all_gather", ("model",),
                                sizes_bytes=(4096, 65536), repeats=1)
        assert mc.sizes_bytes == (4096, 65536)
        assert all(t > 0 and np.isfinite(t) for t in mc.times_s)
        fit = mc.fit()
        assert np.isfinite(fit.a) and np.isfinite(fit.b)
        fits = serve_fabric_fits(mesh, ops=("all_gather",),
                                 sizes_bytes=(4096, 65536), repeats=1)
        assert set(fits) == {"all_gather@model"}

    def test_measure_serve_comm_rejects_multi_axis(self):
        mesh = make_mesh((1,), ("model",))
        with pytest.raises(ValueError, match="one axis"):
            measure_serve_comm(mesh, "all_gather", ("model", "data"))


class TestAtRestLayout:
    def test_param_pspecs_follow_megatron_dims(self):
        cfg = _reduced_cfg()
        specs = serving_param_pspecs(param_specs(cfg))
        stages = specs["stages"]["attn_0"]
        # stacked stage leaves: (n_stages, in, out)
        assert tuple(stages["attn"]["wq"]) == (None, None, "model")
        assert tuple(stages["attn"]["wo"]) == (None, "model", None)
        assert tuple(stages["mlp"]["w_gate"]) == (None, None, "model")
        assert tuple(stages["mlp"]["w_down"]) == (None, "model", None)
        assert tuple(specs["embed"]) == ()
        assert tuple(specs["final_norm"]["scale"]) == ()

    def test_cache_pspecs_shard_head_dim(self):
        cfg = _reduced_cfg()
        caches = init_caches(cfg, batch=2, max_seq=16, dtype=jnp.float32)
        specs = serving_cache_pspecs(cfg, caches)
        k_spec, v_spec, kpos_spec = specs["stages"]["attn_0"]
        assert tuple(k_spec) == (None, None, None, None, "model")
        assert tuple(v_spec) == (None, None, None, None, "model")
        assert tuple(kpos_spec) == ()


SHARDED_EXEC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np

    from repro.compat import make_mesh
    from repro.configs import get_reduced
    from repro.core.profiler import parse_collectives
    from repro.launch.specs import param_specs
    from repro.models.transformer import init_caches, init_params
    from repro.planning import build_serve_plan
    from repro.serving import Request, ServingEngine, shard_serving_state

    mesh = make_mesh((4,), ("model",))
    out = {"cells": []}

    cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"),
                              param_dtype=jnp.float32)
    shapes = param_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def run(mesh_arg, policy, fabric):
        plan = build_serve_plan(cfg, shapes, fabric, {"model": 4},
                                batch_rows=2, policy=policy)
        eng = ServingEngine(cfg, params, slots=2, max_seq=20, plan=plan,
                            mesh=mesh_arg)
        rng = np.random.default_rng(0)
        for rid in range(3):  # 3 requests on 2 slots: slot reuse rides along
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=8, dtype=np.int32),
                max_new_tokens=6,
            ))
        done = eng.run_to_completion()
        return {r.rid: r.generated for r in done}, eng, plan

    base, _, _ = run(None, "mg_wfbp", "gpu_nccl")
    # the fabrics/policies pick different merge sets; every one must pin
    # exactly one fused collective per group INSIDE the engine's one
    # jitted step, donate its DecodeState buffers, decode token-for-token
    # identically to the unsharded engine, and never retrace the decode
    # executable across joins, leaves, and slot reuse
    # donation shows as tf.aliasing_output (single-device) or
    # jax.buffer_donor (sharded args) in the lowered StableHLO
    def donated(text):
        return "tf.aliasing_output" in text or "jax.buffer_donor" in text

    for policy, fabric in (("mg_wfbp", "gpu_nccl"), ("wfbp", "gpu_nccl"),
                           ("synceasgd", "tpu_v5e")):
        toks, eng, plan = run(mesh, policy, fabric)
        text = eng._step_fn.lower(eng.params, eng._state).as_text()
        stats = parse_collectives(text)
        out["cells"].append({
            "policy": policy, "fabric": fabric, "op": plan.op,
            "n_groups": len(plan.schedule.groups),
            "gather_ops": stats.counts.get("all-gather", 0),
            "total_collectives": stats.total_ops,
            "tokens_match": toks == base,
            "donated": donated(text),
            "decode_execs": eng.compile_stats()["decode"],
        })

    # MoE: the plan schedules the expert all-to-all; same invariant
    moe_cfg = dataclasses.replace(get_reduced("mixtral-8x7b"),
                                  param_dtype=jnp.float32)
    moe_params = init_params(jax.random.PRNGKey(0), moe_cfg)
    moe_plan = build_serve_plan(moe_cfg, param_specs(moe_cfg), "tpu_v5e",
                                {"model": 4}, batch_rows=2, policy="wfbp")
    eng = ServingEngine(moe_cfg, moe_params, slots=2, max_seq=16,
                        plan=moe_plan, mesh=mesh)
    text = eng._step_fn.lower(eng.params, eng._state).as_text()
    stats = parse_collectives(text)
    out["moe"] = {
        "op": moe_plan.op,
        "n_groups": len(moe_plan.schedule.groups),
        "a2a_ops": stats.counts.get("all-to-all", 0),
        "total_collectives": stats.total_ops,
        "donated": donated(text),
    }

    # at-rest layout: sharded leaves really live in 1/N-size shards
    sp, sc = shard_serving_state(
        params, init_caches(cfg, batch=2, max_seq=20, dtype=jnp.float32),
        cfg, mesh,
    )
    wq = sp["stages"]["attn_0"]["attn"]["wq"]
    shard = wq.sharding.shard_shape(wq.shape)
    out["wq_shard_fraction"] = (np.prod(shard) / np.prod(wq.shape)).item()
    print(json.dumps(out))
""")


def test_engine_step_lowers_one_collective_per_group():
    """Acceptance: the engine's ONE jitted step on a virtual TP mesh
    lowers to exactly one fused collective per ServePlan group, donates
    its ``DecodeState`` buffers (``tf.aliasing_output``/``jax.buffer_donor``
    in the lowered text — the cache arena is updated in place), compiles
    exactly one
    decode executable across joins/leaves/slot reuse, and the sharded
    engine decodes token-for-token what the unsharded engine decodes."""
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_EXEC_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env=SUBPROC_ENV, cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    by = {(c["policy"], c["fabric"]): c for c in rec["cells"]}
    # different merge sets from the same cost vector across the cells
    assert by[("wfbp", "gpu_nccl")]["n_groups"] > by[("mg_wfbp", "gpu_nccl")]["n_groups"]
    for c in rec["cells"]:
        assert c["op"] == "all_gather", c
        assert c["gather_ops"] == c["n_groups"], c
        assert c["total_collectives"] == c["n_groups"], c  # nothing extra
        assert c["tokens_match"], c
        assert c["donated"], c  # the DecodeState buffers alias outputs
        assert c["decode_execs"] == 1, c  # zero steady-state retraces
    moe = rec["moe"]
    assert moe["op"] == "all_to_all"
    assert moe["a2a_ops"] == moe["n_groups"]
    assert moe["total_collectives"] == moe["n_groups"]
    assert moe["donated"]
    # at-rest Megatron layout really shards the projection weights
    assert rec["wq_shard_fraction"] == pytest.approx(0.25)


class TestStepFixedModel:
    """The honest compute+dispatch cost model: ``t_step_fixed`` rides the
    plan, survives JSON, and folds into ``predicted_step_time``."""

    def _plan(self):
        cfg = _reduced_cfg()
        return build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 4}, batch_rows=2)

    def test_with_step_fixed_and_prediction(self):
        plan = self._plan()
        assert plan.t_step_fixed == 0.0
        assert plan.predicted_step_time() == plan.schedule.result.t_iter
        cal = plan.with_step_fixed(1.5e-3)
        assert cal.t_step_fixed == 1.5e-3
        assert cal.predicted_step_time() == pytest.approx(
            plan.schedule.result.t_iter + 1.5e-3)
        assert cal.provenance["t_step_fixed_source"] == "probe"
        # the original plan is untouched (frozen-value semantics)
        assert plan.t_step_fixed == 0.0

    def test_json_round_trip_and_legacy_load(self):
        from repro.planning import ServePlan

        cal = self._plan().with_step_fixed(2e-4)
        rt = ServePlan.from_json_dict(json.loads(cal.to_json()))
        assert rt.t_step_fixed == pytest.approx(2e-4)
        assert rt.predicted_step_time() == pytest.approx(cal.predicted_step_time())
        # artifacts written before the fixed-term model load as 0.0
        d = json.loads(self._plan().to_json())
        d.pop("t_step_fixed")
        legacy = ServePlan.from_json_dict(d)
        assert legacy.t_step_fixed == 0.0

    def test_describe_and_group_summaries_carry_fixed(self):
        from repro.planning import group_comparison_lines

        cal = self._plan().with_step_fixed(1e-3)
        assert "step=fixed" in cal.describe()
        for g in cal.group_summaries():
            assert g["t_fixed_s"] == pytest.approx(1e-3)
        lines = group_comparison_lines(
            cal, tuple(0.0 for _ in cal.schedule.groups))
        assert lines[0].startswith("step: fixed=")
        assert len(lines) == 1 + len(cal.schedule.groups)
        # an uncalibrated plan keeps the legacy table shape
        plain = group_comparison_lines(
            self._plan(), tuple(0.0 for _ in self._plan().schedule.groups))
        assert len(plain) == len(self._plan().schedule.groups)


class TestServePlanCapacityModel:
    """Direct contracts for ``predicted_completion_s`` /
    ``capacity_tok_per_s`` — the terms fleet admission and the what-if
    simulator price ETAs and scale decisions with."""

    def _plan(self):
        cfg = _reduced_cfg()
        return build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 4}, batch_rows=2)

    def test_completion_scales_linearly_in_tokens(self):
        plan = self._plan()
        step = plan.predicted_step_time()
        assert plan.predicted_completion_s(1) == pytest.approx(step)
        assert plan.predicted_completion_s(17) == pytest.approx(17 * step)

    def test_completion_zero_and_negative_tokens_clamp_to_zero(self):
        plan = self._plan()
        assert plan.predicted_completion_s(0) == 0.0
        assert plan.predicted_completion_s(-5) == 0.0

    def test_capacity_is_rows_per_step(self):
        plan = self._plan()
        step = plan.predicted_step_time()
        assert plan.capacity_tok_per_s(1) == pytest.approx(1.0 / step)
        assert plan.capacity_tok_per_s(8) == pytest.approx(8.0 / step)

    def test_capacity_zero_rows_is_zero_not_none(self):
        """An idle replica has zero capacity — a priced answer, not a
        missing one (None is reserved for un-evaluated schedules)."""
        plan = self._plan()
        assert plan.capacity_tok_per_s(0) == 0.0

    def test_unevaluated_schedule_prices_nothing(self):
        """Gate-empty plan: no evaluated timeline => both terms are None
        (admission must refuse to price, not price garbage)."""
        plan = self._plan()
        gutted = dataclasses.replace(
            plan, schedule=dataclasses.replace(plan.schedule, result=None))
        assert gutted.predicted_step_time() is None
        assert gutted.predicted_completion_s(4) is None
        assert gutted.capacity_tok_per_s(4) is None

    def test_step_fixed_feeds_both_terms(self):
        """The calibrated fixed term moves completion and capacity
        together — they stay mutually consistent views of one step."""
        cal = self._plan().with_step_fixed(1e-2)
        step = cal.predicted_step_time()
        assert cal.predicted_completion_s(3) == pytest.approx(3 * step)
        assert cal.capacity_tok_per_s(5) == pytest.approx(5.0 / step)
