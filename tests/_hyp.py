"""Optional-hypothesis shim.

``hypothesis`` is an *optional* dev dependency (see pyproject.toml
``[project.optional-dependencies] test``).  When it is installed, this
module re-exports the real ``given`` / ``settings`` / ``st``; when it is
not, property tests decay to ``pytest.mark.skip`` instead of breaking
collection of the whole module (the non-property tests keep running).
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Attribute sink: st.integers(...)/st.floats(...) etc. are only
        evaluated at decoration time and their results never used when the
        test is skipped."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
