"""Docs-check: the public API surface stays documented.

Imports the package's public modules and fails on any exported name
(``__all__``) whose class/function docstring is empty — the CI
``docs-check`` step runs exactly this file, so a PR that adds an
undocumented export fails before review.  Constants (tuples, frozen
preset instances) are exempt: they carry their type's docstring.
"""

import importlib
import inspect

import pytest

#: Modules whose ``__all__`` is the public API surface (README/docs
#: entry points: the planning subsystem, the Fabric API, serving, and
#: the training-side sync).
PUBLIC_MODULES = (
    "repro.planning",
    "repro.fabric",
    "repro.serving",
    "repro.core.sync",
)

#: Modules that must carry a module-level docstring (the docs/ tree
#: links into these as subsystem entry points).
DOCUMENTED_MODULES = PUBLIC_MODULES + (
    "repro",
    "repro.compat",
    "repro.planning.serve",
    "repro.planning.tuner",
    "repro.fabric.measured",
    "repro.serving.engine",
    "repro.serving.sharded",
    "repro.core.sync",
    "repro.core.bucketing",
)


@pytest.mark.parametrize("modname", PUBLIC_MODULES)
def test_every_export_has_a_docstring(modname):
    mod = importlib.import_module(modname)
    assert hasattr(mod, "__all__"), f"{modname} must declare __all__"
    missing = []
    for name in mod.__all__:
        obj = getattr(mod, name)
        if not (inspect.isclass(obj) or inspect.isroutine(obj)):
            continue  # constants/preset instances document via their type
        doc = inspect.getdoc(obj)
        if not doc or not doc.strip():
            missing.append(name)
    assert not missing, (
        f"{modname} exports without docstrings: {missing} — every public "
        f"name needs a one-line summary (see docs/architecture.md)"
    )


@pytest.mark.parametrize("modname", DOCUMENTED_MODULES)
def test_module_docstring(modname):
    mod = importlib.import_module(modname)
    assert mod.__doc__ and mod.__doc__.strip(), f"{modname} needs a module docstring"
