"""Property-based timeline/schedule suite for the issue-order modes.

Each property runs twice: a seeded, always-on sweep (pure stdlib) and a
``hypothesis`` ``@given`` variant through the ``tests/_hyp`` shim that
explores the same space adversarially when the optional dependency is
installed (and decays to a skip when it is not).

Properties:

  * overlapped ``evaluate`` never prices a schedule slower than the
    serialized issue order (hiding comm can only help);
  * ``t_iter`` is monotone in the (α, β) wire constants, both modes;
  * ``dp_optimal_schedule`` matches brute-force enumeration of ALL
    contiguous partitions, both modes (exact Bellman recursion);
  * the DES replay (``sim.replay.simulate_train_iteration``) with
    homogeneous multipliers reproduces ``core.timeline.evaluate``
    bit-identically — same floats, same traces — both modes.
"""

from __future__ import annotations

import random

import pytest

from _hyp import given, settings, st

from repro.core.comm_model import AllReduceModel
from repro.core.cost_model import LayerCost, TPU_V5E
from repro.core.schedule import dp_optimal_schedule
from repro.core.timeline import MODES, comm_avail_times, evaluate
from repro.sim.replay import simulate_train_iteration

SEEDS = range(25)


def _mk_costs(rng: random.Random, L: int) -> list[LayerCost]:
    return [
        LayerCost(
            name=f"l{i}",
            params=0,
            grad_bytes=rng.randrange(1, 1 << 22),
            bwd_flops=rng.uniform(1e9, 5e11),
            fwd_flops=rng.uniform(1e9, 5e11),
        )
        for i in range(L)
    ]


def _mk_groups(rng: random.Random, L: int) -> list[tuple[int, int]]:
    cuts = sorted(rng.sample(range(1, L), k=rng.randrange(0, L))) if L > 1 else []
    bounds = [0, *cuts, L]
    return [(bounds[i] + 1, bounds[i + 1]) for i in range(len(bounds) - 1)]


def _all_partitions(L: int):
    for mask in range(1 << (L - 1)):
        bounds = [0] + [i + 1 for i in range(L - 1) if mask >> i & 1] + [L]
        yield [(bounds[i] + 1, bounds[i + 1]) for i in range(len(bounds) - 1)]


def _mk_model(rng: random.Random) -> AllReduceModel:
    return AllReduceModel(a=rng.uniform(0.0, 5e-3), b=rng.uniform(1e-11, 5e-9))


# -- property bodies (shared by the seeded and hypothesis variants) ---------


def check_overlap_le_serialized(seed: int) -> None:
    rng = random.Random(seed)
    L = rng.randrange(1, 12)
    costs = _mk_costs(rng, L)
    groups = _mk_groups(rng, L)
    ar = _mk_model(rng)
    over = evaluate(groups, costs, ar, TPU_V5E, mode="overlap")
    ser = evaluate(groups, costs, ar, TPU_V5E, mode="serialized")
    assert over.t_iter <= ser.t_iter + 1e-12, (groups, over.t_iter, ser.t_iter)
    assert over.t_comm_exposed <= ser.t_comm_exposed + 1e-12
    # serialized pins every group's availability to the end of backward
    assert all(g.avail == ser.groups[0].avail for g in ser.groups)


def check_monotone_in_alpha_beta(seed: int) -> None:
    rng = random.Random(seed)
    L = rng.randrange(1, 10)
    costs = _mk_costs(rng, L)
    groups = _mk_groups(rng, L)
    a = sorted(rng.uniform(0.0, 5e-3) for _ in range(2))
    b = sorted(rng.uniform(1e-11, 5e-9) for _ in range(2))
    for mode in MODES:
        lo = evaluate(groups, costs, AllReduceModel(a=a[0], b=b[0]), TPU_V5E, mode=mode)
        hi = evaluate(groups, costs, AllReduceModel(a=a[1], b=b[1]), TPU_V5E, mode=mode)
        assert lo.t_iter <= hi.t_iter + 1e-12, (mode, a, b)
        assert lo.t_comm_total <= hi.t_comm_total + 1e-12


def check_dp_optimal_vs_exhaustive(seed: int) -> None:
    rng = random.Random(seed)
    L = rng.randrange(1, 8)
    costs = _mk_costs(rng, L)
    ar = _mk_model(rng)
    for mode in MODES:
        dp = dp_optimal_schedule(costs, ar, TPU_V5E, mode=mode)
        best = min(
            evaluate(groups, costs, ar, TPU_V5E, mode=mode).t_iter
            for groups in _all_partitions(L)
        )
        assert dp.result.t_iter <= best + 1e-12, (mode, dp.groups, dp.result.t_iter, best)


def check_des_replay_bit_identical(seed: int) -> None:
    rng = random.Random(seed)
    L = rng.randrange(1, 10)
    costs = _mk_costs(rng, L)
    groups = _mk_groups(rng, L)
    ar = _mk_model(rng)
    for mode in MODES:
        want = evaluate(groups, costs, ar, TPU_V5E, mode=mode)
        for n_hosts in (1, 4):
            got = simulate_train_iteration(
                groups, costs, ar, TPU_V5E, multipliers=(1.0,) * n_hosts, mode=mode
            )
            # bit-identical, not approx: the DES must *be* the model
            assert got.t_iter == want.t_iter, (mode, n_hosts)
            assert got.t_f == want.t_f and got.t_b == want.t_b
            assert got.t_comm_total == want.t_comm_total
            assert got.groups == want.groups, (mode, n_hosts)


# -- seeded always-run sweeps ----------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_overlap_le_serialized(seed):
    check_overlap_le_serialized(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_monotone_in_alpha_beta(seed):
    check_monotone_in_alpha_beta(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_dp_optimal_vs_exhaustive(seed):
    check_dp_optimal_vs_exhaustive(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_des_replay_bit_identical(seed):
    check_des_replay_bit_identical(seed)


# -- hypothesis variants (skip when the extra is absent) --------------------


class TestHypothesis:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=50, deadline=None)
    def test_overlap_le_serialized(self, seed):
        check_overlap_le_serialized(seed)

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=50, deadline=None)
    def test_monotone_in_alpha_beta(self, seed):
        check_monotone_in_alpha_beta(seed)

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_dp_optimal_vs_exhaustive(self, seed):
        check_dp_optimal_vs_exhaustive(seed)

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_des_replay_bit_identical(self, seed):
        check_des_replay_bit_identical(seed)


# -- mode plumbing ----------------------------------------------------------


def test_unknown_mode_rejected():
    costs = _mk_costs(random.Random(0), 3)
    with pytest.raises(ValueError, match="unknown issue-order mode"):
        comm_avail_times(costs, TPU_V5E, 1.0, mode="eager")
    with pytest.raises(ValueError, match="unknown issue-order mode"):
        evaluate([(1, 3)], costs, AllReduceModel(a=1e-4, b=1e-9), TPU_V5E, mode="nope")


def test_serialized_merges_everything_under_dp():
    """Equal availability makes one merged group dominate whenever α > 0
    (Eq. 10: merging strictly saves α per merge)."""
    rng = random.Random(7)
    costs = _mk_costs(rng, 6)
    dp = dp_optimal_schedule(costs, AllReduceModel(a=1e-3, b=1e-9), mode="serialized")
    assert dp.groups == ((1, 6),)
