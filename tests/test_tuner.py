"""Closed-loop auto-tuner: registry-wide sweep determinism and argmin
guarantee, comm-drift re-fitting (threshold-exact firing, α×10 injection),
tuner-state checkpoint round-trip, predicted-vs-observed provenance,
per-unit probe non-uniformity, and the bf16_ef residual threading through
the train step + checkpoints."""

import json
import subprocess
import sys

import pytest
from _env import REPO_ROOT, SUBPROC_ENV

from repro.core import AllReduceModel, Hardware, layout_for_stacked_lm
from repro.planning import (
    DEFAULT_COMM_SWEEP,
    MEASURED_HW,
    SLIM_COMM_SWEEP,
    CommRefitter,
    MeasuredComm,
    MeasuredCosts,
    SweepRecord,
    Tuner,
    available_policies,
    build_plan,
    comm_drift,
    default_policies,
    replan_if_comm_drifted,
)

HW = Hardware(name="unit", peak_flops=1.0, hbm_bw=1.0, mxu_eff=1.0, hbm_eff=1.0)


def small_setup(n_layers=6, seed_skew=False):
    layout = layout_for_stacked_lm(
        n_layers, embed_params=5_000_000, layer_params=1_000_000,
        head_params=7_000_000,
    )
    costs = layout.layer_costs(tokens_per_chip=64, hw=HW)
    if seed_skew:
        costs = MeasuredCosts.from_unit_times(
            costs, [0.01 * (i + 1) for i in range(len(costs))], name="skew"
        ).layer_costs()
    ar = AllReduceModel(a=1e-3, b=1e-9)
    return layout, costs, ar


class TestSweep:
    def test_deterministic(self):
        """Same layout × costs × model -> byte-identical chosen plan and
        candidate table, across independent Tuner instances."""
        layout, costs, ar = small_setup(seed_skew=True)
        t1 = Tuner(layout=layout, n_scan_stages=6)
        t2 = Tuner(layout=layout, n_scan_stages=6)
        p1 = t1.sweep(costs, ar, MEASURED_HW, cost_source="skew")
        p2 = t2.sweep(costs, ar, MEASURED_HW, cost_source="skew")
        assert p1.to_json() == p2.to_json()
        assert t1.last_record.to_json_dict() == t2.last_record.to_json_dict()
        # and policy iteration order is the sorted registry, not dict order
        assert list(t1.policies) == sorted(t1.policies)

    def test_argmin_and_per_tensor_bound(self):
        """Acceptance: chosen plan's predicted t_iter ≤ EVERY candidate's,
        in particular ≤ the per_tensor (wfbp) baseline's."""
        layout, costs, ar = small_setup()
        tuner = Tuner(layout=layout, n_scan_stages=6)
        plan = tuner.sweep(costs, ar, MEASURED_HW)
        rec = tuner.last_record
        by_policy = {c.policy: c for c in rec.candidates}
        assert "wfbp" in by_policy  # per_tensor alias target swept
        for c in rec.candidates:
            assert rec.predicted_t_iter <= c.predicted_t_iter + 1e-12, c
        assert rec.predicted_t_iter <= by_policy["wfbp"].predicted_t_iter
        assert plan.schedule.result.t_iter == pytest.approx(rec.predicted_t_iter)

    def test_sweeps_whole_registry(self):
        layout, costs, ar = small_setup()
        tuner = Tuner(layout=layout, n_scan_stages=6)
        tuner.sweep(costs, ar, MEASURED_HW)
        swept = {c.policy for c in tuner.last_record.candidates}
        # 8 units: small enough that even exhaustive 'optimal' is included
        assert swept == set(available_policies())

    def test_exhaustive_dropped_for_large_layouts(self):
        assert "optimal" not in default_policies(40)
        assert "optimal" in default_policies(8)

    def test_arena_bytes_scored_when_shapes_given(self):
        import jax.numpy as jnp

        n_stages = 4
        shapes = {
            "embed": {"tok": jnp.zeros((64, 32))},
            "stages": {"w": jnp.zeros((n_stages, 32, 32))},
            "final_norm": {"scale": jnp.zeros((32,))},
            "head": {"w": jnp.zeros((32, 65))},
        }
        from repro.core.bucketing import stacked_lm_layout

        layout = stacked_lm_layout(shapes, n_stages)
        costs = layout.layer_costs(1 << 20, None)
        tuner = Tuner(layout=layout, n_scan_stages=n_stages, shapes=shapes)
        tuner.sweep(costs, AllReduceModel(a=5e-5, b=1e-9), MEASURED_HW)
        total_elems = 64 * 32 + n_stages * 32 * 32 + 32 + 32 * 65
        for c in tuner.last_record.candidates:
            # exact packing: arena bytes == payload bytes on every candidate
            assert c.arena_bytes == total_elems * 4, c

    def test_provenance_records_search(self):
        layout, costs, ar = small_setup()
        tuner = Tuner(layout=layout, n_scan_stages=6)
        plan = tuner.sweep(
            costs, ar, MEASURED_HW, cost_source="probe_segments",
            comm_source="measured", trigger="startup",
        )
        assert plan.provenance["tuner"] == "startup"
        assert plan.provenance["cost_source"] == "probe_segments"
        assert plan.provenance["comm_source"] == "measured"
        assert float(plan.provenance["predicted_t_iter"]) == pytest.approx(
            tuner.last_record.predicted_t_iter
        )
        assert int(plan.provenance["candidates"]) == len(tuner.last_record.candidates)

    def test_observed_vs_predicted(self):
        layout, costs, ar = small_setup()
        tuner = Tuner(layout=layout, n_scan_stages=6)
        with pytest.raises(ValueError, match="before any sweep"):
            tuner.observe(1.0)
        tuner.sweep(costs, ar, MEASURED_HW)
        rec = tuner.observe(0.042)
        assert rec.observed_t_iter == pytest.approx(0.042)
        assert rec.predicted_t_iter > 0
        # the pair survives serialization
        clone = SweepRecord.from_json_dict(rec.to_json_dict())
        assert clone.observed_t_iter == rec.observed_t_iter


class TestTunerStateCheckpoint:
    def test_round_trip_through_checkpoint(self, tmp_path):
        import numpy as np

        from repro.checkpoint import load_tuner_state, save

        layout, costs, ar = small_setup()
        tuner = Tuner(layout=layout, n_scan_stages=6)
        tuner.sweep(costs, ar, MEASURED_HW, trigger="startup")
        tuner.observe(0.5)
        tuner.sweep(costs, AllReduceModel(a=1e-2, b=1e-9), MEASURED_HW,
                    trigger="comm_drift")

        save(tmp_path, 7, {"x": np.zeros(3)}, tuner=tuner)
        state = load_tuner_state(tmp_path, 7)
        assert state is not None
        restored = Tuner(layout=layout, n_scan_stages=6).load_state(state)
        assert len(restored.history) == 2
        assert [r.trigger for r in restored.history] == ["startup", "comm_drift"]
        assert restored.history[0].observed_t_iter == pytest.approx(0.5)
        assert (
            restored.history[0].to_json_dict() == tuner.history[0].to_json_dict()
        )

    def test_absent_for_untuned_checkpoints(self, tmp_path):
        import numpy as np

        from repro.checkpoint import load_tuner_state, save

        save(tmp_path, 3, {"x": np.zeros(2)})
        assert load_tuner_state(tmp_path, 3) is None

    def test_bad_format_rejected(self):
        layout, _, _ = small_setup()
        with pytest.raises(ValueError, match="tuner state format"):
            Tuner(layout=layout).load_state({"format": 99, "history": []})


class TestCommDrift:
    def test_drift_metric(self):
        a = AllReduceModel(a=1e-3, b=1e-9)
        assert comm_drift(a, a) == 0.0
        assert comm_drift(a, AllReduceModel(a=1e-2, b=1e-9)) == pytest.approx(9.0)
        assert comm_drift(a, AllReduceModel(a=1e-3, b=2e-9)) == pytest.approx(1.0)

    def test_replan_fires_exactly_at_threshold(self):
        """Below/at the (α, β) delta threshold nothing happens; past it the
        policy reruns under the fresh model."""
        layout, costs, ar = small_setup()
        plan = build_plan(layout, costs, ar, policy="mg_wfbp", hw=MEASURED_HW,
                          n_scan_stages=6)
        # drift exactly == threshold: keeps the plan (strict inequality)
        at = AllReduceModel(a=ar.a * 1.25, b=ar.b)
        same, replanned = replan_if_comm_drifted(plan, at, threshold=0.25)
        assert not replanned and same is plan
        # just past it: re-plans
        past = AllReduceModel(a=ar.a * 1.2501, b=ar.b)
        new_plan, replanned = replan_if_comm_drifted(plan, past, threshold=0.25)
        assert replanned
        assert new_plan.ar_model == past
        assert new_plan.provenance["replanned_from_comm"] == ar.name
        assert float(new_plan.provenance["comm_drift"]) == pytest.approx(
            0.2501, rel=1e-3
        )
        # costs and layout are untouched — only the wire model moved
        assert new_plan.costs == plan.costs

    def test_alpha_x10_schedule_actually_changes(self):
        """α×10 congestion makes merging strictly more attractive: the
        re-planned schedule has fewer groups."""
        layout, costs, ar = small_setup(seed_skew=True)
        plan = build_plan(layout, costs, ar, policy="mg_wfbp", hw=MEASURED_HW,
                          n_scan_stages=6)
        congested = AllReduceModel(a=ar.a * 10, b=ar.b, name="congested")
        new_plan, replanned = replan_if_comm_drifted(plan, congested, threshold=0.5)
        assert replanned
        assert len(new_plan.schedule.groups) <= len(plan.schedule.groups)

    def test_measured_comm_ewma_update(self):
        base = MeasuredComm(sizes_bytes=(100, 200), times_s=(1.0, 2.0))
        up = base.update([200, 400], [4.0, 8.0], weight=0.5)
        assert up.sizes_bytes == (100, 200, 400)
        assert up.times_s == (1.0, 3.0, 8.0)  # 200: (2+4)/2; 400: fresh
        with pytest.raises(ValueError, match="EWMA weight"):
            base.update([100], [1.0], weight=0.0)

    def test_refitter_alpha_x10_fires_within_one_check(self):
        """Acceptance: an injected α×10 perturbation triggers a re-fit on
        the FIRST slim-sweep check after the event — i.e. within
        --comm-refit-every steps of the congestion starting."""
        model = AllReduceModel(a=5e-5, b=1e-9)
        base = MeasuredComm(
            sizes_bytes=DEFAULT_COMM_SWEEP,
            times_s=tuple(model(s) for s in DEFAULT_COMM_SWEEP),
        )
        ref = CommRefitter(base=base, threshold=0.5, weight=0.5)
        # healthy probes: no drift, no refit
        _, drift, drifted = ref.check(lambda n: model(n))
        assert not drifted and drift < 0.05
        # congestion event: α jumps ×10
        congested = AllReduceModel(a=model.a * 10, b=model.b)
        fit, drift, drifted = ref.check(lambda n: congested(n))
        assert drifted and ref.refits == 1
        assert drift > 0.5
        # the EWMA'd fit moved toward the congested α (≥2x the baseline)
        assert fit.a > 2 * model.a
        # after the refit the reference follows the new regime: steady
        # congestion does not keep re-firing
        _, _, drifted2 = ref.check(lambda n: congested(n))
        assert ref.checks == 3

    def test_refitter_state_round_trip(self, tmp_path):
        model = AllReduceModel(a=5e-5, b=1e-9)
        base = MeasuredComm(
            sizes_bytes=SLIM_COMM_SWEEP,
            times_s=tuple(model(s) for s in SLIM_COMM_SWEEP),
        )
        ref = CommRefitter(base=base, threshold=0.4, weight=0.25)
        ref.check(lambda n: model(n))
        blob = json.dumps(ref.state_dict())
        clone = CommRefitter.from_state_dict(json.loads(blob))
        assert clone.checks == 1 and clone.threshold == 0.4
        assert clone.base.times_s == ref.base.times_s
        assert clone.reference.a == pytest.approx(ref.reference.a)


class TestUnitProbes:
    """Per-unit segment probes: genuinely non-uniform measured drift —
    the thing the whole-step uniform rescale can never produce."""

    @pytest.fixture(scope="class")
    def profile_and_costs(self):
        import dataclasses

        import jax
        import jax.numpy as jnp

        from repro.configs import get_reduced
        from repro.core.bucketing import stacked_lm_layout
        from repro.core.cost_model import TPU_V5E
        from repro.core.trainer import lm_unit_costs
        from repro.launch.specs import param_specs
        from repro.models.transformer import init_params
        from repro.runtime.timeline import probe_unit_times

        cfg = dataclasses.replace(
            get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32
        )
        shapes = param_specs(cfg)
        layout = stacked_lm_layout(shapes, cfg.n_stages)
        analytic = lm_unit_costs(cfg, shapes, tokens_per_device=64)
        params = init_params(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        batch = {
            "targets": jax.random.randint(key, (2, 32), 0, cfg.vocab),
            "tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab),
        }
        profile = probe_unit_times(cfg, params, batch, layout)
        return profile, analytic, layout

    def test_covers_every_unit(self, profile_and_costs):
        profile, _, layout = profile_and_costs
        assert set(profile.unit_seconds) == {u.name for u in layout.units}
        assert all(t > 0 for t in profile.unit_seconds.values())

    def test_nonuniform_across_units(self, profile_and_costs):
        """Acceptance: the measured/analytic ratio differs across units —
        proof the cost vector is NOT a uniform whole-step rescale."""
        profile, analytic, _ = profile_and_costs
        from repro.core.cost_model import TPU_V5E

        ratios = profile.ratios(analytic, TPU_V5E)
        assert len(set(f"{r:.3e}" for r in ratios.values())) > 1
        assert profile.nonuniformity(analytic, TPU_V5E) > 1.05

    def test_feeds_measured_costs(self, profile_and_costs):
        profile, analytic, _ = profile_and_costs
        from repro.core.cost_model import TPU_V5E

        measured = MeasuredCosts.from_segment_times(
            analytic, TPU_V5E, profile.unit_seconds, name="probe_segments"
        )
        for c, base in zip(measured.layer_costs(), analytic):
            assert c.t_b(MEASURED_HW) == pytest.approx(
                profile.unit_seconds[base.name]
            )
            assert c.grad_bytes == base.grad_bytes  # payloads never move


class TestStepTimer:
    def test_skips_compile_steps_and_medians(self):
        from repro.runtime import StepTimer

        t = StepTimer(window=10, skip_first=2)
        assert t.median() is None
        for dt in (9.0, 9.0, 1.0, 2.0, 3.0):  # two compile steps discarded
            t.observe(dt)
        assert len(t) == 3
        assert t.median() == pytest.approx(2.0)
        t.skip(1)
        t.observe(50.0)  # recompile after re-plan: discarded
        assert t.median() == pytest.approx(2.0)
        t.reset()
        assert t.median() is None


EF_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh, set_mesh
from repro.configs import get_reduced
from repro.core.comm_model import AllReduceModel
from repro.core.sync import SyncConfig
from repro.core.trainer import MGWFBPEngine
from repro.launch.specs import param_specs
from repro.models.transformer import init_params
from repro.optim import make_optimizer
from repro.runtime import RunState
from repro.checkpoint import save, restore
import dataclasses, sys, tempfile

cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
n_dev = jax.device_count()
mesh = make_mesh((n_dev, 1), ("data", "model"))
eng = MGWFBPEngine.build(
    cfg, param_specs(cfg), dp_axes=("data",),
    ar_model=AllReduceModel(a=5e-5, b=1e-9), tokens_per_device=64,
    sync_config=SyncConfig(compression="bf16_ef", fuse="arena"),
)
assert eng.stateful
opt = make_optimizer("sgd")
step = eng.make_train_step(opt, mesh, lr=1e-2)
params = init_params(jax.random.PRNGKey(0), cfg)
residual = eng.init_residual(params, mesh)
assert residual is not None
# per-device state: every leaf carries a leading DP axis of the world size
assert all(x.shape[0] == n_dev for x in jax.tree.leaves(residual))
opt_state = opt.init(params)
key = jax.random.PRNGKey(1)
batch = {
    "targets": jax.random.randint(key, (4, 32), 0, cfg.vocab),
    "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
}
with set_mesh(mesh):
    p1, o1, r1, m1 = step(params, opt_state, residual, batch)
    p2, o2, r2, m2 = step(p1, o1, r1, batch)
res_norm = float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(r2)))
# distinct data shards -> distinct local quantization errors: the
# per-device slices must NOT be copies of device 0's residual
big = max(jax.tree.leaves(r2), key=lambda x: x.size)
slice_diff = float(max(
    jnp.max(jnp.abs(big[i] - big[0])) for i in range(1, n_dev)
)) if n_dev > 1 else -1.0

# checkpoint round-trip with the residual in the tree
state = RunState(step=2, params=p2, opt_state=o2, residual=r2)
d = tempfile.mkdtemp()
save(d, 2, state.checkpoint_tree())
fresh = RunState(
    step=0,
    params=init_params(jax.random.PRNGKey(0), cfg),
    opt_state=opt.init(params),
    residual=eng.init_residual(params, mesh),
)
tree, _ = restore(d, 2, fresh.checkpoint_tree())
diff = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree.leaves(tree["residual"]), jax.tree.leaves(r2))
)
print(json.dumps({
    "n_dev": n_dev,
    "residual_norm": res_norm,
    "slice_diff": slice_diff,
    "restore_diff": diff,
    "loss1": float(m1["loss"]),
    "loss2": float(m2["loss"]),
}))
"""


def test_bf16_ef_residual_threads_and_checkpoints():
    """Satellite: compression='bf16_ef' threads the error-feedback residual
    through the engine's train step on a 4-device DP mesh, the residual is
    genuinely per-device (leading DP axis, distinct slices — not device
    0's copy), and the full per-device state round-trips through the
    checkpoint tree."""
    out = subprocess.run(
        [sys.executable, "-c", EF_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=SUBPROC_ENV, cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_dev"] == 4
    assert rec["residual_norm"] > 0  # the cast error is actually carried
    assert rec["slice_diff"] > 0  # per-device state, not a broadcast
    assert rec["restore_diff"] == 0.0
    assert rec["loss2"] <= rec["loss1"] + 1.0  # training is sane


def test_benchmarks_only_rejects_unknown_tables():
    """Satellite: a typo'd --only exits non-zero and names the known
    tables instead of silently running nothing."""
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "plannin_sweep"],
        capture_output=True, text=True, timeout=300,
        env=SUBPROC_ENV, cwd=REPO_ROOT,
    )
    assert out.returncode != 0
    err = out.stderr + out.stdout
    assert "plannin_sweep" in err  # names the offender
    assert "planning_sweep" in err and "tuner" in err  # lists known tables
