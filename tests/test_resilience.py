"""Serving resilience: snapshot/restore token identity, seeded chaos
kills, corrupt-snapshot fallback, deadline shed/expire, degraded-fabric
replanning — plus the hardened training-loop satellites (restart-counter
persistence, un-swallowed interrupts, exponential backoff) and the
outlier-retrying ``min_of_k`` timing probe."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.core.comm_model import AllReduceModel
from repro.launch.specs import param_specs
from repro.models.transformer import init_params
from repro.planning import build_serve_plan, rebuild_serve_plan, refit_serve_fit
from repro.planning.costs import min_of_k
from repro.runtime import RunState, StragglerMonitor, resilient_loop
from repro.serving import (
    ChaosConfig,
    ChaosError,
    ChaosInjector,
    Request,
    ServingEngine,
    latest_snapshot,
    resilient_serve_loop,
    restore_latest_snapshot,
    save_snapshot,
    snapshot_engine,
)


# ---------------------------------------------------------------------------
# shared engine setup (module-scoped: one compile per shape)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_engine(setup, **kw):
    cfg, params = setup
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 64)
    return ServingEngine(cfg, params, **kw)


def submit_all(eng, n=3, max_new=6, deadline_s=None):
    for rid in range(n):
        eng.submit(Request(rid=rid, prompt=np.arange(3 + rid, dtype=np.int32) + 1,
                           max_new_tokens=max_new, deadline_s=deadline_s))


@pytest.fixture(scope="module")
def baseline_tokens(setup):
    """Uninterrupted run: the tokens every resilient run must reproduce."""
    eng = make_engine(setup)
    submit_all(eng)
    while eng.active or eng.waiting:
        eng.step()
    return {r.rid: r.generated for r in eng.completed}


class FakeClock:
    """Deterministic loop clock: advances a fixed amount per call."""

    def __init__(self, dt=0.25):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


# ---------------------------------------------------------------------------
# min_of_k: outlier-hardened timing probes (satellite)
# ---------------------------------------------------------------------------


class TestMinOfK:
    def test_outlier_discarded_and_retried(self):
        samples = iter([1.0, 50.0, 1.2, 0.9])
        assert min_of_k(lambda: next(samples), 3) == 0.9

    def test_sustained_slowdown_bounded_retries(self):
        """A real slowdown (every probe 100x) must terminate: retries are
        bounded by the repeat budget, and the min never regresses."""
        calls = {"n": 0}

        def sample():
            calls["n"] += 1
            return 1.0 if calls["n"] == 1 else 100.0

        assert min_of_k(sample, 3) == 1.0
        assert calls["n"] <= 6  # repeats + retry budget

    def test_single_repeat(self):
        assert min_of_k(lambda: 2.5, 1) == 2.5


# ---------------------------------------------------------------------------
# StragglerMonitor edge cases (satellite)
# ---------------------------------------------------------------------------


class TestStragglerEdges:
    def test_first_eight_steps_immune(self):
        """No comparisons until 8 observations exist: early compile/warmup
        jitter can never trigger remediation."""
        mon = StragglerMonitor(factor=2.0, patience=1)
        for _ in range(7):
            assert not mon.observe(1.0)
        assert not mon.observe(1000.0)  # 8th observation: still warmup
        assert mon.consecutive_slow == 0
        assert mon.observe(1000.0)  # 9th: compared, flags, patience=1 fires
        assert mon.remediations == 1

    def test_patience_resets_on_fast_step(self):
        mon = StragglerMonitor(factor=2.0, patience=3)
        for _ in range(8):
            mon.observe(1.0)
        mon.observe(5.0)
        mon.observe(5.0)
        assert mon.consecutive_slow == 2
        mon.observe(1.0)  # one fast step wipes the streak
        assert mon.consecutive_slow == 0
        assert mon.remediations == 0

    def test_remediation_resets_counter(self):
        mon = StragglerMonitor(factor=2.0, patience=2)
        for _ in range(8):
            mon.observe(1.0)
        assert not mon.observe(5.0)
        assert mon.observe(5.0)
        assert mon.remediations == 1 and mon.consecutive_slow == 0

    def test_window_eviction_adapts_baseline(self):
        """With window=4, slow steps displace the fast baseline: once two
        3.0s are in the window the median rises to 2.0 and a third 3.0 no
        longer counts as slow — a wide window would keep flagging."""
        mon = StragglerMonitor(factor=2.0, patience=100, window=4)
        wide = StragglerMonitor(factor=2.0, patience=100, window=32)
        for _ in range(8):
            mon.observe(1.0)
            wide.observe(1.0)
        for _ in range(3):
            mon.observe(3.0)
            wide.observe(3.0)
        assert mon.consecutive_slow == 0  # window median adapted to 2.0
        assert wide.consecutive_slow == 3  # wide baseline still 1.0


# ---------------------------------------------------------------------------
# resilient_loop satellites: counter persistence, interrupts, backoff
# ---------------------------------------------------------------------------


def _train_state():
    return RunState(step=0, params={"w": jnp.zeros(())}, opt_state={})


def _train(state, step):
    state.params = {"w": state.params["w"] + 1.0}
    return state


class TestResilientLoopHardening:
    def test_restart_counter_survives_process_death(self, tmp_path):
        """The restarts counter is folded back in from the checkpoint's
        extra dict: a second process sharing the directory continues the
        count instead of resetting to zero."""
        crash1 = {"n": 0}

        def fault1(step):
            if step == 12 and crash1["n"] == 0:
                crash1["n"] += 1
                raise RuntimeError("node died")

        final = resilient_loop(
            num_steps=20, init_state=_train_state, train_step=_train,
            checkpoint_dir=str(tmp_path), checkpoint_every=5,
            fault_injector=fault1, backoff_base_s=0.0,
        )
        assert final.restarts == 1

        crash2 = {"n": 0}

        def fault2(step):
            if step == 3 and crash2["n"] == 0:
                crash2["n"] += 1
                raise RuntimeError("new process dies too")

        final2 = resilient_loop(
            num_steps=25, init_state=_train_state, train_step=_train,
            checkpoint_dir=str(tmp_path), checkpoint_every=5,
            fault_injector=fault2, backoff_base_s=0.0,
        )
        # one crash in this process, one inherited from the checkpoint
        assert final2.restarts == 2
        assert final2.step == 25

    def test_keyboard_interrupt_never_swallowed(self, tmp_path):
        def fault(step):
            if step == 2:
                raise KeyboardInterrupt

        with pytest.raises(KeyboardInterrupt):
            resilient_loop(
                num_steps=10, init_state=_train_state, train_step=_train,
                checkpoint_dir=str(tmp_path), fault_injector=fault,
                backoff_base_s=0.0,
            )

    def test_exponential_backoff_schedule(self, tmp_path):
        crashes = {"n": 0}

        def fault(step):
            if crashes["n"] < 3:
                crashes["n"] += 1
                raise RuntimeError("flaky")

        sleeps = []
        resilient_loop(
            num_steps=5, init_state=_train_state, train_step=_train,
            checkpoint_dir=str(tmp_path), fault_injector=fault,
            backoff_base_s=0.01, sleep_fn=sleeps.append,
        )
        assert sleeps == [0.01, 0.02, 0.04]


# ---------------------------------------------------------------------------
# snapshot/restore: token-for-token identity
# ---------------------------------------------------------------------------


class TestSnapshotRestore:
    def test_restore_resumes_token_identical(self, setup, baseline_tokens, tmp_path):
        eng = make_engine(setup)
        submit_all(eng)
        for _ in range(3):
            eng.step()
        save_snapshot(eng, str(tmp_path), 3)

        fresh = make_engine(setup)
        step, skipped = restore_latest_snapshot(fresh, str(tmp_path))
        assert step == 3 and skipped == 0
        while fresh.active or fresh.waiting:
            fresh.step()
        assert {r.rid: r.generated for r in fresh.completed} == baseline_tokens

    def test_geometry_mismatch_rejected(self, setup):
        eng = make_engine(setup)
        snap = snapshot_engine(eng, 0)
        other = make_engine(setup, max_seq=32)
        with pytest.raises(ValueError, match="geometry"):
            other.restore_snapshot(snap)

    def test_partial_write_ignored(self, setup, tmp_path):
        eng = make_engine(setup)
        submit_all(eng)
        save_snapshot(eng, str(tmp_path), 3)
        ChaosInjector(ChaosConfig(seed=1)).partial_write(str(tmp_path), 5)
        assert latest_snapshot(str(tmp_path)) == 3

    def test_mixed_directory_lands_on_newest_complete(self, setup, tmp_path):
        """A directory after a rough night: complete snapshots at steps
        2/4/6/8, the two newest byte-flipped, plus partial writes newer
        than everything.  ``restore_latest_snapshot`` must skip the
        partials outright (never listed as snapshots), count one
        fallback per corrupt snapshot, and land on the newest complete
        one — step 4."""
        eng = make_engine(setup)
        submit_all(eng, n=2, max_new=12)
        want_row_pos = {}
        for step in (2, 4, 6, 8):
            eng.step()
            eng.step()
            save_snapshot(eng, str(tmp_path), step)
            want_row_pos[step] = eng.row_pos.copy()
        inj = ChaosInjector(ChaosConfig(seed=11))
        inj.corrupt_snapshot(str(tmp_path), 8)
        inj.corrupt_snapshot(str(tmp_path), 6)
        inj.partial_write(str(tmp_path), 9)
        inj.partial_write(str(tmp_path), 11)

        fresh = make_engine(setup)
        step, skipped = restore_latest_snapshot(fresh, str(tmp_path))
        assert step == 4
        assert skipped == 2  # one fallback per corrupt snapshot
        assert np.array_equal(fresh.row_pos, want_row_pos[4])

    def test_all_snapshots_corrupt_raises(self, setup, tmp_path):
        eng = make_engine(setup)
        submit_all(eng)
        inj = ChaosInjector(ChaosConfig(seed=2))
        for step in (1, 2):
            eng.step()
            save_snapshot(eng, str(tmp_path), step)
            inj.corrupt_snapshot(str(tmp_path), step)
        fresh = make_engine(setup)
        with pytest.raises(RuntimeError, match="no loadable serve snapshot"):
            restore_latest_snapshot(fresh, str(tmp_path))


# ---------------------------------------------------------------------------
# chaos-injected serve loop
# ---------------------------------------------------------------------------


class TestChaosServeLoop:
    def test_kill_midrun_restores_identical_tokens(
        self, setup, baseline_tokens, tmp_path
    ):
        eng = make_engine(setup)
        submit_all(eng)
        report = resilient_serve_loop(
            eng, snapshot_dir=str(tmp_path), snapshot_every=2,
            backoff_base_s=0.0,
            chaos=ChaosInjector(ChaosConfig(seed=7, kill_at=(4,))),
        )
        assert report.restarts == 1
        assert len(report.recovery_times_s) == 1
        assert {r.rid: r.generated for r in report.completed} == baseline_tokens
        assert report.goodput_tokens == sum(len(t) for t in baseline_tokens.values())

    def test_corrupt_snapshot_falls_back_to_older(
        self, setup, baseline_tokens, tmp_path
    ):
        eng = make_engine(setup)
        submit_all(eng)
        report = resilient_serve_loop(
            eng, snapshot_dir=str(tmp_path), snapshot_every=2,
            backoff_base_s=0.0,
            chaos=ChaosInjector(ChaosConfig(
                seed=7, kill_at=(5,), corrupt_snapshot_at=4, partial_write_at=4,
            )),
        )
        assert report.snapshot_fallbacks >= 1
        assert {r.rid: r.generated for r in report.completed} == baseline_tokens

    def test_seeded_kills_deterministic(self):
        def kill_steps(seed):
            inj = ChaosInjector(ChaosConfig(seed=seed, kill_prob=0.3, max_kills=10))
            out = []
            for s in range(50):
                try:
                    inj.fault_injector(s)
                except ChaosError:
                    out.append(s)
            return out

        assert kill_steps(5) == kill_steps(5)
        assert kill_steps(5) != kill_steps(6)

    def test_each_step_kills_at_most_once(self):
        inj = ChaosInjector(ChaosConfig(seed=0, kill_at=(4,)))
        with pytest.raises(ChaosError):
            inj.fault_injector(4)
        inj.fault_injector(4)  # restored replay of the same step: no re-kill

    def test_deadline_shed_and_expire(self, setup, tmp_path):
        eng = make_engine(setup, slots=2, max_seq=64)
        eng.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32) + 1,
                           max_new_tokens=6, deadline_s=1000.0))
        eng.submit(Request(rid=1, prompt=np.arange(4, dtype=np.int32) + 1,
                           max_new_tokens=50, deadline_s=6.0))
        eng.submit(Request(rid=2, prompt=np.arange(5, dtype=np.int32) + 1,
                           max_new_tokens=6, deadline_s=-1.0))
        report = resilient_serve_loop(
            eng, snapshot_dir=str(tmp_path), snapshot_every=100,
            backoff_base_s=0.0, clock=FakeClock(0.25),
        )
        by_rid = {r.rid: r for r in report.completed}
        assert report.shed == 1 and by_rid[2].shed and not by_rid[2].generated
        assert report.expired == 1 and by_rid[1].expired
        assert 0 < len(by_rid[1].generated) < 50  # graceful partial output
        assert len(by_rid[0].generated) == 6
        assert report.goodput_tokens == 6  # only the deadline-meeting tokens

    def test_stop_flag_snapshots_and_exits(self, setup, tmp_path):
        eng = make_engine(setup)
        submit_all(eng, max_new=20)
        stops = {"n": 0}

        def stop_flag():
            stops["n"] += 1
            return stops["n"] > 4

        report = resilient_serve_loop(
            eng, snapshot_dir=str(tmp_path), snapshot_every=100,
            backoff_base_s=0.0, stop_flag=stop_flag,
        )
        assert report.interrupted
        assert latest_snapshot(str(tmp_path)) == report.steps
        # the snapshot is resumable: a fresh engine finishes the work
        fresh = make_engine(setup)
        restore_latest_snapshot(fresh, str(tmp_path))
        assert fresh.active or fresh.waiting
        while fresh.active or fresh.waiting:
            fresh.step()
        assert len(fresh.completed) == 3


# ---------------------------------------------------------------------------
# degraded-fabric replanning
# ---------------------------------------------------------------------------


class TestDegradedReplan:
    def test_degraded_wire_changes_merge_decision(self):
        """MG-WFBP's merge set is a function of (a, b): a wire with 50x
        the startup cost must merge more aggressively, and the rebuilt
        plan must predict slower steps — the load-bearing acceptance."""
        cfg = get_config("tinyllama-1.1b")
        plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 8}, batch_rows=64)
        assert len(plan.schedule.groups) > 1

        degraded = AllReduceModel(a=plan.model.a * 50, b=plan.model.b * 10,
                                  name="degraded")
        new = rebuild_serve_plan(plan, degraded)
        assert len(new.schedule.groups) < len(plan.schedule.groups)
        assert new.predicted_step_time() > plan.predicted_step_time()
        assert new.provenance["refit"] == "degraded_fabric"

    def test_refit_serve_fit_recovers_constants(self):
        truth = AllReduceModel(a=5e-4, b=2e-9, name="truth")
        fit = refit_serve_fit(lambda nb: truth(nb))
        assert fit.a == pytest.approx(truth.a, rel=1e-6)
        assert fit.b == pytest.approx(truth.b, rel=1e-6)

    def test_loop_replans_under_sustained_slowdown(self, setup, tmp_path):
        cfg, params = setup
        plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 8}, batch_rows=4)
        eng = ServingEngine(cfg, params, slots=2, max_seq=128, plan=plan)
        for rid in range(2):
            eng.submit(Request(rid=rid, prompt=np.arange(4, dtype=np.int32) + 1,
                               max_new_tokens=40))
        chaos = ChaosInjector(ChaosConfig(seed=3, slow_factor=30.0, slow_after=12))
        report = resilient_serve_loop(
            eng, snapshot_dir=str(tmp_path), snapshot_every=50,
            backoff_base_s=0.0, chaos=chaos,
            straggler=StragglerMonitor(window=16, factor=2.0, patience=2),
        )
        assert report.replans >= 1
        # the engine now runs a plan priced at the degraded wire, and the
        # baseline-probing refit does not compound across replans
        assert eng.plan.model.a == pytest.approx(plan.model.a * 30)
        assert eng.plan.predicted_step_time() > plan.predicted_step_time()
