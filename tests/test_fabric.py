"""Fabric API: typed-collective cost algebra, the backend-preset registry,
back-compat shims over core.comm_model, the ServePlan lifecycle, and the
serve-side lowering invariant (one collective HLO op per scheduled group)."""

import dataclasses
import json
import subprocess
import sys
import textwrap

import pytest

from _env import REPO_ROOT, SUBPROC_ENV

from repro.core.comm_model import (
    AllReduceModel,
    TPU_V5E as TPU_V5E_SHIM,
    TpuInterconnect,
    fit_affine,
    paper_cluster_model,
    tpu_psum_model,
)
from repro.fabric import (
    Collective,
    Fabric,
    MeasuredFabric,
    RingInterconnect,
    available_fabrics,
    get_fabric,
    register_fabric,
)

PRESETS = ("tpu_v5e", "gpu_nccl", "dcn_only", "paper_10gbe")
#: Hierarchical tree/pipeline presets (Wang & Vuduc): covered by the
#: preset-wide invariants but not the ring-phase algebra tests (a tree
#: all-reduce is not rs ∘ ag, and its startup can undercut a ring
#: all_gather's — that asymmetry is the point of the presets).
HIER_PRESETS = ("tree_10gbe", "pipeline_10gbe", "tpu_v5e_tree_dcn")
ALL_PRESETS = PRESETS + HIER_PRESETS
#: Representative psum axis sets (single-axis, multi-ICI, cross-pod).
AXIS_CASES = (
    {"data": 8},
    {"data": 32},
    {"pod": 2, "data": 16},
    {"data": 16, "model": 4},
)


class TestFabricAlgebra:
    def test_rs_plus_ag_equals_all_reduce_per_axis(self):
        """One ring phase each way: reduce_scatter ∘ all_gather == all_reduce."""
        for preset in PRESETS:
            f = get_fabric(preset)
            for n in (2, 8, 16):
                rs = f.cost("reduce_scatter", {"data": n})
                ag = f.cost("all_gather", {"data": n})
                ar = f.cost("all_reduce", {"data": n})
                assert rs.a + ag.a == pytest.approx(ar.a, rel=1e-12), preset
                assert rs.b + ag.b == pytest.approx(ar.b, rel=1e-12), preset

    def test_hierarchical_composition_matches_psum_model(self):
        """Satellite: rs(ici) + cross-pod ar on 1/ici of the message +
        ag(ici) composed through the fabric == TpuInterconnect.psum_model."""
        f = get_fabric("tpu_v5e")
        for ici, pods in ((16, 2), (8, 4), (32, 2)):
            rs = f.cost(Collective.REDUCE_SCATTER, {"data": ici})
            ar = f.cost(Collective.ALL_REDUCE, {"pod": pods})
            ag = f.cost(Collective.ALL_GATHER, {"data": ici})
            ref = tpu_psum_model({"pod": pods, "data": ici})
            assert rs.a + ar.a + ag.a == pytest.approx(ref.a, rel=1e-12)
            assert rs.b + ag.b + ar.b / ici == pytest.approx(ref.b, rel=1e-12)

    def test_paper_preset_reproduces_paper_cluster(self):
        """paper_10gbe all_reduce == Table II ring at the paper's constants."""
        f = get_fabric("paper_10gbe")
        for n in (2, 4, 8):
            got = f.cost("all_reduce", {"data": n})
            ref = paper_cluster_model(n, algorithm="ring")
            assert got.a == pytest.approx(ref.a, rel=1e-12)
            assert got.b == pytest.approx(ref.b, rel=1e-12)

    def test_gather_cheaper_than_reduce(self):
        """all_gather ships bytes without reducing: b strictly below
        all_reduce's, a strictly below (one phase vs two)."""
        for preset in PRESETS:
            f = get_fabric(preset)
            ag = f.cost("all_gather", {"data": 8})
            ar = f.cost("all_reduce", {"data": 8})
            assert ag.b < ar.b and ag.a < ar.a, preset

    def test_all_to_all_prices_full_volume_per_tier(self):
        """Hierarchical all-to-all reshuffles the full local volume on
        every tier — no reduce-scatter shrink factor on the slow tier."""
        f = get_fabric("tpu_v5e")
        both = f.cost("all_to_all", {"data": 8, "pod": 4})
        ici = f.cost("all_to_all", {"data": 8})
        pod = f.cost("all_to_all", {"pod": 4})
        assert both.b == pytest.approx(ici.b + pod.b, rel=1e-12)

    def test_trivial_axes_are_free(self):
        f = get_fabric("tpu_v5e")
        for op in Collective:
            m = f.cost(op, {"data": 1})
            assert (m.a, m.b) == (0.0, 0.0)

    def test_every_preset_prices_every_op(self):
        for preset in ALL_PRESETS:
            f = get_fabric(preset)
            for op in Collective:
                for axes in AXIS_CASES:
                    m = f.cost(op, axes)
                    assert m.a > 0 and m.b > 0, (preset, op, axes)
                    # Eq. 10: merging recovers exactly the startup
                    assert m.merged_gain(1 << 20, 1 << 20) == pytest.approx(m.a)


class TestRegistry:
    def test_round_trip_and_protocol(self):
        for preset in ALL_PRESETS:
            f = get_fabric(preset)
            assert isinstance(f, Fabric)
            assert f.name == preset
        assert set(ALL_PRESETS) <= set(available_fabrics())

    def test_available_fabrics_is_sorted_list(self):
        """The registry listing is a sorted list — stable display order,
        directly usable as argparse choices."""
        names = available_fabrics()
        assert isinstance(names, list)
        assert names == sorted(names)

    def test_unknown_name_errors_with_known_list(self):
        with pytest.raises(KeyError, match="tpu_v5e"):
            get_fabric("infiniband_9000")

    def test_instance_passthrough(self):
        custom = RingInterconnect(ici_link_bw=1e9, name="custom")
        assert get_fabric(custom) is custom
        with pytest.raises(TypeError):
            get_fabric(object())  # no .cost

    def test_register_measured_round_trip(self):
        fit = AllReduceModel(a=3e-5, b=2e-9, name="fit")
        mf = MeasuredFabric(models={"data": fit})
        register_fabric("measured", mf, overwrite=True)
        got = get_fabric("measured")
        assert got is mf
        m = got.cost("all_reduce", {"data": 8})
        assert (m.a, m.b) == (fit.a, fit.b)

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError):
            register_fabric("tpu_v5e", RingInterconnect())


class TestMeasuredFabric:
    def test_from_comm_fit_slots_into_cost(self):
        """A MeasuredComm-style sweep drives the same cost() surface."""
        from repro.planning import MeasuredComm

        true = AllReduceModel(a=5e-5, b=1.5e-9)
        sizes = tuple(4096 * 8**i for i in range(5))
        comm = MeasuredComm(sizes_bytes=sizes,
                            times_s=tuple(true(s) for s in sizes),
                            axes=("data",))
        mf = MeasuredFabric.from_comm(comm)
        ar = mf.cost("all_reduce", {"data": 32})
        assert ar.a == pytest.approx(true.a, rel=1e-6)
        assert ar.b == pytest.approx(true.b, rel=1e-6)
        # derived single-phase ops: half the ring each way
        ag = mf.cost("all_gather", {"data": 32})
        assert ag.a == pytest.approx(true.a / 2, rel=1e-6)
        assert ag.b == pytest.approx(true.b / 2, rel=1e-6)

    def test_op_override_and_missing_axes(self):
        mf = MeasuredFabric(models={
            "data": AllReduceModel(a=1e-5, b=1e-9),
            "all_gather@data": AllReduceModel(a=9e-6, b=3e-10),
        })
        ag = mf.cost("all_gather", {"data": 8})
        assert (ag.a, ag.b) == (9e-6, 3e-10)  # direct fit wins
        with pytest.raises(KeyError, match="model"):
            mf.cost("all_reduce", {"model": 4})


class TestCommModelShim:
    def test_shim_names_are_the_preset(self):
        """Satellite: core.comm_model keeps the TPU names as re-exports of
        the tpu_v5e fabric preset."""
        assert TPU_V5E_SHIM is get_fabric("tpu_v5e")
        assert TpuInterconnect is RingInterconnect
        assert isinstance(TPU_V5E_SHIM, TpuInterconnect)

    def test_shim_and_preset_identical_ab(self):
        """Satellite: identical (a, b) through both surfaces for
        representative axis sizes."""
        preset = get_fabric("tpu_v5e")
        for axes in AXIS_CASES:
            shim = tpu_psum_model(axes)
            direct = preset.cost("all_reduce", axes)
            assert (shim.a, shim.b) == (direct.a, direct.b), axes
            legacy = TpuInterconnect().psum_model(axes)
            assert (shim.a, shim.b) == (legacy.a, legacy.b), axes

    def test_core_package_reexports(self):
        import repro.core as core

        assert core.TPU_V5E_ICI is get_fabric("tpu_v5e")
        assert core.tpu_psum_model is tpu_psum_model


def _serve_inputs(arch="tinyllama-1.1b", batch_rows=16):
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.specs import param_specs

    cfg = get_config(arch)
    return cfg, param_specs(cfg)


class TestServePlan:
    def test_json_round_trip_exact(self):
        from repro.planning import ServePlan, build_serve_plan

        cfg, shapes = _serve_inputs()
        plan = build_serve_plan(cfg, shapes, "tpu_v5e", {"model": 8},
                                batch_rows=16)
        rt = ServePlan.from_json(plan.to_json())
        assert rt == plan
        # and through a dict cycle that simulates a file on disk
        rt2 = ServePlan.from_json_dict(json.loads(plan.to_json()))
        assert rt2.schedule.result.t_iter == plan.schedule.result.t_iter

    def test_save_load(self, tmp_path):
        from repro.planning import ServePlan, build_serve_plan

        cfg, shapes = _serve_inputs()
        plan = build_serve_plan(cfg, shapes, "gpu_nccl", {"model": 8},
                                batch_rows=16)
        p = plan.save(tmp_path / "serve_plan.json")
        assert ServePlan.load(p) == plan

    def test_bad_format_rejected(self):
        from repro.planning import ServePlan, build_serve_plan

        cfg, shapes = _serve_inputs()
        d = build_serve_plan(cfg, shapes, "tpu_v5e", {"model": 8},
                             batch_rows=16).to_json_dict()
        d["format"] = 99
        with pytest.raises(ValueError, match="format"):
            ServePlan.from_json_dict(d)

    def test_moe_arch_schedules_all_to_all(self):
        from repro.configs import get_config
        from repro.launch.specs import param_specs
        from repro.planning import build_serve_plan, decode_unit_costs

        cfg = get_config("mixtral-8x7b")
        plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 8}, batch_rows=16)
        assert plan.op == "all_to_all"
        assert plan.provenance["fabric"] == "tpu_v5e"
        # 'moe' blocks carry an attention sublayer: the per-stage payload
        # must include the fresh KV rows on top of the expert dispatch
        costs = decode_unit_costs(cfg, param_specs(cfg), 16)
        kv = 2 * 16 * cfg.attention.n_kv_heads * cfg.attention.head_dim * 2
        a2a = 2 * 16 * cfg.moe.top_k * cfg.d_model * 2 * len(cfg.pattern)
        assert costs[0].grad_bytes == kv + a2a

    def test_recurrent_stages_ship_no_kv(self):
        from repro.configs import get_config
        from repro.launch.specs import param_specs
        from repro.planning import decode_unit_costs

        cfg = get_config("rwkv6-7b")  # pattern ('rwkv',): no KV cache
        costs = decode_unit_costs(cfg, param_specs(cfg), 16)
        assert costs[0].grad_bytes == 1  # clamped empty payload

    def test_fabric_moves_the_merge_set(self):
        """Same cost vector, different fabric -> different schedule: the
        NCCL-class launch overhead merges what TPU ICI keeps separate."""
        from repro.planning import build_serve_plan

        cfg, shapes = _serve_inputs()
        tpu = build_serve_plan(cfg, shapes, "tpu_v5e", {"model": 8},
                               batch_rows=16)
        nccl = build_serve_plan(cfg, shapes, "gpu_nccl", {"model": 8},
                                batch_rows=16)
        assert len(tpu.schedule.groups) > len(nccl.schedule.groups)

    def test_all_presets_yield_valid_plans(self):
        from repro.planning import build_serve_plan

        cfg, shapes = _serve_inputs()
        for preset in ALL_PRESETS:
            plan = build_serve_plan(cfg, shapes, preset, {"model": 8},
                                    batch_rows=16)
            assert plan.schedule.groups[0][0] == 1
            assert plan.schedule.groups[-1][1] == cfg.n_stages
            assert plan.schedule.result.t_iter > 0
            if plan.model.a > 0:
                assert plan.model.merged_gain(1, 1) > 0

    def test_engine_carries_plan(self):
        import jax
        import jax.numpy as jnp

        from repro.configs import get_reduced
        from repro.launch.specs import param_specs
        from repro.models.transformer import init_params
        from repro.planning import build_serve_plan
        from repro.serving import Request, ServingEngine

        cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"),
                                  param_dtype=jnp.float32)
        plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 4}, batch_rows=2)
        params = init_params(jax.random.PRNGKey(0), cfg)
        eng = ServingEngine(cfg, params, slots=2, max_seq=32, plan=plan)
        assert eng.plan is plan
        assert eng.predicted_step_time() == plan.schedule.result.t_iter
        import numpy as np

        eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=3))
        done = eng.run_to_completion()
        assert len(done) == 1 and len(done[0].generated) == 3


SERVE_LOWERING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.configs import get_config
    from repro.core.profiler import parse_collectives
    from repro.launch.specs import param_specs
    from repro.planning import build_serve_plan, make_group_collective

    cfg = get_config("tinyllama-1.1b")
    shapes = param_specs(cfg)
    mesh = make_mesh((8,), ("model",))
    out = []
    # tpu_v5e @ 16 rows -> many groups; gpu_nccl -> one merged group;
    # wfbp pins the one-op-per-group invariant at the other extreme.
    for fabric, policy in (("tpu_v5e", "mg_wfbp"), ("gpu_nccl", "mg_wfbp"),
                           ("tpu_v5e", "wfbp")):
        plan = build_serve_plan(cfg, shapes, fabric, {"model": 8},
                                batch_rows=16, policy=policy)
        gather = make_group_collective(plan)
        stacked = jnp.ones((cfg.n_stages, 16, 64), jnp.float32)

        f = shard_map(gather, mesh=mesh, in_specs=(P(),),
                      out_specs=[P(None, "model") for _ in plan.schedule.groups],
                      axis_names={"model"}, check_vma=False)
        stats = parse_collectives(jax.jit(f).lower(stacked).as_text())
        outs = jax.jit(f)(stacked)
        ok = all(float(jnp.max(jnp.abs(o - 1.0))) == 0.0 for o in outs)
        out.append({
            "fabric": fabric,
            "policy": policy,
            "op": plan.op,
            "n_groups": len(plan.schedule.groups),
            "collective_ops": stats.counts.get("all-gather", 0),
            "total_collectives": stats.total_ops,
            "values_ok": ok,
        })
    print(json.dumps(out))
""")


def test_serve_lowering_one_collective_per_group():
    """Acceptance: exactly one collective HLO op per scheduled serve group
    — the decode-side analogue of the training sync's lowering invariant."""
    out = subprocess.run(
        [sys.executable, "-c", SERVE_LOWERING_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=SUBPROC_ENV, cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    recs = json.loads(out.stdout.strip().splitlines()[-1])
    by = {(r["fabric"], r["policy"]): r for r in recs}
    # the fabrics picked different merge sets from the same cost vector
    assert by[("tpu_v5e", "mg_wfbp")]["n_groups"] > by[("gpu_nccl", "mg_wfbp")]["n_groups"]
    assert by[("tpu_v5e", "wfbp")]["n_groups"] == get_config_n_stages()
    for r in recs:
        assert r["op"] == "all_gather", r
        assert r["collective_ops"] == r["n_groups"], r
        assert r["total_collectives"] == r["n_groups"], r  # nothing extra
        assert r["values_ok"], r


def get_config_n_stages():
    from repro.configs import get_config

    return get_config("tinyllama-1.1b").n_stages
