"""Substrate tests: data pipeline, checkpointing, fault tolerance,
optimizers, comm models."""

import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.core import (
    ALGORITHMS,
    AllReduceModel,
    TpuInterconnect,
    paper_cluster_model,
    tpu_psum_model,
)
from repro.data import DataConfig, make_stream
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, sgd_init, sgd_update
from repro.runtime import RunState, StragglerMonitor, resilient_loop


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def cfg(self, **kw):
        return DataConfig(vocab=128, seq_len=32, global_batch=8, **kw)

    def test_deterministic_per_step(self):
        s = make_stream(self.cfg())
        a, b = s.batch_at(7), s.batch_at(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = s.batch_at(8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_targets_shifted(self):
        s = make_stream(self.cfg())
        b = s.batch_at(0)
        assert b["tokens"].shape == (8, 32) and b["targets"].shape == (8, 32)

    def test_host_sharding_partitions_batch(self):
        full = make_stream(self.cfg(), host_rank=0, host_count=1)
        h0 = make_stream(self.cfg(), host_rank=0, host_count=2)
        h1 = make_stream(self.cfg(), host_rank=1, host_count=2)
        assert h0.batch_at(3)["tokens"].shape == (4, 32)
        # different ranks draw different rows
        assert not np.array_equal(h0.batch_at(3)["tokens"], h1.batch_at(3)["tokens"])

    def test_resume_mid_stream(self):
        s = make_stream(self.cfg())
        it = s.iterate(start_step=5)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], s.batch_at(5)["tokens"])

    def test_embeds_mode(self):
        s = make_stream(self.cfg(input_mode="embeds", d_model=16))
        b = s.batch_at(0)
        assert b["embeds"].shape == (8, 32, 16)

    @settings(max_examples=20, deadline=None)
    @given(step=st.integers(0, 10_000), rank=st.integers(0, 3))
    def test_pure_function_of_step(self, step, rank):
        s1 = make_stream(self.cfg(), host_rank=rank, host_count=4)
        s2 = make_stream(self.cfg(), host_rank=rank, host_count=4)
        np.testing.assert_array_equal(
            s1.batch_at(step)["tokens"], s2.batch_at(step)["tokens"]
        )


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def tree(self, k=0):
        return {
            "a": jnp.arange(12.0).reshape(3, 4) + k,
            "nested": {"b": jnp.ones((5,), jnp.int32) * k},
        }

    def test_roundtrip(self, tmp_path):
        save(tmp_path, 3, self.tree(1), extra={"note": "x"})
        out, extra = restore(tmp_path, 3, self.tree(0))
        np.testing.assert_array_equal(out["a"], self.tree(1)["a"])
        assert extra == {"note": "x"}

    def test_latest_step_ignores_tmp(self, tmp_path):
        save(tmp_path, 1, self.tree())
        save(tmp_path, 2, self.tree())
        (tmp_path / "step_00000099.tmp").mkdir()
        assert latest_step(tmp_path) == 2

    def test_shape_mismatch_rejected(self, tmp_path):
        save(tmp_path, 1, self.tree())
        bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((5,), jnp.int32)}}
        with pytest.raises(ValueError):
            restore(tmp_path, 1, bad)

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(tmp_path)
        ck.save(10, self.tree(2))
        ck.save(20, self.tree(3))  # waits for the first
        ck.wait()
        assert latest_step(tmp_path) == 20
        out, _ = restore(tmp_path, 10, self.tree(0))
        np.testing.assert_array_equal(out["a"], self.tree(2)["a"])

    def test_overwrite_same_step(self, tmp_path):
        save(tmp_path, 5, self.tree(1))
        save(tmp_path, 5, self.tree(9))
        out, _ = restore(tmp_path, 5, self.tree(0))
        np.testing.assert_array_equal(out["a"], self.tree(9)["a"])


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


class TestFaultTolerance:
    def test_restart_resumes_from_checkpoint(self, tmp_path):
        calls = {"crashes": 0}

        def init_state():
            return RunState(step=0, params={"w": jnp.zeros(())}, opt_state={})

        def fault(step):
            if step == 12 and calls["crashes"] == 0:
                calls["crashes"] += 1
                raise RuntimeError("node died")

        def train(state, step):
            state.params = {"w": state.params["w"] + 1.0}
            return state

        final = resilient_loop(
            num_steps=20, init_state=init_state, train_step=train,
            checkpoint_dir=str(tmp_path), checkpoint_every=5,
            fault_injector=fault,
        )
        assert final.step == 20
        assert final.restarts == 1
        # params replayed deterministically: w == 20 (5 steps lost, redone)
        assert float(final.params["w"]) == 20.0

    def test_max_restarts_exceeded(self, tmp_path):
        def init_state():
            return RunState(step=0, params={}, opt_state={})

        def fault(step):
            raise RuntimeError("always dies")

        with pytest.raises(RuntimeError):
            resilient_loop(
                num_steps=5, init_state=init_state,
                train_step=lambda s, i: s,
                checkpoint_dir=str(tmp_path), max_restarts=2,
                fault_injector=fault,
            )

    def test_straggler_monitor(self):
        mon = StragglerMonitor(factor=2.0, patience=3)
        for _ in range(16):
            assert not mon.observe(1.0)
        assert not mon.observe(5.0)
        assert not mon.observe(5.0)
        assert mon.observe(5.0)  # third consecutive -> remediate
        assert mon.remediations == 1
        # counter resets after remediation
        assert not mon.observe(5.0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


class TestOptim:
    def test_sgd_momentum_matches_manual(self):
        p = {"w": jnp.asarray([1.0, 2.0])}
        g = {"w": jnp.asarray([0.5, -0.5])}
        st_ = sgd_init(p, momentum=0.9)
        p1, st1 = sgd_update(g, st_, p, lr=0.1, momentum=0.9)
        np.testing.assert_allclose(np.asarray(p1["w"]), [1.0 - 0.05, 2.0 + 0.05])
        p2, _ = sgd_update(g, st1, p1, lr=0.1, momentum=0.9)
        # m2 = 0.9*0.5 + 0.5 = 0.95  =>  w2 = w1 -/+ 0.1*0.95
        np.testing.assert_allclose(
            np.asarray(p2["w"]), [0.95 - 0.095, 2.05 + 0.095], rtol=1e-6
        )

    def test_adamw_decreases_quadratic(self):
        p = {"w": jnp.asarray([5.0, -3.0])}
        st_ = adamw_init(p)
        for _ in range(200):
            g = {"w": 2 * p["w"]}
            p, st_ = adamw_update(g, st_, p, lr=0.05, weight_decay=0.0)
        assert float(jnp.max(jnp.abs(p["w"]))) < 0.5

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(10.0)
        total = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(clipped)))
        assert float(total) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# comm models
# ---------------------------------------------------------------------------


class TestCommModel:
    def test_merging_property_eq10(self):
        for name, fn in ALGORITHMS.items():
            m = fn(8, 45e-6, 1e-9, 1e-10)
            assert m.merged_gain(1e6, 2e6) == pytest.approx(m.a)
            assert m(1e6) + m(2e6) > m(3e6)

    def test_paper_intercepts(self):
        assert paper_cluster_model(8).a == pytest.approx(633.64e-6, rel=1e-3)

    def test_tpu_hierarchical_model(self):
        single = tpu_psum_model({"data": 16})
        multi = tpu_psum_model({"pod": 2, "data": 16})
        assert multi.a > single.a  # DCN startup adds
        assert multi(1 << 20) > single(1 << 20)

    @settings(max_examples=50, deadline=None)
    @given(
        n=st.sampled_from([2, 4, 8, 16, 64]),
        m1=st.integers(1, 10**8),
        m2=st.integers(1, 10**8),
    )
    def test_merge_never_hurts_pure_comm(self, n, m1, m2):
        ar = paper_cluster_model(n)
        assert ar(m1 + m2) <= ar(m1) + ar(m2)
