"""Pallas kernel validation in interpret mode: shape/dtype sweeps and
hypothesis property tests against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels import (
    attention_ref,
    flash_attention_fwd,
    rglru_pallas,
    rglru_ref,
    wkv_pallas,
    wkv_ref,
)
from repro.models.rwkv6 import wkv_chunked


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,hd,causal,window,softcap",
    [
        (2, 256, 4, 2, 64, True, None, None),
        (1, 512, 8, 8, 128, True, None, None),
        (2, 256, 4, 1, 64, True, 128, None),
        (1, 256, 2, 2, 64, True, None, 50.0),
        (1, 256, 4, 2, 64, False, None, None),
        (1, 384, 6, 2, 128, True, 256, 30.0),  # everything at once
        (1, 128, 4, 4, 256, True, None, None),  # gemma head_dim
    ],
)
def test_flash_attention_matches_ref(B, S, Hq, Hkv, hd, causal, window, softcap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    out = flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=128, block_k=128, interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


@settings(max_examples=12, deadline=None)
@given(
    bq=st.sampled_from([64, 128, 256]),
    bk=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
    causal=st.booleans(),
)
def test_flash_attention_block_shape_invariance(bq, bk, seed, causal):
    """Output must not depend on the tiling."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------


def _wkv_inputs(key, B, T, H, K, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, K), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, H, K), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, K), jnp.float32).astype(dtype)
    # decays in ~(0.63, 0.999) like trained RWKV models
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (B, T, H, K), minval=-6.0, maxval=-0.8)))
    u = jax.random.normal(ks[4], (H, K), jnp.float32) * 0.5
    return r, k, v, w.astype(jnp.float32), u


@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 64, 2, 32, 16),
    (1, 128, 4, 64, 32),
    (1, 256, 1, 64, 128),
    (2, 96, 2, 32, 32),  # T not a multiple of a power-of-two chunk count
])
def test_wkv_pallas_matches_sequential_ref(B, T, H, K, chunk):
    r, k, v, w, u = _wkv_inputs(jax.random.PRNGKey(1), B, T, H, K)
    out_ref, s_ref = wkv_ref(r, k, v, w, u)
    out_pl, s_pl = wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref), rtol=2e-4, atol=2e-4)


def test_wkv_chunked_jnp_matches_sequential_ref():
    """The model's chunked jnp path (training fallback) is also exact."""
    r, k, v, w, u = _wkv_inputs(jax.random.PRNGKey(2), 2, 128, 2, 32)
    out_ref, s_ref = wkv_ref(r, k, v, w, u)
    out_c, s_c = wkv_chunked(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_ref), rtol=2e-4, atol=2e-4)


def test_wkv_initial_state_threading():
    """Splitting a sequence across two kernel calls == one call (serving)."""
    r, k, v, w, u = _wkv_inputs(jax.random.PRNGKey(3), 1, 128, 2, 32)
    out_full, s_full = wkv_pallas(r, k, v, w, u, chunk=32, interpret=True)
    h = 64
    out_a, s_a = wkv_pallas(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, chunk=32, interpret=True)
    out_b, s_b = wkv_pallas(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s_a, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_full[:, h:]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_full), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([8, 16, 32, 64]))
def test_wkv_chunk_invariance(seed, chunk):
    """WKV output must not depend on the chunk size (associativity)."""
    r, k, v, w, u = _wkv_inputs(jax.random.PRNGKey(seed), 1, 64, 2, 32)
    out_ref, s_ref = wkv_ref(r, k, v, w, u)
    out, s = wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _rglru_inputs(key, B, T, W):
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, T, W)) * 2.0 + 2.0)  # (0,1)
    g = jax.random.normal(k2, (B, T, W)) * 0.5
    return a, g


@pytest.mark.parametrize("B,T,W,chunk,block_w", [
    (2, 64, 128, 16, 128),
    (1, 128, 256, 32, 128),
    (1, 256, 512, 128, 256),
])
def test_rglru_pallas_matches_ref(B, T, W, chunk, block_w):
    a, g = _rglru_inputs(jax.random.PRNGKey(0), B, T, W)
    h_ref, hT_ref = rglru_ref(a, g)
    h, hT = rglru_pallas(a, g, chunk=chunk, block_w=block_w, interpret=True)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref), rtol=1e-5, atol=1e-5)


def test_rglru_state_threading():
    a, g = _rglru_inputs(jax.random.PRNGKey(1), 1, 128, 128)
    h_full, hT_full = rglru_pallas(a, g, chunk=32, block_w=128, interpret=True)
    h_a, s_a = rglru_pallas(a[:, :64], g[:, :64], chunk=32, block_w=128, interpret=True)
    h_b, s_b = rglru_pallas(a[:, 64:], g[:, 64:], s_a, chunk=32, block_w=128, interpret=True)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full[:, 64:]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(hT_full), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rglru_associative_scan_fallback_matches_ref(seed):
    from repro.kernels.rglru.ops import rglru

    a, g = _rglru_inputs(jax.random.PRNGKey(seed), 2, 64, 64)
    h_ref, hT_ref = rglru_ref(a, g)
    h, hT = rglru(a, g, use_pallas=False)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention backward (dQ/dK/dV Pallas kernels)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,Hq,Hkv,hd,causal,window,softcap",
    [
        (1, 256, 4, 2, 64, True, None, None),
        (1, 256, 4, 4, 64, False, None, None),
        (1, 256, 2, 1, 64, True, 128, None),
        (1, 256, 2, 2, 64, True, None, 50.0),
        (1, 384, 6, 2, 128, True, 256, 30.0),
    ],
)
def test_flash_attention_bwd_matches_ref_grads(B, S, Hq, Hkv, hd, causal, window, softcap):
    from repro.kernels.flash_attention.ops import flash_attention_train

    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    w = jax.random.normal(ks[3], (B, S, Hq, hd), jnp.float32)  # loss weights

    def loss_kernel(q, k, v):
        o = flash_attention_train(q, k, v, causal, window, softcap, True)
        return jnp.sum(o * w)

    def loss_ref(q, k, v):
        o = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
        return jnp.sum(o * w)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4, err_msg=name
        )


def test_flash_attention_fwd_lse():
    from repro.kernels.flash_attention import flash_attention_fwd

    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
    o, lse = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64,
                                 interpret=True, return_lse=True)
    # reference lse
    s = jnp.einsum("bsqh,btqh->bqst", q, k) * 64**-0.5
    mask = jnp.tril(jnp.ones((128, 128), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    lse_ref = jax.nn.logsumexp(s, axis=-1).transpose(0, 2, 1)  # (B, S, H)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), rtol=1e-5, atol=1e-5)
