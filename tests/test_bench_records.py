"""Committed BENCH record hygiene.

Every ``benchmarks/results/BENCH_*.json`` must round-trip byte-identically
through the writer's serialization (``json.dumps(..., indent=1,
sort_keys=True)`` + trailing newline) — so re-running a suite that
produces the same numbers yields a zero diff, and nobody hand-edits a
record into a shape the writer would immediately rewrite.

``BENCH_overlap.json`` additionally carries the tentpole claim and is
pinned structurally: the dag issue order overlaps, the post order does
not, and the two are bit-identical in loss.
"""

import json
import pathlib

import pytest

RESULTS = pathlib.Path(__file__).parent.parent / "benchmarks" / "results"
BENCH_FILES = sorted(RESULTS.glob("BENCH_*.json"))


def test_some_records_committed():
    assert len(BENCH_FILES) >= 9, BENCH_FILES


@pytest.mark.parametrize("path", BENCH_FILES, ids=lambda p: p.stem)
def test_roundtrips_byte_identically(path):
    raw = path.read_text()
    rec = json.loads(raw)
    # suites publish either one record dict or a list of row dicts
    assert isinstance(rec, (dict, list)) and rec, path
    assert raw == json.dumps(rec, indent=1, sort_keys=True) + "\n", (
        f"{path.name} is not in the writer's canonical serialization; "
        f"regenerate it through benchmarks.run.write_bench"
    )


def test_overlap_record_claims():
    rec = json.loads((RESULTS / "BENCH_overlap.json").read_text())
    for key in ("arch", "policy", "fuse", "n_groups", "n_devices",
                "group_wire_bytes", "post", "dag", "loss_bit_identical"):
        assert key in rec, key
    assert rec["loss_bit_identical"] is True
    assert len(rec["group_wire_bytes"]) == rec["n_groups"]
    for issue in ("post", "dag"):
        side = rec[issue]
        assert side["n_comm_spans"] == rec["n_groups"] * rec["n_devices"]
        assert side["total_comm_us"] > 0
    # the tentpole: dag hides wire inside backward, post cannot
    assert rec["dag"]["overlap_fraction"] > 0
    assert rec["dag"]["n_overlapped_starts"] > 0
    assert rec["post"]["n_overlapped_starts"] == 0
    assert rec["dag"]["overlap_fraction"] > rec["post"]["overlap_fraction"]
