"""Shared environment for subprocess-based multi-device tests.

Forced-device-count cases run in a subprocess so the main pytest
process keeps a single CPU device; every such test uses this one env
(repo-root-relative PYTHONPATH, CPU backend pinned so jax skips the
60-second TPU probe the container's libtpu otherwise triggers).
"""

from __future__ import annotations

import os
import pathlib

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
SUBPROC_ENV = {
    "PYTHONPATH": str(REPO_ROOT / "src"),
    "PATH": os.environ.get("PATH", "/usr/bin:/bin:/usr/local/bin"),
    "JAX_PLATFORMS": "cpu",
}
