"""Scheduler correctness: Algorithm 1 vs the timeline and vs exhaustive optimum."""

import math

import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import (
    AllReduceModel,
    Hardware,
    LayerCost,
    evaluate,
    evaluate_schedule,
    fixed_bucket_schedule,
    groups_from_merged_set,
    mg_wfbp_schedule,
    optimal_schedule,
    paper_cluster_model,
    synceasgd_schedule,
    wfbp_schedule,
)
from repro.core.schedule import dp_optimal_schedule

HW = Hardware(name="unit", peak_flops=1.0, hbm_bw=1.0, mxu_eff=1.0, hbm_eff=1.0)
# With HW above, t_b == bwd_flops and t_f == fwd_flops — tests control times
# directly in "seconds".


def mk_costs(tb: list[float], nbytes: list[int], tf: float = 0.0) -> list[LayerCost]:
    """Layer costs with explicit backward times and message sizes."""
    assert len(tb) == len(nbytes)
    out = []
    for i, (t, n) in enumerate(zip(tb, nbytes)):
        out.append(
            LayerCost(
                name=f"l{i + 1}",
                params=n,
                grad_bytes=n,
                bwd_flops=t,
                fwd_flops=tf / len(tb),
            )
        )
    return out


class TestTimeline:
    def test_naive_ssgd_no_overlap_bound(self):
        """t_iter never exceeds t_f + t_b + t_c (naive S-SGD, Eq. 3)."""
        costs = mk_costs([1.0, 1.0, 1.0], [100, 100, 100], tf=3.0)
        ar = AllReduceModel(a=0.5, b=0.01)
        res = evaluate([(1, 1), (2, 2), (3, 3)], costs, ar, HW)
        t_c = sum(ar(100) for _ in range(3))
        assert res.t_iter <= 3.0 + 3.0 + t_c + 1e-12

    def test_case1_fully_hidden(self):
        """Paper Case 1: t_c(l) <= t_b(l-1) for all l>=2 => only layer 1 exposed."""
        # comm of each layer = 0.5, backward of each layer = 1.0
        costs = mk_costs([1.0] * 4, [1] * 4, tf=1.0)
        ar = AllReduceModel(a=0.25, b=0.25)  # T_ar(1) = 0.5
        res = evaluate([(l, l) for l in range(1, 5)], costs, ar, HW)
        # t_iter = t_f + t_b + t_c(1)  (Eq. 11)
        assert res.t_iter == pytest.approx(1.0 + 4.0 + 0.5)

    def test_case3_comm_bound(self):
        """Paper Case 3: comm dominates; exposed time > 0."""
        costs = mk_costs([0.1] * 4, [100] * 4, tf=0.1)
        ar = AllReduceModel(a=1.0, b=0.01)  # T_ar = 2.0 each
        res = evaluate([(l, l) for l in range(1, 5)], costs, ar, HW)
        # first comm starts at t_f + t_b(4); 4 serialized all-reduces follow
        assert res.t_iter == pytest.approx(0.1 + 0.1 + 4 * 2.0)
        assert res.t_comm_exposed > 0

    def test_merge_reduces_t_iter_when_comm_bound(self):
        costs = mk_costs([0.1] * 4, [100] * 4, tf=0.1)
        ar = AllReduceModel(a=1.0, b=0.01)
        sep = evaluate([(l, l) for l in range(1, 5)], costs, ar, HW)
        merged = evaluate([(1, 4)], costs, ar, HW)
        assert merged.t_iter < sep.t_iter

    def test_partition_validation(self):
        costs = mk_costs([1.0] * 3, [1] * 3)
        ar = AllReduceModel(a=0.1, b=0.1)
        with pytest.raises(ValueError):
            evaluate([(1, 1), (3, 3)], costs, ar, HW)  # gap
        with pytest.raises(ValueError):
            evaluate([(1, 2)], costs, ar, HW)  # missing coverage

    def test_speedup_formula(self):
        costs = mk_costs([1.0] * 2, [10] * 2, tf=2.0)
        ar = AllReduceModel(a=0.5, b=0.05)
        res = evaluate([(1, 1), (2, 2)], costs, ar, HW)
        n = 8
        assert res.speedup(n) == pytest.approx(n * (res.t_f + res.t_b) / res.t_iter)


class TestMergedSetConversion:
    def test_roundtrip_empty(self):
        assert groups_from_merged_set(frozenset(), 4) == ((1, 1), (2, 2), (3, 3), (4, 4))

    def test_roundtrip_all(self):
        assert groups_from_merged_set(frozenset({2, 3, 4}), 4) == ((1, 4),)

    def test_mixed(self):
        # merge 3->2 and 5->4: groups [1],[2,3],[4,5]
        assert groups_from_merged_set(frozenset({3, 5}), 5) == ((1, 1), (2, 3), (4, 5))

    def test_schedule_merged_set_inverse(self):
        s = wfbp_schedule(6)
        assert s.merged_set == frozenset()
        s = synceasgd_schedule(6)
        assert s.merged_set == frozenset(range(2, 7))


class TestAlgorithms:
    def test_wfbp_synceasgd_structure(self):
        assert len(wfbp_schedule(10).groups) == 10
        assert len(synceasgd_schedule(10).groups) == 1

    def test_fixed_bucket(self):
        costs = mk_costs([1.0] * 6, [10, 10, 10, 10, 10, 10])
        s = fixed_bucket_schedule(costs, bucket_bytes=25)
        # filled from layer 6 down: [6,5,4] (30>=25), [3,2,1]
        assert s.groups == ((1, 3), (4, 6))

    def test_mg_wfbp_merges_when_comm_bound(self):
        """High startup cost + tiny layers => MG-WFBP must merge heavily."""
        costs = mk_costs([0.01] * 8, [10] * 8, tf=0.01)
        ar = AllReduceModel(a=1.0, b=1e-4)
        s = mg_wfbp_schedule(costs, ar, HW)
        assert len(s.groups) < 8  # merged something
        assert s.result is not None

    def test_mg_wfbp_keeps_wfbp_when_hidden(self):
        """Comm fully hidden (Case 1) => merging is unnecessary; t_iter equal
        to WFBP's ideal Eq. 11 regardless of the merge set chosen."""
        costs = mk_costs([1.0] * 6, [1] * 6, tf=1.0)
        ar = AllReduceModel(a=0.05, b=0.05)  # T_ar(1) = 0.1 << t_b = 1.0
        s = mg_wfbp_schedule(costs, ar, HW)
        ideal = 1.0 + 6.0 + ar(sum(c.grad_bytes for c in costs[: s.groups[0][1]]))
        assert s.result.t_iter <= 1.0 + 6.0 + ar(6) + 1e-9
        # and not worse than plain WFBP
        w = evaluate([(l, l) for l in range(1, 7)], costs, ar, HW)
        assert s.result.t_iter <= w.t_iter + 1e-9

    def test_mg_wfbp_beats_both_baselines_paper_regime(self):
        """The paper's headline: MG-WFBP <= min(WFBP, SyncEASGD).

        Regime modeled on Fig. 3 Case 3: many small layers + one large."""
        tb = [0.002] * 20 + [0.01] * 4
        nb = [200_000] * 20 + [5_000_000] * 4
        costs = mk_costs(tb, nb, tf=0.02)
        ar = paper_cluster_model(8)
        mg = mg_wfbp_schedule(costs, ar, HW)
        w = evaluate([(l, l) for l in range(1, 25)], costs, ar, HW)
        se = evaluate([(1, 24)], costs, ar, HW)
        assert mg.result.t_iter <= w.t_iter + 1e-12
        assert mg.result.t_iter <= se.t_iter + 1e-12


class TestOptimality:
    """Theorem 1 claims Algorithm 1 is optimal.  Property-testing finds this
    FALSE in general (documented in core/schedule.py); the beyond-paper
    O(L²) DP is exact.  These tests pin both facts."""

    @settings(max_examples=300, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=9),
        data=st.data(),
    )
    def test_dp_matches_exhaustive_exactly(self, n, data):
        tb = data.draw(
            st.lists(
                st.floats(min_value=1e-4, max_value=1.0, allow_nan=False),
                min_size=n,
                max_size=n,
            )
        )
        nb = data.draw(
            st.lists(st.integers(min_value=1, max_value=10_000_000), min_size=n, max_size=n)
        )
        a = data.draw(st.floats(min_value=1e-6, max_value=0.5))
        b = data.draw(st.floats(min_value=1e-12, max_value=1e-6))
        tf = data.draw(st.floats(min_value=0.0, max_value=1.0))
        costs = mk_costs(tb, nb, tf=tf)
        ar = AllReduceModel(a=a, b=b)
        dp = dp_optimal_schedule(costs, ar, HW)
        exact = optimal_schedule(costs, ar, HW)
        assert dp.result.t_iter == pytest.approx(exact.result.t_iter, rel=1e-9, abs=1e-12)
        # greedy never beats the true optimum
        greedy = mg_wfbp_schedule(costs, ar, HW)
        assert greedy.result.t_iter >= dp.result.t_iter - 1e-9

    def test_greedy_suboptimal_counterexample(self):
        """Recorded counterexample to Theorem 1 (found by random search):
        greedy merges too aggressively and delays the tail groups."""
        tb = [
            0.1880362249778715,
            0.9795995162787854,
            0.3657441445657224,
            0.26826409413571534,
            0.4846450910111654,
            0.3350610361256854,
            0.48343216823856044,
            0.03235261717415612,
        ]
        nb = [5_000_000, 9_000_000, 2_000_000, 8_000_000, 1_000_000, 7_000_000, 3_000_000, 6_000_000]
        costs = mk_costs(tb, nb, tf=0.5)
        ar = AllReduceModel(a=0.4, b=5e-7)
        greedy = mg_wfbp_schedule(costs, ar, HW)
        dp = dp_optimal_schedule(costs, ar, HW)
        exact = optimal_schedule(costs, ar, HW)
        assert dp.result.t_iter == pytest.approx(exact.result.t_iter, rel=1e-9)
        # The greedy is measurably worse on at least some instances; on this
        # one it must not be better than optimal (and the suite that found it
        # measured ~24% loss frequency overall).
        assert greedy.result.t_iter >= exact.result.t_iter - 1e-12

    def test_greedy_exact_on_uniform(self):
        """In the paper's own regime (uniform layers) greedy == optimal."""
        costs = mk_costs([0.01] * 8, [1_000_000] * 8, tf=0.05)
        ar = paper_cluster_model(8)
        greedy = mg_wfbp_schedule(costs, ar, HW)
        exact = optimal_schedule(costs, ar, HW)
        assert greedy.result.t_iter == pytest.approx(exact.result.t_iter, rel=1e-9)

    def test_dp_scales_to_many_layers(self):
        import random

        rng = random.Random(7)
        n = 160  # ResNet-50-scale layer count
        tb = [rng.uniform(1e-4, 5e-3) for _ in range(n)]
        nb = [rng.randint(1_000, 5_000_000) for _ in range(n)]
        costs = mk_costs(tb, nb, tf=0.1)
        ar = paper_cluster_model(64)
        dp = dp_optimal_schedule(costs, ar, HW)
        greedy = mg_wfbp_schedule(costs, ar, HW)
        assert dp.result.t_iter <= greedy.result.t_iter + 1e-12


class TestEvaluateSchedule:
    def test_attach_result(self):
        costs = mk_costs([1.0] * 3, [5] * 3, tf=1.0)
        ar = AllReduceModel(a=0.1, b=0.01)
        s = evaluate_schedule(wfbp_schedule(3), costs, ar, HW)
        assert s.result is not None and s.result.t_iter > 0


class TestTimelineCrossValidation:
    """The paper's τ_c recurrences (Eqs. 7/20) and our group-trace
    evaluator are independent implementations — they must agree."""

    @settings(max_examples=100, deadline=None)
    @given(n=st.integers(2, 12), data=st.data())
    def test_wfbp_tau_c_recurrence_matches_evaluate(self, n, data):
        tb = data.draw(st.lists(
            st.floats(min_value=1e-4, max_value=1.0), min_size=n, max_size=n))
        nb = data.draw(st.lists(
            st.integers(min_value=1, max_value=10**7), min_size=n, max_size=n))
        a = data.draw(st.floats(min_value=1e-6, max_value=0.3))
        b = data.draw(st.floats(min_value=1e-12, max_value=1e-6))
        tf = data.draw(st.floats(min_value=0.0, max_value=0.5))
        costs = mk_costs(tb, nb, tf=tf)
        ar = AllReduceModel(a=a, b=b)

        # paper recurrence, 1-based arrays (Eq. 6/7)
        tau_b = [0.0] * (n + 1)
        tau_b[n] = tf
        for l in range(n - 1, 0, -1):
            tau_b[l] = tau_b[l + 1] + tb[l]  # t_b of layer l+1 is tb[l] 0-based
        tau_c = [0.0] * (n + 1)
        tau_c[n] = tau_b[n] + tb[n - 1]
        for l in range(n - 1, 0, -1):
            tau_c[l] = max(tau_c[l + 1] + ar(nb[l]), tau_b[l] + tb[l - 1])
        t_iter_paper = tau_c[1] + ar(nb[0])

        res = evaluate([(l, l) for l in range(1, n + 1)], costs, ar, HW)
        assert res.t_iter == pytest.approx(max(t_iter_paper, tf + sum(tb)), rel=1e-9)
