"""Continuous-batching serving engine: correctness against single-request
decoding and slot reuse."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.transformer import init_params
from repro.serving import Request, ServingEngine


def make_engine(slots=2, max_seq=64):
    cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServingEngine(cfg, params, slots=slots, max_seq=max_seq)


def test_single_request_matches_dedicated_engine():
    """Two engines, one request each vs one engine with both: same outputs
    (batch slots must be independent)."""
    prompt_a = np.arange(10, 18, dtype=np.int32)
    prompt_b = np.arange(40, 48, dtype=np.int32)

    cfg, params, eng_both = make_engine(slots=2)
    eng_both.submit(Request(rid=1, prompt=prompt_a, max_new_tokens=6))
    eng_both.submit(Request(rid=2, prompt=prompt_b, max_new_tokens=6))
    done = {r.rid: r.generated for r in eng_both.run_to_completion()}

    for rid, prompt in ((1, prompt_a), (2, prompt_b)):
        _, _, eng_solo = make_engine(slots=1)
        eng_solo.submit(Request(rid=rid, prompt=prompt, max_new_tokens=6))
        solo = eng_solo.run_to_completion()[0].generated
        assert done[rid] == solo, f"request {rid}: batched != solo"


def test_slot_reuse_after_completion():
    prompts = [np.arange(i, i + 8, dtype=np.int32) for i in (0, 16, 32)]
    cfg, params, eng = make_engine(slots=2)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = eng.run_to_completion()
    assert len(done) == 3  # third request reused a freed slot
    assert all(len(r.generated) == 4 for r in done)
