"""Continuous-batching serving engine: correctness against single-request
decoding, slot reuse, and the dispatch-free-loop invariants (empty-step
no-op, retirement as a masked write, bucketed admission compile counts)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.transformer import init_params
from repro.serving import Request, ServingEngine


def make_engine(slots=2, max_seq=64):
    cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"), param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServingEngine(cfg, params, slots=slots, max_seq=max_seq)


def test_single_request_matches_dedicated_engine():
    """Two engines, one request each vs one engine with both: same outputs
    (batch slots must be independent)."""
    prompt_a = np.arange(10, 18, dtype=np.int32)
    prompt_b = np.arange(40, 48, dtype=np.int32)

    cfg, params, eng_both = make_engine(slots=2)
    eng_both.submit(Request(rid=1, prompt=prompt_a, max_new_tokens=6))
    eng_both.submit(Request(rid=2, prompt=prompt_b, max_new_tokens=6))
    done = {r.rid: r.generated for r in eng_both.run_to_completion()}

    for rid, prompt in ((1, prompt_a), (2, prompt_b)):
        _, _, eng_solo = make_engine(slots=1)
        eng_solo.submit(Request(rid=rid, prompt=prompt, max_new_tokens=6))
        solo = eng_solo.run_to_completion()[0].generated
        assert done[rid] == solo, f"request {rid}: batched != solo"


def test_slot_reuse_after_completion():
    prompts = [np.arange(i, i + 8, dtype=np.int32) for i in (0, 16, 32)]
    cfg, params, eng = make_engine(slots=2)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = eng.run_to_completion()
    assert len(done) == 3  # third request reused a freed slot
    assert all(len(r.generated) == 4 for r in done)


def test_empty_step_is_a_noop():
    """With nothing admitted, ``step`` returns 0 and compiles nothing:
    no decode executable, no dispatch, no collective."""
    _, _, eng = make_engine(slots=2)
    assert eng.step() == 0
    assert eng.step() == 0
    stats = eng.compile_stats()
    assert stats["decode"] == 0
    assert stats["admit"] == {}
    assert stats["prefill"] == 0


def test_mid_bucket_retirement_keeps_tokens_identical():
    """A row retiring mid-batch is a masked mask-flip, not a reshape: the
    surviving row's tokens match a solo decode exactly, and the decode
    executable never recompiles across the retirement."""
    prompt_short = np.arange(10, 18, dtype=np.int32)
    prompt_long = np.arange(40, 48, dtype=np.int32)

    cfg, params, eng = make_engine(slots=2)
    eng.submit(Request(rid=1, prompt=prompt_short, max_new_tokens=3))
    eng.submit(Request(rid=2, prompt=prompt_long, max_new_tokens=9))
    done = {r.rid: r.generated for r in eng.run_to_completion()}
    assert len(done[1]) == 3 and len(done[2]) == 9
    assert eng.compile_stats()["decode"] == 1  # zero retraces across retirement

    _, _, solo = make_engine(slots=1)
    solo.submit(Request(rid=2, prompt=prompt_long, max_new_tokens=9))
    assert done[2] == solo.run_to_completion()[0].generated


def test_bucket_boundary_compiles_at_most_one_new_executable():
    """Crossing an admission batch-bucket boundary (1-wide join vs a
    multi-row join) compiles at most one new admit executable; the decode
    executable stays at exactly one throughout."""
    cfg, params, eng = make_engine(slots=4)
    eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=6))
    eng.step()  # 1-row admission: bucket 1
    stats1 = eng.compile_stats()
    assert stats1["admit"] == {1: 1}
    assert stats1["decode"] == 1
    for rid in (1, 2, 3):  # 3-row admission on the free slots: bucket 4
        eng.submit(Request(rid=rid, prompt=np.arange(rid, rid + 8, dtype=np.int32),
                           max_new_tokens=6))
    eng.step()
    stats2 = eng.compile_stats()
    assert stats2["admit"] == {1: 1, 4: 1}  # exactly one new bucket
    assert stats2["decode"] == 1
    done = eng.run_to_completion()
    assert len(done) == 4
    # the whole run, joins and all, still holds the one-executable line
    assert eng.compile_stats()["decode"] == 1
    assert eng.compile_stats()["admit"] == {1: 1, 4: 1}
