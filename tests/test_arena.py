"""Arena wire path + measured-comm fitting.

Covers the PR-2 tentpole end to end:

  * pack→unpack numeric round-trip (oracle vs Pallas-interpret), incl.
    scan-stacked slices, odd-sized tails, and the fused error-feedback
    residual;
  * the plan-time ``group_arenas`` layout: exact packing (zero padding),
    offsets/sizes, scan-slice shapes;
  * lowered-HLO invariants for all three fuse modes: exact all-reduce op
    AND byte counts (``profiler.parse_collectives`` on stablehlo), zero
    concatenate ops on the arena path, bf16 halving wire bytes;
  * seeded ``MeasuredComm`` α–β fit recovery;
  * plan-aware checkpointing: the plan JSON rides beside the weights.
"""

import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _env import REPO_ROOT, SUBPROC_ENV

import jax
import jax.numpy as jnp

from repro.core import (
    AllReduceModel,
    fit_affine,
    group_arenas,
    parse_collectives,
    stacked_lm_layout,
)
from repro.core.sync import SyncConfig, make_gradient_sync
from repro.kernels.comm_pack import pack_arena, unpack_arena
from repro.planning import MeasuredComm, build_schedule
from repro.runtime import bf16_ef_encode


def _parts(seed=0, shapes=((3, 5), (7,), (2, 2, 3), (1,), (11,))):
    rng = np.random.default_rng(seed)
    parts = [jnp.asarray(rng.standard_normal(s), jnp.float32) for s in shapes]
    sizes = [int(np.prod(s)) for s in shapes]
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).tolist()
    return parts, offsets, sizes, sum(sizes)


class TestPackUnpack:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_round_trip_ref_vs_pallas(self, dtype):
        parts, offsets, sizes, total = self._setup()
        a_ref, _ = pack_arena(parts, offsets, total, dtype, use_pallas=False)
        a_pal, _ = pack_arena(parts, offsets, total, dtype, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(a_ref, np.float32), np.asarray(a_pal, np.float32)
        )
        slots = list(zip(offsets, sizes))
        shapes = [p.shape for p in parts]
        dts = [p.dtype for p in parts]
        out_r = unpack_arena(a_ref, slots, shapes, dts, scale=0.25, use_pallas=False)
        out_p = unpack_arena(a_pal, slots, shapes, dts, scale=0.25, interpret=True)
        for r, p, orig in zip(out_r, out_p, parts):
            assert r.shape == orig.shape and r.dtype == orig.dtype
            np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
        if dtype == jnp.float32:  # lossless: unpack(pack(x)) * 4 == x
            for r, orig in zip(out_r, parts):
                np.testing.assert_allclose(
                    np.asarray(r) * 4.0, np.asarray(orig), rtol=1e-6
                )

    @staticmethod
    def _setup():
        # odd sizes on purpose: 15, 7, 12, 1, 11 — tails never tile-align
        return _parts()

    @pytest.mark.parametrize("ef", [False, True])
    def test_multi_chunk_pipeline_matches_oracle(self, ef):
        """Shrinking ``chunk`` below the part sizes forces the
        double-buffered DMA pipeline (warm-up + cross-chunk slot reuse,
        odd tails) — results must stay bit-identical to the oracle."""
        parts, offsets, sizes, total = _parts(
            seed=3, shapes=((40, 25), (37,), (250, 10), (1,), (1001,))
        )
        rng = np.random.default_rng(4)
        res = (
            [jnp.asarray(rng.standard_normal(p.shape) * 1e-3, jnp.float32)
             for p in parts]
            if ef else None
        )
        a_ref, r_ref = pack_arena(
            parts, offsets, total, jnp.bfloat16, residuals=res, use_pallas=False
        )
        # chunk=256: parts span 4, 1, 10, 1, 4 chunks with ragged tails
        a_pal, r_pal = pack_arena(
            parts, offsets, total, jnp.bfloat16, residuals=res,
            interpret=True, chunk=256,
        )
        np.testing.assert_array_equal(
            np.asarray(a_ref, np.float32), np.asarray(a_pal, np.float32)
        )
        if ef:
            for rr, rp in zip(r_ref, r_pal):
                np.testing.assert_array_equal(np.asarray(rr), np.asarray(rp))
        slots = list(zip(offsets, sizes))
        shapes = [p.shape for p in parts]
        dts = [p.dtype for p in parts]
        o_ref = unpack_arena(a_ref, slots, shapes, dts, scale=0.5, use_pallas=False)
        o_pal = unpack_arena(a_pal, slots, shapes, dts, scale=0.5,
                             interpret=True, chunk=256)
        for r, p in zip(o_ref, o_pal):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(p))

    def test_error_feedback_matches_compression_oracle(self):
        parts, offsets, sizes, total = self._setup()
        rng = np.random.default_rng(1)
        res = [jnp.asarray(rng.standard_normal(p.shape) * 1e-3, jnp.float32)
               for p in parts]
        for kw in ({"use_pallas": False}, {"interpret": True}):
            arena, new_res = pack_arena(
                parts, offsets, total, jnp.bfloat16, residuals=res, **kw
            )
            for p, r0, r1, off, n in zip(parts, res, new_res, offsets, sizes):
                wire_want, res_want = bf16_ef_encode(p, r0)
                np.testing.assert_array_equal(
                    np.asarray(arena[off : off + n], np.float32),
                    np.asarray(wire_want, np.float32).reshape(-1),
                )
                assert r1.shape == p.shape
                np.testing.assert_allclose(
                    np.asarray(r1), np.asarray(res_want), atol=1e-7
                )
                # EF identity: wire + residual reconstructs the accumulator
                np.testing.assert_allclose(
                    np.asarray(arena[off : off + n], np.float32).reshape(p.shape)
                    + np.asarray(r1),
                    np.asarray(p) + np.asarray(r0),
                    atol=1e-7,
                )


def _toy_layout(n_stages=4):
    shapes = {
        "embed": {"tok": jnp.zeros((32, 16))},
        "stages": {
            "w1": jnp.zeros((n_stages, 16, 16)),
            "w2": jnp.zeros((n_stages, 16)),
        },
        "final_norm": {"scale": jnp.zeros((16,))},
        "head": {"w": jnp.zeros((16, 33))},  # odd tail
    }
    return shapes, stacked_lm_layout(shapes, n_stages)


class TestGroupArenas:
    def test_exact_packing_and_scan_slices(self):
        shapes, layout = _toy_layout()
        costs = layout.layer_costs(1024, None)
        # merge everything -> one arena with leaf + multi-stage slice slots
        sched = build_schedule("single", costs, AllReduceModel(a=1e-3, b=1e-9))
        (arena,) = group_arenas(layout, sched, shapes, jnp.bfloat16)
        assert arena.comm_dtype == "bfloat16"
        # exact packing: no padding, contiguous offsets
        off = 0
        for slot in arena.slots:
            assert slot.offset == off
            assert slot.size == int(np.prod(slot.shape))
            off += slot.size
        assert arena.size == off
        assert arena.nbytes == arena.size * 2
        total_params = 32 * 16 + 4 * (16 * 16 + 16) + 16 + 16 * 33
        assert arena.size == total_params
        # the scan slice spans all four stages with the sliced leading axis
        slices = [s for s in arena.slots if s.kind == "slice"]
        assert {s.stack_range for s in slices} == {(0, 4)}
        assert {s.shape[0] for s in slices} == {4}

    def test_plan_exposes_arena_layout(self):
        from repro.planning import build_plan

        shapes, layout = _toy_layout()
        costs = layout.layer_costs(1024, None)
        plan = build_plan(
            layout, costs, AllReduceModel(a=1e-3, b=1e-9), n_scan_stages=4
        )
        via_plan = plan.group_arenas(shapes, jnp.bfloat16)
        direct = group_arenas(layout, plan.schedule, shapes, jnp.bfloat16)
        assert via_plan == direct
        assert len(via_plan) == len(plan.schedule.groups)

    def test_shapeless_leaves_rejected(self):
        shapes, layout = _toy_layout()
        costs = layout.layer_costs(1024, None)
        sched = build_schedule("single", costs, AllReduceModel(a=1e-3, b=1e-9))
        bad = jax.tree.map(lambda x: tuple(x.shape), shapes)  # tuples, not arrays
        with pytest.raises(TypeError, match="has no .shape"):
            group_arenas(layout, sched, bad)

    def test_per_group_arenas_cover_per_tensor_schedule(self):
        shapes, layout = _toy_layout()
        costs = layout.layer_costs(1024, None)
        sched = build_schedule("per_tensor", costs, AllReduceModel(a=1e-9, b=1e-12))
        arenas = layout.group_arenas(sched, shapes)  # ParamLayout method
        assert len(arenas) == len(sched.groups)
        assert sum(a.size for a in arenas) == 32 * 16 + 4 * (16 * 16 + 16) + 16 + 16 * 33
        # stage groups are singleton slices [i, i+1)
        stage_arenas = [a for a in arenas if a.slots[0].kind == "slice"]
        assert len(stage_arenas) == 4
        for a in stage_arenas:
            assert all(s.stack_range[1] - s.stack_range[0] == 1 for s in a.slots)


ARENA_LOWERING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core import (
        AllReduceModel, SyncConfig, count_expected_allreduces,
        make_gradient_sync, parse_collectives, stacked_lm_layout,
    )
    from repro.planning import build_schedule

    n_stages = 4
    shapes = {
        "embed": {"tok": jnp.zeros((32, 16))},
        "stages": {"w1": jnp.zeros((n_stages, 16, 16)), "w2": jnp.zeros((n_stages, 16))},
        "final_norm": {"scale": jnp.zeros((16,))},
        "head": {"w": jnp.zeros((16, 33))},
    }
    layout = stacked_lm_layout(shapes, n_stages)
    costs = layout.layer_costs(1024, None)
    mesh = make_mesh((8,), ("data",))
    key = jax.random.PRNGKey(0)
    grads = jax.tree.map(
        lambda s: jax.random.normal(jax.random.fold_in(key, s.size), s.shape), shapes
    )
    n_elems = sum(x.size for x in jax.tree.leaves(grads))

    out = []
    for policy in ("per_tensor", "single", "bucketed"):
        sched = build_schedule(policy, costs, AllReduceModel(a=1e-3, b=1e-9))
        rec = {"policy": policy, "n_groups": len(sched.groups)}
        for fuse in ("concat", "variadic", "arena"):
            for comp in (None, "bf16"):
                cfgs = SyncConfig(fuse=fuse, compression=comp)
                sync = make_gradient_sync(layout, sched, ("data",), cfgs)

                def body(g):
                    r = jax.lax.axis_index("data").astype(jnp.float32)
                    return sync(jax.tree.map(lambda x: x * (r + 1.0), g))

                f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                                      axis_names={"data"}, check_vma=False))
                stats = parse_collectives(f.lower(grads).as_text())
                got = f(grads)
                expect = jax.tree.map(lambda x: 4.5 * x, grads)
                diff = max(jax.tree.leaves(jax.tree.map(
                    lambda a, b: float(jnp.max(jnp.abs(a - b))), got, expect)))
                rec[f"{fuse}_{comp or 'f32'}"] = {
                    "allreduce_ops": stats.counts.get("all-reduce", 0),
                    "expected": count_expected_allreduces(sched, cfgs, layout),
                    "wire_bytes": stats.bytes_by_kind.get("all-reduce", 0),
                    "concat_ops": stats.concat_ops,
                    "max_diff": diff,
                }
        rec["n_elems"] = int(n_elems)
        out.append(rec)

    # stateful error-feedback arena mode
    sched = build_schedule("bucketed", costs, AllReduceModel(a=1e-3, b=1e-9))
    cfgs = SyncConfig(fuse="arena", compression="bf16_ef")
    sync = make_gradient_sync(layout, sched, ("data",), cfgs)
    res0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), grads)

    def body_ef(g, r):
        return sync(g, r)

    f = jax.jit(shard_map(body_ef, mesh=mesh, in_specs=(P(), P()),
                          out_specs=(P(), P()), axis_names={"data"}, check_vma=False))
    stats = parse_collectives(f.lower(grads, res0).as_text())
    o, r1 = f(grads, res0)
    # identical ranks: avg == bf16 value, so out + residual == grads exactly
    rec_ef = {
        "allreduce_ops": stats.counts.get("all-reduce", 0),
        "n_groups": len(sched.groups),
        "concat_ops": stats.concat_ops,
        "recon_diff": max(jax.tree.leaves(jax.tree.map(
            lambda a, b, c: float(jnp.max(jnp.abs(a + b - c))), o, r1, grads))),
    }
    print(json.dumps({"cases": out, "ef": rec_ef}))
""")


def test_arena_lowering_op_and_byte_counts():
    """Acceptance: ``fuse='arena'`` lowers to exactly one all-reduce HLO op
    per schedule group with ZERO concatenate ops, at exactly the concat
    layout's wire bytes (half of them under bf16) — per policy, via
    ``profiler.parse_collectives``."""
    out = subprocess.run(
        [sys.executable, "-c", ARENA_LOWERING_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env=SUBPROC_ENV, cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    for rec in payload["cases"]:
        n_groups, n_elems = rec["n_groups"], rec["n_elems"]
        for comp, itemsize in (("f32", 4), ("bf16", 2)):
            arena = rec[f"arena_{comp}"]
            concat = rec[f"concat_{comp}"]
            variadic = rec[f"variadic_{comp}"]
            # arena: one op per group, zero concatenates, exact bytes
            assert arena["allreduce_ops"] == n_groups, rec
            assert arena["expected"] == n_groups, rec
            assert arena["concat_ops"] == 0, rec
            assert arena["wire_bytes"] == n_elems * itemsize, rec
            # byte parity with concat, and bf16 halves the wire exactly
            assert arena["wire_bytes"] <= concat["wire_bytes"], rec
            assert concat["allreduce_ops"] == n_groups, rec
            # variadic stays zero-copy but op counts are version-dependent
            assert variadic["concat_ops"] == 0, rec
            assert variadic["allreduce_ops"] == variadic["expected"], rec
            for fuse in ("arena", "concat", "variadic"):
                tol = 1e-4 if comp == "f32" else 0.1
                assert rec[f"{fuse}_{comp}"]["max_diff"] < tol, (fuse, comp, rec)
        assert rec["arena_bf16"]["wire_bytes"] * 2 == rec["concat_f32"]["wire_bytes"], rec
    ef = payload["ef"]
    assert ef["allreduce_ops"] == ef["n_groups"]
    assert ef["concat_ops"] == 0
    assert ef["recon_diff"] == pytest.approx(0.0, abs=1e-6)


class TestMeasuredComm:
    def test_fit_recovers_synthetic_alpha_beta(self):
        rng = np.random.default_rng(42)
        a, b = 4.5e-5, 1.0 / 1.07e9  # the paper's 10GbE constants
        sizes = tuple(4096 * 8**i for i in range(6))
        times = tuple(a + b * s + float(rng.normal(0, 2e-7)) for s in sizes)
        fit = MeasuredComm(sizes_bytes=sizes, times_s=times, axes=("data",)).fit()
        assert fit.a == pytest.approx(a, rel=0.05)
        assert fit.b == pytest.approx(b, rel=0.05)
        assert fit.name == "measured_comm[data]"
        # merge gain is the recovered α (Eq. 10)
        assert fit.merged_gain(1e6, 2e6) == pytest.approx(fit.a)

    def test_fit_clamps_negative_intercept(self):
        m = fit_affine([100, 200, 300], [1e-6, 3e-6, 5e-6])
        assert m.a >= 0.0 and m.b > 0.0

    def test_fit_rejects_degenerate_sweep(self):
        with pytest.raises(ValueError, match="pairs"):
            fit_affine([100], [1e-6])

    def test_live_sweep_on_host_mesh(self):
        from repro.compat import make_mesh

        mesh = make_mesh((1,), ("data",))
        m = MeasuredComm.time_psums(
            mesh, ("data",), sizes_bytes=(4096, 65536, 1 << 20), repeats=1
        )
        assert len(m.times_s) == 3 and all(t > 0 for t in m.times_s)
        fit = m.fit()  # fits and is a usable AllReduceModel
        assert fit(1 << 20) >= fit(4096) >= 0.0

    def test_measured_model_drives_planning_transparently(self):
        _, layout = _toy_layout()
        costs = layout.layer_costs(1024, None)
        fit = fit_affine(
            [4096, 65536, 1 << 20], [5e-5 + s / 1e9 for s in (4096, 65536, 1 << 20)],
            name="measured_comm[data]",
        )
        sched = build_schedule("mg_wfbp", costs, fit)
        assert sched.result is not None and len(sched.groups) >= 1


class TestPlanAwareCheckpoint:
    def test_plan_rides_beside_weights(self, tmp_path):
        from repro.checkpoint import load_plan, restore, save
        from repro.core import layout_for_stacked_lm
        from repro.planning import build_plan

        layout = layout_for_stacked_lm(4, 5000, 3000, 7000)
        costs = layout.layer_costs(tokens_per_chip=64, hw=None)
        plan = build_plan(
            layout, costs, AllReduceModel(a=1e-3, b=1e-9), n_scan_stages=4
        )
        tree = {"w": np.arange(6, dtype=np.float32)}
        save(tmp_path, 7, tree, extra={"k": 1}, plan=plan)
        got = load_plan(tmp_path, 7)
        assert got == plan
        restored, extra = restore(tmp_path, 7, tree)
        assert extra == {"k": 1}
        np.testing.assert_array_equal(restored["w"], tree["w"])

    def test_missing_plan_is_none(self, tmp_path):
        from repro.checkpoint import load_plan, save

        save(tmp_path, 3, {"w": np.zeros(2, np.float32)})
        assert load_plan(tmp_path, 3) is None

    def test_async_checkpointer_snapshots_plan(self, tmp_path):
        from repro.checkpoint import AsyncCheckpointer, load_plan
        from repro.core import layout_for_stacked_lm
        from repro.planning import build_plan

        layout = layout_for_stacked_lm(2, 100, 100, 100)
        costs = layout.layer_costs(tokens_per_chip=8, hw=None)
        plan = build_plan(layout, costs, AllReduceModel(a=1e-4, b=1e-9))
        ck = AsyncCheckpointer(tmp_path)
        ck.save(5, {"w": np.ones(3, np.float32)}, plan=plan)
        ck.wait()
        assert load_plan(tmp_path, 5) == plan


class TestCompatProbe:
    def test_variadic_probe_cached_and_consistent(self):
        from repro.compat import variadic_psum_is_single_op

        first = variadic_psum_is_single_op()
        assert variadic_psum_is_single_op() is first  # functools.cache
        assert variadic_psum_is_single_op.cache_info().hits >= 1
        # on this container's jax (0.4.x) the version gate answers False
        # without lowering; on modern jax the probe must agree with the
        # shard_map feature boundary either way
        assert isinstance(first, bool)

    def test_sync_rejects_bad_modes(self):
        shapes, layout = _toy_layout()
        costs = layout.layer_costs(1024, None)
        sched = build_schedule("single", costs, AllReduceModel(a=1e-3, b=1e-9))
        with pytest.raises(ValueError, match="unknown fuse"):
            make_gradient_sync(layout, sched, ("data",), SyncConfig(fuse="nope"))
        with pytest.raises(ValueError, match="requires fuse='arena'"):
            make_gradient_sync(
                layout, sched, ("data",),
                SyncConfig(fuse="concat", compression="bf16_ef"),
            )
