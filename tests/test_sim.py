"""What-if simulator (``repro.sim``): the deterministic event core, the
ClusterSpec fleet geometry, the DES == ``core.timeline.evaluate``
exactness invariant, straggler/elastic/serve replay semantics, the
hierarchical tree/pipeline fabrics, calibration against the committed
BENCH records, the paper's scaling-efficiency ordering, and the
byte-deterministic ``SimReport`` artifact."""

import json
import math

import pytest

from repro.configs.cnn_profiles import cnn_layer_costs
from repro.core.comm_model import binary_tree
from repro.core.cost_model import K80_CALIBRATED
from repro.core.timeline import evaluate
from repro.fabric import Collective, get_fabric
from repro.planning.registry import build_schedule
from repro.sim import (
    MAX_HOSTS,
    ClusterEvent,
    ClusterSpec,
    EventQueue,
    SimReport,
    calibrate_serve,
    calibrate_train,
    replay_serve,
    replay_train,
    row_from_replay,
    simulate_train_iteration,
)


def _paper_cell(arch="googlenet", batch=64, n=8):
    """(costs, ar_model) for one paper-cluster cell."""
    costs = cnn_layer_costs(arch, batch)
    ar = ClusterSpec(n_hosts=n, fabric="paper_10gbe").ar_model()
    return costs, ar


class TestEventQueue:
    def test_orders_by_time_then_insertion(self):
        q = EventQueue()
        q.push(2.0, "late")
        q.push(1.0, "tie_first", tag=1)
        q.push(1.0, "tie_second", tag=2)
        kinds = [q.pop().kind for _ in range(3)]
        assert kinds == ["tie_first", "tie_second", "late"]
        assert (q.pushed, q.popped) == (3, 3)

    def test_negative_time_rejected(self):
        with pytest.raises(ValueError, match="negative"):
            EventQueue().push(-1e-9, "bad")

    def test_empty_pop_raises(self):
        with pytest.raises(IndexError):
            EventQueue().pop()

    def test_time_regression_is_a_bug_not_a_race(self):
        q = EventQueue()
        q.push(1.0, "a")
        q.pop()
        q.push(0.5, "past")
        with pytest.raises(RuntimeError, match="before now"):
            q.pop()


class TestClusterSpec:
    def test_flat_and_two_tier_axes(self):
        flat = ClusterSpec(n_hosts=8)
        assert flat.axis_sizes() == {"data": 8}
        tiered = ClusterSpec(n_hosts=64, ici_size=16)
        assert tiered.axis_sizes() == {"data": 16, "pod": 4}
        # shrink below one domain collapses back to a flat fast tier
        assert tiered.axis_sizes(12) == {"data": 12}

    def test_bench_geometry_prices_like_the_committed_sweep(self):
        """The calibration cluster's ar model IS the benchmark's
        tpu_psum_model({'pod': 2, 'data': 16}) — same floats."""
        from repro.core import tpu_psum_model

        spec = ClusterSpec(n_hosts=32, ici_size=16, fabric="tpu_v5e")
        got = spec.ar_model()
        ref = tpu_psum_model({"pod": 2, "data": 16})
        assert (got.a, got.b) == (ref.a, ref.b)

    def test_json_round_trip_exact(self):
        spec = ClusterSpec(
            n_hosts=64, ici_size=16, fabric="pipeline_10gbe",
            straggler_spread=0.3, seed=7,
            events=(ClusterEvent(at_iter=2, kind="kill", count=4),),
        )
        rt = ClusterSpec.from_json(spec.to_json())
        assert rt == spec
        assert rt.to_json() == spec.to_json()  # byte-stable too

    def test_validation(self):
        with pytest.raises(ValueError):
            ClusterSpec(n_hosts=0)
        with pytest.raises(ValueError):
            ClusterSpec(n_hosts=MAX_HOSTS + 1)
        with pytest.raises(ValueError, match="kind"):
            ClusterEvent(at_iter=0, kind="explode")
        with pytest.raises(ValueError):
            ClusterEvent(at_iter=-1, kind="kill")

    def test_straggler_draw_seeded_and_stable_across_shrink(self):
        spec = ClusterSpec(n_hosts=8, straggler_spread=0.5, seed=3)
        m8 = spec.straggler_multipliers()
        assert m8 == spec.straggler_multipliers()  # pure function of seed
        assert all(1.0 <= m <= 1.5 for m in m8)
        assert len(set(m8)) > 1  # actually heterogeneous
        # host i keeps its multiplier when the fleet shrinks
        assert spec.straggler_multipliers(5) == m8[:5]
        homog = ClusterSpec(n_hosts=8)
        assert homog.straggler_multipliers() == (1.0,) * 8

    def test_alive_after_applies_events_in_order(self):
        spec = ClusterSpec(
            n_hosts=16,
            events=(ClusterEvent(at_iter=1, kind="shrink", count=8),
                    ClusterEvent(at_iter=3, kind="grow", count=4),
                    ClusterEvent(at_iter=5, kind="kill", count=2)),
        )
        assert spec.alive_after(0) == (16, 0)
        assert spec.alive_after(1) == (8, 0)
        assert spec.alive_after(3) == (12, 0)
        assert spec.alive_after(5) == (10, 2)
        # a kill storm can never drop the fleet below one host
        doomed = ClusterSpec(
            n_hosts=2, events=(ClusterEvent(at_iter=0, kind="kill", count=99),))
        assert doomed.alive_after(0) == (1, 99)


class TestExactnessInvariant:
    """With homogeneous multipliers the DES is not 'close to' the analytic
    timeline — it is the same floats, trace row by trace row.  This is
    the invariant the calibration layer leans on."""

    @pytest.mark.parametrize("policy", ["synceasgd", "wfbp", "mg_wfbp"])
    def test_des_matches_evaluate_bit_for_bit(self, policy):
        costs, ar = _paper_cell()
        sched = build_schedule(policy, list(costs), ar, hw=K80_CALIBRATED)
        ref = evaluate(list(sched.groups), list(costs), ar, hw=K80_CALIBRATED)
        sim = simulate_train_iteration(
            sched.groups, list(costs), ar, hw=K80_CALIBRATED,
            multipliers=(1.0,) * 8)
        assert sim.t_iter == ref.t_iter  # == on floats, deliberately
        assert sim.groups == tuple(ref.groups)
        assert sim.n_events == 8 * len(sched.groups)

    def test_multiplier_validation(self):
        costs, ar = _paper_cell()
        with pytest.raises(ValueError, match="at least one"):
            simulate_train_iteration([(1, len(costs))], list(costs), ar,
                                     hw=K80_CALIBRATED, multipliers=())
        with pytest.raises(ValueError, match=">= 1"):
            simulate_train_iteration([(1, len(costs))], list(costs), ar,
                                     hw=K80_CALIBRATED, multipliers=(0.5,))


class TestStragglers:
    def test_slowest_host_sets_the_compute_wall(self):
        costs, ar = _paper_cell()
        sched = build_schedule("mg_wfbp", list(costs), ar, hw=K80_CALIBRATED)
        base = simulate_train_iteration(sched.groups, list(costs), ar,
                                        hw=K80_CALIBRATED)
        slow = simulate_train_iteration(sched.groups, list(costs), ar,
                                        hw=K80_CALIBRATED,
                                        multipliers=(1.0, 1.0, 1.4))
        assert slow.t_compute == pytest.approx(1.4 * (base.t_f + base.t_b))
        assert slow.t_iter >= base.t_iter
        # efficiency is judged against the *baseline* compute (Eq. 4), so
        # straggling shows up as lost efficiency, not a moved goalpost
        assert slow.scaling_efficiency < base.scaling_efficiency

    def test_t_iter_monotone_in_spread(self):
        costs = cnn_layer_costs("googlenet", 64)
        t = [
            replay_train(
                ClusterSpec(n_hosts=16, fabric="paper_10gbe",
                            straggler_spread=s, seed=5),
                list(costs), "mg_wfbp", hw=K80_CALIBRATED,
            ).mean_t_iter
            for s in (0.0, 0.25, 0.5)
        ]
        assert t[0] <= t[1] <= t[2]


class TestElasticReplay:
    def test_transitions_reprice_and_replan(self):
        costs = cnn_layer_costs("googlenet", 64)
        spec = ClusterSpec(
            n_hosts=64, fabric="paper_10gbe",
            events=(ClusterEvent(at_iter=1, kind="shrink", count=56),
                    ClusterEvent(at_iter=3, kind="grow", count=56)),
        )
        res = replay_train(spec, list(costs), "mg_wfbp",
                           hw=K80_CALIBRATED, n_iters=5)
        assert [r["n_alive"] for r in res.iterations] == [64, 8, 8, 64, 64]
        assert res.n_replans == 2
        assert [r["replanned"] for r in res.iterations] == [
            False, True, False, True, False]
        # 8-host comm is strictly cheaper than 64-host on the same policy:
        # the ring startup scales with N, and the merge set re-fits
        by_alive = {r["n_alive"]: r for r in res.iterations}
        assert by_alive[8]["t_iter_s"] < by_alive[64]["t_iter_s"]

    def test_kills_are_tallied(self):
        costs = cnn_layer_costs("googlenet", 64)
        spec = ClusterSpec(
            n_hosts=8, events=(ClusterEvent(at_iter=1, kind="kill", count=3),))
        res = replay_train(spec, list(costs), "wfbp",
                           hw=K80_CALIBRATED, n_iters=2)
        assert res.n_kills == 3
        assert res.iterations[-1]["n_alive"] == 5


class TestServeReplay:
    def _load(self, n=8, tokens=16, deadline=None):
        from repro.serving.fleet import LoadSpec

        return LoadSpec(n_requests=n, prompt_len=1, max_new_tokens=tokens,
                        kind="trace", trace_arrivals_s=(0.0,) * n,
                        deadline_s=deadline, seed=0)

    def test_deterministic_and_token_conserving(self):
        a = replay_serve(self._load(), 0.01, n_replicas=2, slots=2)
        b = replay_serve(self._load(), 0.01, n_replicas=2, slots=2)
        assert a == b
        assert a.completed == 8 and a.shed == a.lost == 0
        assert a.tokens_emitted == 8 * 16

    def test_slot_bound_admission(self):
        """2 slots x 1 replica x 8 requests of 16 tokens: at most 2 tokens
        per step, so >= 64 steps — no mid-step free riders."""
        one = replay_serve(self._load(), 0.01, n_replicas=1, slots=2)
        assert one.steps >= 64
        assert one.duration_s == pytest.approx(one.steps * 0.01)

    def test_kill_fails_over_with_progress_preserved(self):
        sv = replay_serve(self._load(), 0.01, n_replicas=2, slots=4,
                          kill_at_s={0: 0.035})
        assert sv.failovers >= 1
        assert sv.completed == 8 and sv.lost == 0
        # work is conserved: the survivor finishes every request
        assert sv.tokens_emitted <= 8 * 16  # kill may eat an in-flight step
        solo = replay_serve(self._load(), 0.01, n_replicas=1, slots=4)
        assert sv.duration_s >= solo.duration_s * 0.5  # sanity, not perf

    def test_all_replicas_dead_loses_requests(self):
        sv = replay_serve(self._load(n=4), 0.01, n_replicas=1, slots=4,
                          kill_at_s={0: 0.005})
        assert sv.lost + sv.failovers >= 1
        assert sv.completed < 4

    def test_deadline_sheds_at_admission(self):
        sv = replay_serve(self._load(deadline=1e-9), 0.01,
                          n_replicas=2, slots=2)
        assert sv.shed == 8 and sv.completed == 0 and sv.tokens_emitted == 0

    def test_bad_step_rejected(self):
        with pytest.raises(ValueError, match="step_s"):
            replay_serve(self._load(), 0.0)


class TestHierarchicalFabrics:
    def test_tree_startup_is_log_n(self):
        f = get_fabric("tree_10gbe")
        for n in (8, 64, 512):
            got = f.cost(Collective.ALL_REDUCE, {"data": n})
            ref = binary_tree(n, f.ici_alpha, 1.0 / f.ici_link_bw, f.gamma)
            assert got.a == pytest.approx(ref.a, rel=1e-12)
            assert got.b == pytest.approx(ref.b, rel=1e-12)

    def test_pipeline_beats_ring_startup_and_tree_bandwidth_at_512(self):
        ring = get_fabric("paper_10gbe").cost("all_reduce", {"data": 512})
        tree = get_fabric("tree_10gbe").cost("all_reduce", {"data": 512})
        pipe = get_fabric("pipeline_10gbe").cost("all_reduce", {"data": 512})
        assert pipe.a < ring.a  # O(lg N) startup vs O(N)
        assert pipe.b < tree.b  # near-ring bandwidth vs lg N penalty
        # and the crossover is real: at 100 MB the pipeline wins both
        M = 100 * 1024 * 1024
        assert pipe(M) < ring(M) and pipe(M) < tree(M)

    def test_unknown_tier_algo_rejected(self):
        from repro.fabric import HierarchicalFabric

        with pytest.raises(ValueError, match="algorithm"):
            HierarchicalFabric(ici_algo="carrier_pigeon")

    def test_trivial_tier_is_free(self):
        from repro.fabric.hierarchical import pipeline_tree

        m = pipeline_tree(1, 45e-6, 1e-9, 1e-10)
        assert (m.a, m.b) == (0.0, 0.0)


class TestCalibration:
    def test_train_replay_reproduces_committed_planning_rows(self):
        rep = calibrate_train()
        assert rep.ok and len(rep.rows) >= 30
        # the DES at the benchmark geometry IS the committed evaluator:
        # exact agreement, not just within budget
        assert rep.max_ratio == pytest.approx(1.0, abs=1e-9)

    def test_serve_replay_within_budget(self):
        rep = calibrate_serve()
        assert rep.ok
        assert 1.0 <= rep.max_ratio <= rep.budget
        names = {r.name.split("/")[-1] for r in rep.rows}
        assert names == {"decode_step_s", "decode_tok_per_s"}

    def test_report_json_shape(self):
        rep = calibrate_serve()
        d = rep.to_json_dict()
        assert d["kind"] == "serve" and d["ok"] is True
        assert all(r["ratio"] >= 1.0 for r in d["rows"])


class TestPaperOrdering:
    def test_mgwfbp_beats_wfbp_beats_synceasgd_at_8_nodes(self):
        """Figs. 6-7 regime: paper batches, 8-node 10GbE."""
        effs = {}
        for arch, batch in (("googlenet", 64), ("resnet50", 32)):
            costs = cnn_layer_costs(arch, batch)
            spec = ClusterSpec(n_hosts=8, fabric="paper_10gbe")
            for p in ("synceasgd", "wfbp", "mg_wfbp"):
                res = replay_train(spec, list(costs), p, hw=K80_CALIBRATED)
                effs[p] = res.mean_efficiency
            assert effs["mg_wfbp"] > effs["wfbp"] > effs["synceasgd"], (
                arch, effs)


class TestSimReport:
    def _report(self):
        costs = cnn_layer_costs("googlenet", 64)
        rows = []
        for n in (4, 8):
            spec = ClusterSpec(n_hosts=n, fabric="paper_10gbe")
            for p in ("wfbp", "mg_wfbp"):
                res = replay_train(spec, list(costs), p, hw=K80_CALIBRATED)
                rows.append(row_from_replay(res, "googlenet", "paper_10gbe", n))
        return SimReport(rows=tuple(rows), provenance={"source": "test"})

    def test_byte_identical_across_builds(self):
        assert self._report().to_json() == self._report().to_json()

    def test_round_trip_and_select(self):
        rep = self._report()
        rt = SimReport.from_json(rep.to_json())
        assert rt == rep
        assert len(rep.select(n_hosts=8)) == 2
        assert rep.select(policy="wfbp", n_hosts=4)[0].policy == "wfbp"
        assert rep.best_policy(n_hosts=8) == "mg_wfbp"
        with pytest.raises(ValueError, match="no rows"):
            rep.best_policy(n_hosts=512)

    def test_save_load_and_bad_format(self, tmp_path):
        rep = self._report()
        p = rep.save(tmp_path / "report.json")
        assert SimReport.load(p) == rep
        d = json.loads(rep.to_json())
        d["format"] = 99
        with pytest.raises(ValueError, match="format"):
            SimReport.from_json_dict(d)

    def test_efficiency_table_lines(self):
        lines = self._report().efficiency_table()
        assert len(lines) == 4
        assert all("eff=" in ln and "t_iter_ms=" in ln for ln in lines)
