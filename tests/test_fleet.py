"""Serving fleet: seeded load generation, SLO-aware routing/shedding,
in-flight failover with token-identical resume, per-replica chaos
domains, and plan-priced watchdog scale decisions."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch.specs import param_specs
from repro.models.transformer import init_params
from repro.planning import build_serve_plan
from repro.serving import (
    ChaosConfig,
    FleetConfig,
    FleetController,
    FleetWatchdog,
    LoadGenerator,
    LoadSpec,
    Request,
    ServingEngine,
)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"),
                              param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                            {"model": 8}, batch_rows=4)
    return cfg, params, plan


def make_fleet(setup, tmp_path, *, replicas=2, chaos=None,
               chaos_replicas=None, **cfg_kw):
    cfg, params, plan = setup
    cfg_kw.setdefault("snapshot_every", 50)
    cfg_kw.setdefault("max_restores", 0)
    fleet_cfg = FleetConfig(replicas=replicas, backoff_base_s=0.0,
                            idle_sleep_s=0.0, **cfg_kw)
    return FleetController(
        engine_factory=lambda rid: ServingEngine(
            cfg, params, slots=2, max_seq=64, plan=plan),
        config=fleet_cfg,
        snapshot_root=str(tmp_path),
        chaos=chaos,
        chaos_replicas=chaos_replicas,
    )


def fast_load(n=4, max_new=5, **kw):
    # arrivals all effectively immediate so CPU tests never idle-wait
    kw.setdefault("rate_rps", 1e6)
    return LoadGenerator(LoadSpec(n_requests=n, prompt_len=4,
                                  max_new_tokens=max_new, **kw))


# ---------------------------------------------------------------------------
# LoadGenerator
# ---------------------------------------------------------------------------


class TestLoadGenerator:
    def test_same_seed_same_traffic(self):
        a = LoadGenerator(LoadSpec(n_requests=6, seed=7))
        b = LoadGenerator(LoadSpec(n_requests=6, seed=7))
        for (ta, ra), (tb, rb) in zip(a.due(1e9), b.due(1e9)):
            assert ta == tb
            assert np.array_equal(ra.prompt, rb.prompt)

    def test_due_respects_arrival_order(self):
        gen = LoadGenerator(LoadSpec(n_requests=8, kind="trace",
                                     trace_arrivals_s=(0.0, 1.0, 2.0)))
        first = gen.due(1.5)
        assert [off for off, _ in first] == sorted(off for off, _ in first)
        assert all(off <= 1.5 for off, _ in first)
        assert not gen.exhausted
        assert gen.next_arrival_s > 1.5
        rest = gen.due(1e9)
        assert len(first) + len(rest) == 8
        assert gen.exhausted

    def test_trace_cycles_past_its_length(self):
        gen = LoadGenerator(LoadSpec(n_requests=5, kind="trace",
                                     trace_arrivals_s=(0.0, 0.5)))
        offs = [off for off, _ in gen.due(1e9)]
        assert len(offs) == 5
        assert offs == sorted(offs)
        assert len(set(offs)) == 5  # cycling shifts repeats by a period


# ---------------------------------------------------------------------------
# per-replica chaos domains
# ---------------------------------------------------------------------------


class TestForReplica:
    def test_deterministic_and_distinct(self):
        fleet = ChaosConfig(seed=42, kill_at=(3,), slow_factor=2.0)
        seeds = [fleet.for_replica(i).seed for i in range(4)]
        again = [fleet.for_replica(i).seed for i in range(4)]
        assert seeds == again  # exactly reproducible from the fleet seed
        assert len(set(seeds)) == 4  # independent fault domains

    def test_schedule_fields_shared(self):
        fleet = ChaosConfig(seed=1, kill_at=(3,), kill_prob=0.25,
                            slow_factor=2.0, slow_after=5)
        derived = fleet.for_replica(2)
        assert derived.kill_at == fleet.kill_at
        assert derived.kill_prob == fleet.kill_prob
        assert derived.slow_factor == fleet.slow_factor
        assert derived.slow_after == fleet.slow_after
        assert derived.seed != fleet.seed


# ---------------------------------------------------------------------------
# fleet runs
# ---------------------------------------------------------------------------


class TestFleet:
    def test_fault_free_completes_everything(self, setup, tmp_path):
        fleet = make_fleet(setup, tmp_path, replicas=2)
        load = fast_load(n=5, max_new=5)
        report = fleet.run(load)
        assert report.offered == 5
        assert len(report.completed) == 5
        assert report.shed == 0 and report.expired == 0
        assert report.goodput_tokens == 5 * 5
        assert report.replica_deaths == 0
        assert report.failover_token_mismatches == 0
        assert len(report.latencies_s) == 5
        assert report.latency_percentile(99) >= report.latency_percentile(50)
        # router spread work across both replicas
        assert {r.replica_id for r in report.completed.values()} == {0, 1}

    def test_failover_preserves_partial_tokens(self, setup, tmp_path):
        # replica 0 is a fault domain that dies at step 2 with no restore
        # budget; its in-flight requests must land on replica 1 and finish
        # token-identical to their partial prefix.
        fleet = make_fleet(
            setup, tmp_path, replicas=2,
            chaos=ChaosConfig(kill_at=(2,)), chaos_replicas=(0,),
        )
        report = fleet.run(fast_load(n=4, max_new=8))
        assert report.replica_deaths == 1
        assert report.failovers >= 1
        assert len(report.completed) == 4
        assert report.failover_token_mismatches == 0
        assert report.goodput_tokens == 4 * 8  # never double-charged
        moved = [r for r in report.completed.values() if r.retries > 0]
        assert moved
        assert all(r.replica_id == 1 for r in moved)
        assert all(len(r.generated) == 8 for r in moved)

    def test_failover_is_deterministic(self, setup, tmp_path):
        out = []
        for sub in ("a", "b"):
            fleet = make_fleet(
                setup, tmp_path / sub, replicas=2,
                chaos=ChaosConfig(seed=5, kill_at=(2,)), chaos_replicas=(0,),
            )
            report = fleet.run(fast_load(n=4, max_new=6, seed=3))
            out.append({rid: tuple(r.generated)
                        for rid, r in sorted(report.completed.items())})
        assert out[0] == out[1]

    def test_sheds_when_no_replica_meets_deadline(self, setup, tmp_path):
        fleet = make_fleet(setup, tmp_path, replicas=2)
        report = fleet.run(fast_load(n=3, max_new=64, deadline_s=1e-9))
        assert report.shed == 3
        assert report.goodput_tokens == 0
        assert all(r.shed for r in report.completed.values())
        assert report.latency_percentile(99) == 0.0  # shed requests excluded

    def test_elastic_scale_up_under_backlog(self, setup, tmp_path):
        fleet = make_fleet(
            setup, tmp_path, replicas=1, elastic=True, max_replicas=2,
            scale_up_backlog_s=0.0,
        )
        report = fleet.run(fast_load(n=6, max_new=6))
        assert report.scale_ups >= 1
        assert len(fleet.replicas) == 2
        assert report.scale_decisions
        d = report.scale_decisions[0]
        assert d["action"] == "scale_up"
        assert d["drain_s_after"] < d["drain_s_before"]
        assert len(report.completed) == 6
        assert report.failover_token_mismatches == 0
        # the scaled-up replica absorbed rebalanced backlog, not just
        # existed: it decoded steps and finished requests of its own
        scaled = next(r for r in report.replicas if r["rid"] == 1)
        assert scaled["steps"] > 0
        assert {r.replica_id for r in report.completed.values()} == {0, 1}


# ---------------------------------------------------------------------------
# watchdog pricing (unit level)
# ---------------------------------------------------------------------------


class _FakePlan:
    def capacity_tok_per_s(self, rows):
        return float(rows) * 100.0


class TestFleetWatchdog:
    def test_scale_up_priced_by_plan(self):
        dog = FleetWatchdog(scale_up_backlog_s=0.5)
        act = dog.assess(round_idx=0, backlog_tokens=1000, n_alive=2,
                         plan=_FakePlan(), slots=4)
        assert act == "scale_up"
        d = dog.decisions[0]
        assert d["capacity_tok_per_s_per_replica"] == 400.0
        assert d["drain_s_before"] == pytest.approx(1000 / 800)
        assert d["drain_s_after"] == pytest.approx(1000 / 1200)

    def test_cooldown_blocks_thrash(self):
        dog = FleetWatchdog(scale_up_backlog_s=0.5, cooldown_rounds=3)
        assert dog.assess(round_idx=0, backlog_tokens=1000, n_alive=1,
                          plan=_FakePlan(), slots=4) == "scale_up"
        for i in range(1, 3):
            assert dog.assess(round_idx=i, backlog_tokens=1000, n_alive=1,
                              plan=_FakePlan(), slots=4) is None
        # the next decision lands exactly cooldown_rounds later
        assert dog.assess(round_idx=3, backlog_tokens=1000, n_alive=1,
                          plan=_FakePlan(), slots=4) == "scale_up"

    def test_scale_down_after_idle(self):
        dog = FleetWatchdog(scale_down_idle_rounds=2, cooldown_rounds=0)
        assert dog.assess(round_idx=0, backlog_tokens=0, n_alive=2,
                          plan=_FakePlan(), slots=4) is None
        assert dog.assess(round_idx=1, backlog_tokens=0, n_alive=2,
                          plan=_FakePlan(), slots=4) == "scale_down"
        # never below one replica
        dog2 = FleetWatchdog(scale_down_idle_rounds=1, cooldown_rounds=0)
        assert dog2.assess(round_idx=0, backlog_tokens=0, n_alive=1,
                           plan=_FakePlan(), slots=4) is None

    def test_unpriced_fleet_never_scales(self):
        dog = FleetWatchdog(scale_up_backlog_s=0.0)
        assert dog.assess(round_idx=0, backlog_tokens=10_000, n_alive=1,
                          plan=None, slots=4) is None
        assert not dog.decisions


# ---------------------------------------------------------------------------
# engine failover seams (drain + resume re-admission)
# ---------------------------------------------------------------------------


class TestEngineFailoverSeams:
    def test_drain_requests_empties_engine(self, setup, tmp_path):
        cfg, params, plan = setup
        eng = ServingEngine(cfg, params, slots=2, max_seq=64, plan=plan)
        for rid in range(3):
            eng.submit(Request(rid=rid,
                               prompt=np.arange(4, dtype=np.int32) + 1,
                               max_new_tokens=6))
        for _ in range(2):
            eng.step()
        reqs = eng.drain_requests()
        assert len(reqs) == 3
        assert not eng.active and not eng.waiting
        assert not any(r.done for r in reqs)
        # in-flight requests keep their partial output for the peer
        assert any(r.generated for r in reqs)

    def test_resume_admission_preserves_prefix(self, setup, tmp_path):
        # the failover contract: a request drained mid-flight and
        # resumed on a peer keeps its partial prefix verbatim, finishes
        # to full budget, and the resumed continuation is deterministic.
        # (Bit-identity with an uninterrupted run is NOT promised — the
        # peer re-prefills the prefix, and batched prefill is not
        # bit-identical to incremental decode in fp32; exact-state
        # identity is what snapshots are for.)
        cfg, params, plan = setup
        prompt = np.arange(5, dtype=np.int32) + 1

        a = ServingEngine(cfg, params, slots=2, max_seq=64)
        a.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
        for _ in range(3):
            a.step()
        (req,) = a.drain_requests()
        prefix = list(req.generated)
        assert 0 < len(prefix) < 8

        outs = []
        for _ in range(2):
            b = ServingEngine(cfg, params, slots=2, max_seq=64)
            clone = dataclasses.replace(
                req, generated=list(prefix), done=False, retries=req.retries + 1,
            )
            b.submit(clone)
            while b.active or b.waiting:
                b.step()
            outs.append(list(b.completed[0].generated))
        assert outs[0][: len(prefix)] == prefix
        assert len(outs[0]) == 8  # finishes the full token budget
        assert outs[0] == outs[1]  # resumed continuation is deterministic
