"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness; decode-vs-forward consistency; full-config
parameter counts validated via eval_shape (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.models import forward, init_caches, init_params, loss_fn, param_count
from repro.models.transformer import init_params as _init

B, S = 2, 64


def make_batch(cfg, key, batch=B, seq=S):
    k1, k2 = jax.random.split(key)
    tgt = jax.random.randint(k2, (batch, seq), 0, cfg.vocab)
    if cfg.input_mode == "embeds":
        return {
            "embeds": jax.random.normal(k1, (batch, seq, cfg.d_model), jnp.float32) * 0.02,
            "targets": tgt,
        }
    return {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab), "targets": tgt}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits, _, aux = forward(params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_segmented_forward_matches_single_scan(arch):
    """Bucket-segmented scan must be numerically identical to one scan."""
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    n = cfg.n_stages
    if n < 2:
        pytest.skip("single-stage model")
    one, _, _ = forward(params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                        segments=((0, n),))
    two, _, _ = forward(params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                        segments=((0, n // 2), (n // 2, n)))
    np.testing.assert_allclose(np.asarray(one), np.asarray(two), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize(
    "arch",
    ["tinyllama-1.1b", "gemma2-2b", "mixtral-8x7b", "recurrentgemma-9b", "rwkv6-7b"],
)
def test_decode_matches_forward(arch):
    """Prefill + incremental decode logits == full-forward logits.

    Runs in fp32 so the check isolates cache/masking logic from bf16
    rounding (bf16 reorder noise is ~1e-2 on O(1) logits)."""
    import dataclasses

    cfg = dataclasses.replace(get_reduced(arch), param_dtype=jnp.float32)
    if cfg.moe is not None:
        # capacity dropping depends on chunk composition, so decode ==
        # forward only holds when nothing is dropped — give ample capacity.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params = init_params(jax.random.PRNGKey(0), cfg)
    seq = 32
    batch = make_batch(cfg, jax.random.PRNGKey(1), batch=1, seq=seq)
    kwargs = (
        {"embeds": batch["embeds"]} if cfg.input_mode == "embeds" else {"tokens": batch["tokens"]}
    )
    full_logits, _, _ = forward(params, cfg, **kwargs)

    # prefill on the first seq-8 positions, then decode 8 tokens
    split = seq - 8
    caches = init_caches(cfg, batch=1, max_seq=seq, dtype=jnp.float32)
    if cfg.input_mode == "embeds":
        pre = {"embeds": batch["embeds"][:, :split]}
    else:
        pre = {"tokens": batch["tokens"][:, :split]}
    logits_pre, caches, _ = forward(params, cfg, **pre, caches=caches)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]), np.asarray(full_logits[:, split - 1]),
        rtol=2e-3, atol=2e-3,
    )

    for t in range(split, seq):
        if cfg.input_mode == "embeds":
            step_in = {"embeds": batch["embeds"][:, t : t + 1]}
        else:
            step_in = {"tokens": batch["tokens"][:, t : t + 1]}
        logits_t, caches, _ = forward(params, cfg, **step_in, caches=caches, q_offset=t)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"decode step t={t}",
        )


EXPECTED_PARAMS_B = {
    "musicgen-large": (1.4, 2.6),
    "tinyllama-1.1b": (1.0, 1.2),
    "starcoder2-7b": (6.4, 7.8),
    "gemma2-2b": (2.0, 3.2),
    "starcoder2-3b": (2.7, 3.5),
    "mixtral-8x7b": (44.0, 49.0),
    "dbrx-132b": (125.0, 138.0),
    "rwkv6-7b": (6.5, 8.2),
    "recurrentgemma-9b": (8.0, 10.5),
    "qwen2-vl-2b": (1.2, 1.8),
}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_param_count(arch):
    """Full configs hit the advertised parameter counts (eval_shape only —
    nothing is allocated)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: _init(k, cfg), jax.random.PRNGKey(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    lo, hi = EXPECTED_PARAMS_B[arch]
    assert lo <= n / 1e9 <= hi, f"{arch}: {n / 1e9:.2f}B params outside [{lo}, {hi}]"


def test_mrope_reduces_to_rope_for_text():
    """Qwen2-VL M-RoPE with equal (t,h,w) streams == standard RoPE."""
    from repro.models.layers import apply_mrope, apply_rope

    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (2, 16, 4, 24), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    mpos = jnp.broadcast_to(pos[None], (3, 2, 16))
    a = apply_rope(x, pos, 1e6)
    b = apply_mrope(x, mpos, 1e6, (4, 4, 4))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_sliding_window_masks_distant_tokens():
    """A windowed arch must ignore keys beyond the window."""
    import dataclasses

    cfg = get_reduced("mixtral-8x7b")
    att = dataclasses.replace(cfg.attention, window=8)
    # ample expert capacity: with dropping, a perturbed token can displace
    # *other* tokens from expert slots, which would defeat the locality
    # this test checks (same caveat as the decode-consistency test)
    moe = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, attention=att, moe=moe, local_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, cfg.vocab)
    base, _, _ = forward(params, cfg, tokens=tokens)
    # perturb a token far outside the window of the last position
    tokens2 = tokens.at[0, 2].set((tokens[0, 2] + 1) % cfg.vocab)
    pert, _, _ = forward(params, cfg, tokens=tokens2)
    np.testing.assert_allclose(
        np.asarray(base[:, -1]), np.asarray(pert[:, -1]), rtol=1e-4, atol=1e-4
    )
