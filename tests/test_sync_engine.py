"""MG-WFBP sync engine: schedule groups -> exactly that many variadic
all-reduces in the compiled HLO, with numerics identical to unbucketed DP.

Multi-device cases run in a subprocess so the main pytest process keeps a
single CPU device (smoke tests must not see a forced device count)."""

import json
import subprocess
import sys
import textwrap
from _env import REPO_ROOT, SUBPROC_ENV  # shared subprocess env

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, re, sys
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_reduced
    from repro.core.comm_model import AllReduceModel
    from repro.core.trainer import MGWFBPEngine, lm_unit_costs
    from repro.launch.specs import param_specs
    from repro.models.transformer import init_params
    from repro.optim import make_optimizer

    method = sys.argv[1]
    arch = sys.argv[2]

    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_reduced(arch)
    p_shapes = param_specs(cfg)
    ar = AllReduceModel(a=5e-5, b=1e-9)

    eng = MGWFBPEngine.build(
        cfg, p_shapes, dp_axes=("data",), ar_model=ar,
        tokens_per_device=1024, method=method,
    )
    opt = make_optimizer("sgd", momentum=0.9)
    step = eng.make_train_step(opt, mesh, lr=1e-2)

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    B, S = 8, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)

    # reference FIRST (params are donated to the compiled step below):
    # plain jit grad + mean over full batch
    from repro.models import loss_fn
    def ref_loss(p):
        return loss_fn(p, batch, cfg)[0]
    g_ref = jax.grad(ref_loss)(params)
    from repro.optim.optimizers import sgd_update, sgd_init
    ref_params, _ = sgd_update(g_ref, sgd_init(params, 0.9), params, 1e-2, 0.9)
    ref_params = jax.tree.map(np.asarray, ref_params)

    with set_mesh(mesh):
        lowered = step.lower(params, opt_state, batch)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        # count gradient all-reduces over the data axis: replica_groups
        # containing {0,2,4,6}-style (stride-model) groups
        n_ar = len(re.findall(r" all-reduce\\(", hlo))
        new_params, _, metrics = compiled(params, opt_state, batch)

    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        new_params, ref_params)
    max_diff = max(jax.tree.leaves(diffs))
    print(json.dumps({
        "n_allreduce": n_ar,
        "n_groups": len(eng.schedule.groups),
        "segments": list(map(list, eng.segments)),
        "max_param_diff": max_diff,
        "loss": float(metrics["loss"]),
        "method": method,
        "groups": list(map(list, eng.schedule.groups)),
    }))
""")


def run_case(method: str, arch: str) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, method, arch],
        capture_output=True, text=True, timeout=600,
        env=SUBPROC_ENV,
        cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("method,arch", [
    ("mg_wfbp", "tinyllama-1.1b"),
    ("dp_optimal", "tinyllama-1.1b"),
    ("synceasgd", "tinyllama-1.1b"),
    ("mg_wfbp", "mixtral-8x7b"),
    ("mg_wfbp", "recurrentgemma-9b"),  # tail pattern
])
def test_bucketed_sync_numerics_and_hlo(method, arch):
    rec = run_case(method, arch)
    # numerics: bucketed shard_map DP == plain data parallelism
    assert rec["max_param_diff"] < 5e-2, rec  # bf16 params => loose abs tol
    # structure: gradient all-reduces == schedule groups (+1 for the loss
    # pmean, +small constant for psums XLA inserts for norms statistics)
    assert rec["n_allreduce"] >= rec["n_groups"]
    assert rec["n_allreduce"] <= rec["n_groups"] + 4, rec


def test_synceasgd_single_group():
    rec = run_case("synceasgd", "tinyllama-1.1b")
    assert rec["n_groups"] == 1
    assert len(rec["segments"]) == 1


def test_wfbp_many_groups():
    rec = run_case("wfbp", "tinyllama-1.1b")
    # every unit separate: embed + 4 stages + head = 6 groups (reduced cfg)
    assert rec["n_groups"] == 6
    # FINDING (EXPERIMENTS.md): XLA's all-reduce combiner merges adjacent
    # small all-reduces below its size threshold — the compiler-level
    # analogue of the paper's tensor-fusion baselines.  At these reduced
    # test sizes all 6 WFBP reduces may legally combine into fewer ops;
    # production runs pin the combiner threshold to 0.
    assert 1 <= rec["n_allreduce"] <= 6 + 4
