"""DAG issue order: the in-backward step must be a pure reordering.

One subprocess (8 virtual CPU devices) compiles the same engine twice —
``issue="post"`` and ``issue="dag"`` — on identical params/batch and
checks that

  * the dag HLO still carries one gradient all-reduce per schedule group
    (plus the loss pmean and whatever small psums XLA adds);
  * losses and updated parameters are bit-identical between the two
    issue orders: moving the collectives inside backward must not change
    a single ulp of the math.
"""

import json
import subprocess
import sys
import textwrap

from _env import REPO_ROOT, SUBPROC_ENV

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, re
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_reduced
    from repro.core.comm_model import AllReduceModel
    from repro.core.sync import SyncConfig
    from repro.core.trainer import MGWFBPEngine
    from repro.launch.specs import param_specs
    from repro.models.transformer import init_params
    from repro.optim import make_optimizer

    mesh = make_mesh((8,), ("data",))
    cfg = get_reduced("tinyllama-1.1b")
    eng = MGWFBPEngine.build(
        cfg, param_specs(cfg), dp_axes=("data",),
        ar_model=AllReduceModel(a=5e-5, b=1e-9),
        tokens_per_device=1024, method="wfbp",
        sync_config=SyncConfig(fuse="arena"),
    )
    opt = make_optimizer("sgd", momentum=0.9)

    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 8, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)

    out = {"n_groups": len(eng.schedule.groups)}
    results = {}
    for issue in ("post", "dag"):
        step = eng.make_train_step(opt, mesh, lr=1e-2, issue=issue)
        # the step donates params/opt_state: hand it fresh copies
        p0 = jax.tree.map(jnp.array, params)
        with set_mesh(mesh):
            lowered = step.lower(p0, opt.init(p0), batch)
            compiled = lowered.compile()
            out[f"n_allreduce_{issue}"] = len(
                re.findall(r" all-reduce\\(", compiled.as_text()))
            new_params, _, metrics = compiled(p0, opt.init(p0), batch)
        results[issue] = jax.tree.map(np.asarray, new_params)
        out[f"loss_{issue}"] = float(metrics["loss"])

    same = jax.tree.map(
        lambda a, b: bool(np.array_equal(a, b)), results["post"], results["dag"])
    out["params_bit_identical"] = all(jax.tree.leaves(same))
    print(json.dumps(out))
""")


def test_dag_issue_order_structure_and_numerics():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=SUBPROC_ENV, cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_groups"] == 6  # wfbp on reduced tinyllama: one per unit
    for issue in ("post", "dag"):
        # one gradient all-reduce per group + loss pmean (+ small slack
        # for statistics psums); the XLA combiner may merge some on the
        # reduced sizes, hence the >= 1 floor rather than == n_groups
        assert 1 <= rec[f"n_allreduce_{issue}"] <= rec["n_groups"] + 4, rec
    # the dag reordering must not change the math at all
    assert rec["loss_post"] == rec["loss_dag"], rec
    assert rec["params_bit_identical"], rec
