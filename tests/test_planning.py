"""Planning subsystem: policy registry, Plan artifact round-trip, cost
sources + measured-profile re-planning, scan-bucket edge cases, and the
sync lowering invariant (exactly one all-reduce per schedule group)."""

import json
import os
import random
import subprocess
import sys
import textwrap
from _env import REPO_ROOT, SUBPROC_ENV  # shared subprocess env

import pytest

from repro.core import (
    AllReduceModel,
    Hardware,
    LayerCost,
    layer_buckets_for_scan,
    layout_for_stacked_lm,
    wfbp_schedule,
)
from repro.core.schedule import (
    Schedule,
    dp_optimal_schedule,
    evaluate,
    mg_wfbp_schedule,
    optimal_schedule,
)
from repro.planning import (
    MEASURED_HW,
    MeasuredCosts,
    Plan,
    available_policies,
    build_plan,
    build_schedule,
    cost_drift,
    get_policy,
    register_policy,
    replan_if_drifted,
    resolve_policy_name,
)

HW = Hardware(name="unit", peak_flops=1.0, hbm_bw=1.0, mxu_eff=1.0, hbm_eff=1.0)


def mk_costs(tb, nbytes, tf=0.0):
    return [
        LayerCost(
            name=f"l{i + 1}", params=n, grad_bytes=n, bwd_flops=t, fwd_flops=tf / len(tb)
        )
        for i, (t, n) in enumerate(zip(tb, nbytes))
    ]


class TestRegistry:
    def test_builtins_registered(self):
        names = available_policies()
        for p in ("wfbp", "synceasgd", "fixed", "mg_wfbp", "dp_optimal", "optimal"):
            assert p in names

    def test_strategy_aliases(self):
        """The old SyncConfig.strategy vocabulary resolves to policies."""
        assert resolve_policy_name("per_tensor") == "wfbp"
        assert resolve_policy_name("single") == "synceasgd"
        assert resolve_policy_name("bucketed") == "mg_wfbp"

    def test_unknown_policy_raises(self):
        with pytest.raises(KeyError, match="unknown scheduler policy"):
            get_policy("definitely_not_a_policy")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_policy("mg_wfbp")(lambda *a, **k: None)

    def test_register_custom_policy(self):
        @register_policy("_test_pairs", overwrite=True)
        def pairs(costs, ar_model, hw=HW, t_f=None, **opts):
            L = len(costs)
            groups = tuple((l, min(l + 1, L)) for l in range(1, L + 1, 2))
            return Schedule(groups=groups, method="_test_pairs")

        costs = mk_costs([0.1] * 5, [100] * 5, tf=0.1)
        s = build_schedule("_test_pairs", costs, AllReduceModel(a=0.01, b=1e-6), hw=HW)
        assert s.groups == ((1, 2), (3, 4), (5, 5))
        assert s.result is not None  # registry evaluated it

    def test_all_builtin_policies_run_and_evaluate(self):
        costs = mk_costs([0.01] * 6, [1000] * 6, tf=0.05)
        ar = AllReduceModel(a=1e-3, b=1e-8)
        for name in ("wfbp", "synceasgd", "fixed", "mg_wfbp", "dp_optimal", "optimal"):
            s = build_schedule(name, costs, ar, hw=HW)
            assert s.result is not None and s.result.t_iter > 0, name
            assert s.groups[0][0] == 1 and s.groups[-1][1] == 6, name


class TestSchedulingEquivalence:
    """Seeded-random coverage (runs without hypothesis): the exact DP never
    loses to the paper's greedy and always matches exhaustive search."""

    def test_dp_le_greedy_and_eq_exhaustive_random(self):
        rng = random.Random(1234)
        for trial in range(40):
            L = rng.randint(2, 12)
            tb = [rng.uniform(1e-4, 1.0) for _ in range(L)]
            nb = [rng.randint(1, 10_000_000) for _ in range(L)]
            costs = mk_costs(tb, nb, tf=rng.uniform(0.0, 1.0))
            ar = AllReduceModel(a=rng.uniform(1e-6, 0.5), b=rng.uniform(1e-12, 1e-6))
            dp = dp_optimal_schedule(costs, ar, HW)
            greedy = mg_wfbp_schedule(costs, ar, HW)
            exact = optimal_schedule(costs, ar, HW)
            assert dp.result.t_iter <= greedy.result.t_iter + 1e-9, (trial, L)
            assert dp.result.t_iter == pytest.approx(
                exact.result.t_iter, rel=1e-9, abs=1e-12
            ), (trial, L)


class TestScanBuckets:
    def test_group_spanning_embed_boundary(self):
        # units: 1=embed, 2..5=stages, 6=head; group [1..3] spans embed+2 stages
        s = Schedule(groups=((1, 3), (4, 6)), method="manual")
        assert layer_buckets_for_scan(s, 4) == ((0, 2), (2, 4))

    def test_group_spanning_head_boundary(self):
        s = Schedule(groups=((1, 1), (2, 6)), method="manual")
        assert layer_buckets_for_scan(s, 4) == ((0, 4),)

    def test_single_group_covers_all(self):
        s = Schedule(groups=((1, 6),), method="manual")
        assert layer_buckets_for_scan(s, 4) == ((0, 4),)

    def test_singletons_give_per_stage_segments(self):
        s = wfbp_schedule(6)
        assert layer_buckets_for_scan(s, 4) == ((0, 1), (1, 2), (2, 3), (3, 4))

    def test_coverage_mismatch_raises(self):
        with pytest.raises(ValueError, match="do not cover"):
            layer_buckets_for_scan(wfbp_schedule(4), 4)


def small_plan(policy="mg_wfbp"):
    layout = layout_for_stacked_lm(4, embed_params=5000, layer_params=3000, head_params=7000)
    costs = layout.layer_costs(tokens_per_chip=64, hw=HW)
    ar = AllReduceModel(a=1e-3, b=1e-9)
    return build_plan(
        layout, costs, ar, policy=policy, hw=HW, n_scan_stages=4,
        provenance={"arch": "unit-test"},
    )


class TestPlanArtifact:
    def test_json_round_trip_exact(self):
        plan = small_plan()
        clone = Plan.from_json(plan.to_json())
        assert clone == plan
        # and the serialized form itself is stable
        assert clone.to_json() == plan.to_json()

    def test_save_load(self, tmp_path):
        plan = small_plan("dp_optimal")
        path = plan.save(tmp_path / "plans" / "p.json")
        loaded = Plan.load(path)
        assert loaded == plan
        assert loaded.policy == "dp_optimal"
        assert loaded.segments == plan.segments

    def test_provenance_and_describe(self):
        plan = small_plan()
        assert plan.provenance["policy"] == "mg_wfbp"
        assert plan.provenance["cost_source"] == "analytic"
        assert plan.provenance["arch"] == "unit-test"
        assert "mg_wfbp" in plan.describe()

    def test_bad_format_rejected(self):
        plan = small_plan()
        d = plan.to_json_dict()
        d["format"] = 99
        with pytest.raises(ValueError, match="unsupported plan format"):
            Plan.from_json_dict(d)

    def test_build_plan_validates_cost_length(self):
        layout = layout_for_stacked_lm(2, 10, 10, 10)
        costs = mk_costs([0.1] * 3, [10] * 3)  # layout has 4 units
        with pytest.raises(ValueError, match="cost vector"):
            build_plan(layout, costs, AllReduceModel(a=1e-3, b=1e-9), hw=HW)


class TestMeasuredReplan:
    """Acceptance: MeasuredCosts -> replan_if_drifted yields a different
    (better-modeled) schedule than the analytic plan on a skewed-cost
    instance — the journal version's online re-planning."""

    def skewed_setup(self):
        # Analytic belief: tiny uniform backward times + large startup α
        # => comm-bound => Algorithm 1 merges everything into one message.
        layout = layout_for_stacked_lm(6, 1_000_000, 1_000_000, 1_000_000)
        analytic = mk_costs([0.01] * 8, [1_000_000] * 8, tf=0.01)
        ar = AllReduceModel(a=0.5, b=1e-9)
        plan = build_plan(layout, analytic, ar, policy="mg_wfbp", hw=HW, n_scan_stages=6)
        # Reality: backward is ~200x slower than believed => comm hides
        # behind compute and merging everything is pessimal.
        measured = MeasuredCosts.from_unit_times(
            analytic, [2.0] * 8, name="measured_skew"
        )
        return plan, measured

    def test_replan_changes_schedule_and_improves_model(self):
        plan, measured = self.skewed_setup()
        assert plan.schedule.groups == ((1, 8),)  # analytic merged everything
        drift = cost_drift(plan, measured)
        assert drift > 1.0  # 200x skew
        new_plan, replanned = replan_if_drifted(plan, measured, threshold=0.25)
        assert replanned
        assert new_plan.schedule.groups != plan.schedule.groups
        # better-modeled: under measured costs, the re-planned schedule's
        # t_iter beats the stale analytic schedule's.
        stale = evaluate(
            list(plan.schedule.groups), measured.layer_costs(), plan.ar_model, MEASURED_HW
        )
        assert new_plan.schedule.result.t_iter < stale.t_iter - 1e-9
        # provenance records the hand-off
        assert new_plan.provenance["cost_source"] == "measured_skew"
        assert new_plan.provenance["replanned_from"] == "analytic"
        assert float(new_plan.provenance["drift"]) == pytest.approx(drift, rel=1e-3)
        # segments follow the new schedule
        assert new_plan.segments != plan.segments

    def test_below_threshold_keeps_plan(self):
        plan, _ = self.skewed_setup()
        near = MeasuredCosts.from_unit_times(
            list(plan.costs), [c.t_b(HW) * 1.05 for c in plan.costs]
        )
        same, replanned = replan_if_drifted(plan, near, threshold=0.25)
        assert not replanned and same is plan

    def test_zero_drift_on_identical(self):
        plan, _ = self.skewed_setup()
        identical = MeasuredCosts.from_unit_times(
            list(plan.costs), [c.t_b(HW) for c in plan.costs]
        )
        assert cost_drift(plan, identical) == pytest.approx(0.0, abs=1e-12)

    def test_step_timing_calibration(self):
        plan, _ = self.skewed_setup()
        modeled = plan.schedule.result.t_iter
        m = MeasuredCosts.from_step_timing(list(plan.costs), HW, 2 * modeled, modeled)
        # uniform 2x scale on every unit
        for c, base in zip(m.layer_costs(), plan.costs):
            assert c.t_b(MEASURED_HW) == pytest.approx(2 * base.t_b(HW), rel=1e-9)
            assert c.grad_bytes == base.grad_bytes

    def test_unit_count_mismatch_raises(self):
        plan, _ = self.skewed_setup()
        with pytest.raises(ValueError):
            MeasuredCosts.from_unit_times(list(plan.costs), [1.0] * 3)


class TestEnginePlan:
    """MGWFBPEngine accepts/produces a Plan and rebuilds identically from
    the serialized artifact."""

    def test_engine_round_trips_plan(self):
        from repro.configs import get_reduced
        from repro.core.trainer import MGWFBPEngine
        from repro.launch.specs import param_specs

        cfg = get_reduced("tinyllama-1.1b")
        shapes = param_specs(cfg)
        ar = AllReduceModel(a=5e-5, b=1e-9)
        eng = MGWFBPEngine.build(
            cfg, shapes, dp_axes=("data",), ar_model=ar,
            tokens_per_device=1024, policy="mg_wfbp",
        )
        assert eng.plan.provenance["policy"] == "mg_wfbp"
        assert eng.schedule is eng.plan.schedule
        assert eng.segments == eng.plan.segments

        clone = Plan.from_json(eng.plan.to_json())
        eng2 = MGWFBPEngine.build(cfg, None, dp_axes=("data",), plan=clone)
        assert eng2.plan == eng.plan
        assert eng2.schedule.groups == eng.schedule.groups

    def test_engine_replan_rebuilds_sync(self):
        from repro.configs import get_reduced
        from repro.core.trainer import MGWFBPEngine
        from repro.launch.specs import param_specs

        cfg = get_reduced("tinyllama-1.1b")
        shapes = param_specs(cfg)
        # comm-bound analytic belief: merge everything
        ar = AllReduceModel(a=0.5, b=1e-9)
        eng = MGWFBPEngine.build(
            cfg, shapes, dp_axes=("data",), ar_model=ar,
            tokens_per_device=1024, policy="mg_wfbp",
        )
        measured = MeasuredCosts.from_unit_times(
            list(eng.plan.costs), [10.0] * len(eng.plan.costs)
        )
        eng2, replanned = eng.replan(measured, threshold=0.25)
        assert replanned
        assert eng2.plan.schedule.groups != eng.plan.schedule.groups
        assert eng2.sync is not eng.sync


SYNC_LOWERING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, re
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core import (
        AllReduceModel, SyncConfig, count_expected_allreduces,
        make_gradient_sync, stacked_lm_layout,
    )
    from repro.planning import build_schedule

    n_stages = 4
    shapes = {
        "embed": {"tok": jnp.zeros((32, 16))},
        "stages": {"w1": jnp.zeros((n_stages, 16, 16)), "w2": jnp.zeros((n_stages, 16))},
        "final_norm": {"scale": jnp.zeros((16,))},
        "head": {"w": jnp.zeros((16, 32))},
    }
    layout = stacked_lm_layout(shapes, n_stages)
    costs = layout.layer_costs(1024, None)
    mesh = make_mesh((8,), ("data",))
    key = jax.random.PRNGKey(0)
    grads = jax.tree.map(
        lambda s: jax.random.normal(jax.random.fold_in(key, s.size), s.shape), shapes
    )

    # α picked so mg_wfbp lands between the two extremes on these costs.
    CASES = [
        ("per_tensor", AllReduceModel(a=1e-3, b=1e-9)),
        ("single", AllReduceModel(a=1e-3, b=1e-9)),
        ("bucketed", AllReduceModel(a=1e-3, b=1e-9)),
        ("fixed", AllReduceModel(a=1e-3, b=1e-9)),
        ("dp_optimal", AllReduceModel(a=1e-3, b=1e-9)),
    ]
    out = []
    for policy, ar in CASES:
        opts = {"bucket_bytes": 3000} if policy == "fixed" else {}
        sched = build_schedule(policy, costs, ar, **opts)
        rec = {"policy": policy, "n_groups": len(sched.groups)}
        for fuse in ("concat", "variadic"):
            cfgs = SyncConfig(fuse=fuse)
            sync = make_gradient_sync(layout, sched, ("data",), cfgs)

            def body(g):
                # distinct per-device values: rank r contributes (r+1)*g,
                # so the averaged result must equal 4.5*g exactly.
                r = jax.lax.axis_index("data").astype(jnp.float32)
                scaled = jax.tree.map(lambda x: x * (r + 1.0), g)
                return sync(scaled)

            f = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          axis_names={"data"}, check_vma=False)
            lowered = jax.jit(f).lower(grads)
            n_ar = len(re.findall(r"stablehlo\\.all_reduce", lowered.as_text()))
            got = jax.jit(f)(grads)
            expect = jax.tree.map(lambda x: 4.5 * x, grads)
            diff = max(
                jax.tree.leaves(
                    jax.tree.map(
                        lambda a, b: float(jnp.max(jnp.abs(a - b))), got, expect
                    )
                )
            )
            rec[fuse] = {
                "hlo_allreduces": n_ar,
                "expected": count_expected_allreduces(sched, cfgs, layout),
                "max_diff": diff,
            }
        out.append(rec)
    print(json.dumps(out))
""")


def test_sync_lowering_allreduce_counts():
    """Satellite: the unified sync under shard_map lowers to exactly
    len(schedule.groups) all-reduce ops per policy (concat wire layout),
    and count_expected_allreduces is exact for both wire layouts."""
    out = subprocess.run(
        [sys.executable, "-c", SYNC_LOWERING_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=SUBPROC_ENV,
        cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    recs = json.loads(out.stdout.strip().splitlines()[-1])
    assert {r["policy"] for r in recs} == {
        "per_tensor", "single", "bucketed", "fixed", "dp_optimal"
    }
    by = {r["policy"]: r for r in recs}
    assert by["per_tensor"]["n_groups"] == 6  # embed + 4 stages + head
    assert by["single"]["n_groups"] == 1
    assert 1 < by["fixed"]["n_groups"] < 6  # genuinely intermediate
    for r in recs:
        # concat: the merged message of Definition 1 — exactly one
        # all-reduce HLO op per schedule group.
        assert r["concat"]["hlo_allreduces"] == r["n_groups"], r
        assert r["concat"]["expected"] == r["n_groups"], r
        # variadic: one op per wire leaf on this jax; the counter knows.
        assert r["variadic"]["hlo_allreduces"] == r["variadic"]["expected"], r
        for fuse in ("concat", "variadic"):
            assert r[fuse]["max_diff"] < 1e-4, r
