"""Optimizers: SGD(+momentum) — the paper's optimizer — and AdamW for the
LM examples.  Pure-functional; state pytrees mirror the parameter tree so
every sharding rule applies unchanged (optimizer state is automatically
FSDP/ZeRO-sharded alongside its parameter)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array
    m: Pytree  # momentum / first moment ('' tree for plain SGD)
    v: Pytree  # second moment (AdamW only; empty tree otherwise)


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# SGD + momentum (paper Eq. 1/2)
# ---------------------------------------------------------------------------


def sgd_init(params: Pytree, momentum: float = 0.0) -> OptState:
    m = (
        jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        if momentum
        else jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    )
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params))


def sgd_update(
    grads: Pytree,
    state: OptState,
    params: Pytree,
    lr: float | jax.Array,
    momentum: float = 0.0,
) -> tuple[Pytree, OptState]:
    if momentum:
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state.m, grads
        )
        upd = new_m
    else:
        new_m = state.m
        upd = grads
    new_params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - lr * u.astype(jnp.float32)).astype(p.dtype),
        params,
        upd,
    )
    return new_params, OptState(step=state.step + 1, m=new_m, v=state.v)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Pytree) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads: Pytree,
    state: OptState,
    params: Pytree,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Pytree, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.m, grads)
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads
    )

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        step_val = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_val).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, OptState(step=step, m=new_m, v=new_v)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], OptState]
    update: Callable[..., tuple[Pytree, OptState]]
    name: str


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        momentum = kw.get("momentum", 0.0)
        return Optimizer(
            init=lambda p: sgd_init(p, momentum),
            update=lambda g, s, p, lr: sgd_update(g, s, p, lr, momentum),
            name="sgd",
        )
    if name == "adamw":
        return Optimizer(
            init=adamw_init,
            update=lambda g, s, p, lr: adamw_update(g, s, p, lr, **kw),
            name="adamw",
        )
    raise ValueError(name)
