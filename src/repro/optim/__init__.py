from .optimizers import (
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    sgd_init,
    sgd_update,
    make_optimizer,
)

__all__ = [
    "OptState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "sgd_init",
    "sgd_update",
    "make_optimizer",
]
