"""Event-driven replay of the plan lifecycle over a ``ClusterSpec``.

Three layers, all running on the same :class:`~repro.sim.events.EventQueue`:

* :func:`simulate_train_iteration` — one S-SGD iteration as a DAG of
  events (Shi et al., arXiv 1805.03812): every host emits a
  gradient-ready event per schedule group as its (straggler-scaled)
  backward pass crosses the group's lowest layer; a group's merged
  all-reduce issues once *all* hosts are ready and the single serialized
  comm channel is free, in backward order — exactly the
  ``core.timeline.evaluate`` semantics.  With homogeneous multipliers the
  trace is bit-identical to ``evaluate`` (pinned by ``tests/test_sim.py``),
  which is what the calibration layer leans on.

* :func:`replay_train` — many iterations over an elastic fleet: every
  ``ClusterEvent`` shrink/grow/kill changes the alive-host count, the
  fabric re-prices the all-reduce at the new two-tier geometry, and the
  scheduler policy *re-plans* (the merge set is a function of (a, b), so
  elasticity must be allowed to move it).

* :func:`replay_serve` — decode steps over N simulated replicas driven
  by the seeded ``serving.fleet.LoadGenerator`` traffic: plan-priced
  min-ETA routing, slot-bound admission at step boundaries, deadline
  shedding, and kill-triggered in-flight failover with partial progress
  preserved — the fleet controller's semantics without the engines.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from ..core.cost_model import Hardware, LayerCost, TPU_V5E
from ..core.timeline import GroupTrace, comm_avail_times
from ..planning.registry import build_schedule
from .cluster import ClusterSpec
from .events import EventQueue


# ---------------------------------------------------------------------------
# One training iteration as a discrete-event timeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimIteration:
    """Event-driven timeline of one simulated S-SGD iteration.

    ``t_f``/``t_b`` are the *baseline* (multiplier-1) compute times;
    ``t_compute`` is the slowest host's scaled forward+backward — with
    stragglers the iteration can end on compute, not comm.  In the
    homogeneous case every field matches ``core.timeline.evaluate``."""

    t_iter: float
    t_f: float
    t_b: float
    t_compute: float
    t_comm_total: float
    t_comm_exposed: float
    groups: tuple[GroupTrace, ...]
    n_events: int

    @property
    def scaling_efficiency(self) -> float:
        """Per-worker weak-scaling efficiency S(N)/N = (t_f+t_b)/t_iter
        (paper Eq. 4) against the baseline compute time."""
        return (self.t_f + self.t_b) / self.t_iter

    def speedup(self, n: int) -> float:
        """S(N) = N (t_f + t_b) / t_iter (paper Eq. 4)."""
        return n * self.scaling_efficiency


def simulate_train_iteration(
    groups: Sequence[tuple[int, int]],
    costs: list[LayerCost],
    ar_model,
    hw: Hardware = TPU_V5E,
    t_f: float | None = None,
    multipliers: Sequence[float] = (1.0,),
    mode: str = "overlap",
) -> SimIteration:
    """Replay one iteration of a merged-group schedule event by event.

    Each host ``h`` runs forward+backward scaled by ``multipliers[h]``
    and emits one ready event per group when the group's lowest layer's
    gradient lands; the merged all-reduce of a group starts at
    ``max(all hosts ready, channel free)`` in backward order on the one
    serialized channel.  ``multipliers=(1.0,) * n`` reproduces
    ``core.timeline.evaluate`` exactly — same floats, same trace.

    ``mode`` selects the issue-order model (``core.timeline.MODES``):
    under ``serialized`` each host's ready events fire only at the end of
    its (scaled) backward pass, replaying the post-backward step."""
    if not multipliers:
        raise ValueError("need at least one host multiplier")
    if any(m < 1.0 for m in multipliers):
        raise ValueError(f"multipliers must be >= 1, got {multipliers}")
    if t_f is None:
        t_f = sum(c.t_f(hw) for c in costs)
    t_b_total = sum(c.t_b(hw) for c in costs)
    avail = comm_avail_times(costs, hw, t_f, mode)

    order = list(reversed(list(groups)))  # backward (descending) issue order
    nbytes = [
        sum(costs[i - 1].grad_bytes for i in range(lo, hi + 1)) for lo, hi in order
    ]

    q = EventQueue()
    for gi, (lo, _hi) in enumerate(order):
        for h, m in enumerate(multipliers):
            q.push(m * avail[lo], "host_grad", host=h, group=gi)

    pending = [len(multipliers)] * len(order)  # hosts not yet ready per group
    ready_at = [0.0] * len(order)
    traces: list[GroupTrace] = []
    channel_free = 0.0
    next_issue = 0
    while len(q):
        ev = q.pop()
        gi = ev.payload["group"]
        pending[gi] -= 1
        ready_at[gi] = max(ready_at[gi], ev.time)
        # issue every group whose turn has come and whose hosts are done
        while next_issue < len(order) and pending[next_issue] == 0:
            lo, hi = order[next_issue]
            t_avail = ready_at[next_issue]
            start = max(channel_free, t_avail)
            finish = start + ar_model(nbytes[next_issue])
            traces.append(GroupTrace((lo, hi), nbytes[next_issue], t_avail, start, finish))
            channel_free = finish
            next_issue += 1

    t_compute = max(m * (t_f + t_b_total) for m in multipliers)
    t_iter = max(traces[-1].finish, t_compute)
    return SimIteration(
        t_iter=t_iter,
        t_f=t_f,
        t_b=t_b_total,
        t_compute=t_compute,
        t_comm_total=sum(tr.finish - tr.start for tr in traces),
        t_comm_exposed=t_iter - t_compute,
        groups=tuple(traces),
        n_events=q.popped,
    )


# ---------------------------------------------------------------------------
# Elastic multi-iteration train replay
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainReplayResult:
    """Per-iteration trace of one (policy, cluster) train replay.

    ``iterations`` rows carry ``{iter, n_alive, n_groups, t_iter_s,
    t_compute_s, t_comm_exposed_s, efficiency, replanned}``;
    ``n_replans`` counts elastic re-plans after iteration 0 and
    ``n_kills`` the hosts lost to ``kill`` events."""

    policy: str
    cluster: dict[str, Any]
    iterations: tuple[dict[str, Any], ...]
    n_replans: int
    n_kills: int

    @property
    def mean_t_iter(self) -> float:
        return sum(r["t_iter_s"] for r in self.iterations) / len(self.iterations)

    @property
    def mean_efficiency(self) -> float:
        return sum(r["efficiency"] for r in self.iterations) / len(self.iterations)


def replay_train(
    cluster: ClusterSpec,
    costs: list[LayerCost],
    policy: str,
    *,
    hw: Hardware = TPU_V5E,
    n_iters: int = 1,
    t_f: float | None = None,
    policy_opts: dict[str, Any] | None = None,
) -> TrainReplayResult:
    """Replay ``n_iters`` S-SGD iterations of ``policy`` over ``cluster``.

    Whenever a scripted cluster event changes the alive-host count, the
    all-reduce is re-priced at the new two-tier geometry and the policy
    re-plans — the simulated form of the elastic replanning the serving
    stack does on degraded fabrics.  Pure function of its inputs: one
    spec, one trace.  ``policy_opts`` may carry ``mode`` (see
    ``core.timeline.MODES``); the same mode then drives both the
    re-planning and the per-iteration event replay."""
    mode = (policy_opts or {}).get("mode", "overlap")
    iterations: list[dict[str, Any]] = []
    n_alive_prev = -1
    schedule = None
    n_replans = 0
    kills_total = 0
    for i in range(max(1, int(n_iters))):
        n_alive, kills_total = cluster.alive_after(i)
        replanned = n_alive != n_alive_prev
        if replanned:
            ar = cluster.ar_model(n_alive)
            schedule = build_schedule(
                policy, costs, ar, hw=hw, t_f=t_f, **(policy_opts or {})
            )
            if i > 0:
                n_replans += 1
            n_alive_prev = n_alive
        it = simulate_train_iteration(
            schedule.groups,
            costs,
            ar,
            hw=hw,
            t_f=t_f,
            multipliers=cluster.straggler_multipliers(n_alive),
            mode=mode,
        )
        iterations.append(
            {
                "iter": i,
                "n_alive": n_alive,
                "n_groups": len(schedule.groups),
                "t_iter_s": it.t_iter,
                "t_compute_s": it.t_compute,
                "t_comm_exposed_s": it.t_comm_exposed,
                "efficiency": it.scaling_efficiency,
                "replanned": replanned and i > 0,
            }
        )
    return TrainReplayResult(
        policy=policy,
        cluster=cluster.to_json_dict(),
        iterations=tuple(iterations),
        n_replans=n_replans,
        n_kills=kills_total,
    )


# ---------------------------------------------------------------------------
# Serve-side replay: decode steps over simulated replicas
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeSimResult:
    """Outcome of one simulated fleet serve run.

    ``duration_s`` is the last completion instant; ``tokens_per_s`` is
    emitted tokens over that span (steady-state decode throughput —
    admission/prefill cost is out of scope, see ``sim.calibrate``)."""

    completed: int
    shed: int
    lost: int
    failovers: int
    steps: int
    tokens_emitted: int
    duration_s: float
    latencies_s: tuple[float, ...]

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_emitted / self.duration_s if self.duration_s > 0 else 0.0

    def latency_percentile(self, pct: float) -> float:
        """Completion-latency percentile (0 when nothing completed)."""
        if not self.latencies_s:
            return 0.0
        xs = sorted(self.latencies_s)
        idx = min(len(xs) - 1, max(0, round(pct / 100.0 * (len(xs) - 1))))
        return xs[idx]

    def to_json_dict(self) -> dict[str, Any]:
        return {
            "completed": self.completed,
            "shed": self.shed,
            "lost": self.lost,
            "failovers": self.failovers,
            "steps": self.steps,
            "tokens_emitted": self.tokens_emitted,
            "duration_s": self.duration_s,
            "tokens_per_s": self.tokens_per_s,
            "p50_s": self.latency_percentile(50),
            "p99_s": self.latency_percentile(99),
        }


@dataclasses.dataclass
class _Replica:
    step_s: float
    slots: int
    alive: bool = True
    busy: bool = False
    active: dict[int, int] = dataclasses.field(default_factory=dict)
    queue: list[int] = dataclasses.field(default_factory=list)

    def backlog_tokens(self, remaining: dict[int, int]) -> int:
        return sum(remaining[r] for r in self.active) + sum(
            remaining[r] for r in self.queue
        )


def replay_serve(
    load,
    step_s: float,
    *,
    n_replicas: int = 1,
    slots: int = 2,
    multipliers: Sequence[float] | None = None,
    kill_at_s: dict[int, float] | None = None,
) -> ServeSimResult:
    """Simulate decode serving of a seeded load over ``n_replicas``.

    ``load`` is a ``serving.fleet.LoadSpec`` (or a materialized
    ``LoadGenerator``) — the same seeded traffic object the real fleet
    replays, so a simulated and a real run see identical arrivals.
    ``step_s`` is the plan-predicted decode-step seconds
    (``ServePlan.predicted_step_time()``), scaled per replica by
    ``multipliers``.  Requests route to the alive replica with the
    cheapest plan-priced ETA (backlog tokens x step), are shed when a
    deadline can't be met, admit into ``slots`` decode rows at step
    boundaries, and fail over — partial progress preserved — when
    ``kill_at_s`` kills their replica mid-flight."""
    from ..serving.fleet import LoadGenerator, LoadSpec

    if isinstance(load, LoadSpec):
        load = LoadGenerator(load)
    if step_s <= 0:
        raise ValueError(f"step_s must be > 0, got {step_s}")
    mults = tuple(multipliers) if multipliers else (1.0,) * n_replicas
    if len(mults) != n_replicas:
        raise ValueError(f"need {n_replicas} multipliers, got {len(mults)}")
    deadline = load.spec.deadline_s

    replicas = [_Replica(step_s=step_s * m, slots=slots) for m in mults]
    remaining: dict[int, int] = {}
    arrival: dict[int, float] = {}
    latencies: list[float] = []
    completed = shed = lost = failovers = steps = tokens = 0
    last_done = 0.0

    q = EventQueue()
    for off, req in load.due(float("inf")):
        q.push(off, "arrival", rid=req.rid, tokens=req.max_new_tokens)
    for rep_id, t_kill in sorted((kill_at_s or {}).items()):
        q.push(t_kill, "kill", replica=int(rep_id))

    def eta_s(rep: _Replica, rid: int) -> float:
        return rep.step_s * (rep.backlog_tokens(remaining) + remaining[rid])

    def route(rid: int, now: float) -> None:
        nonlocal shed, lost
        alive = [(i, r) for i, r in enumerate(replicas) if r.alive]
        if not alive:
            lost += 1
            return
        best_i, best = min(alive, key=lambda ir: (eta_s(ir[1], rid), ir[0]))
        if deadline is not None and eta_s(best, rid) > deadline:
            shed += 1
            return
        best.queue.append(rid)
        pump(best_i, now)

    def pump(i: int, now: float) -> None:
        """Admit queued requests into free slots at a step boundary (never
        mid-step — a row joining a step in flight would be a free token)
        and keep the replica stepping."""
        rep = replicas[i]
        if not rep.alive or rep.busy:
            return
        while rep.queue and len(rep.active) < rep.slots:
            rid = rep.queue.pop(0)
            rep.active[rid] = remaining[rid]
        if rep.active and not rep.busy:
            rep.busy = True
            q.push(now + rep.step_s, "step", replica=i)

    while len(q):
        ev = q.pop()
        now = ev.time
        if ev.kind == "arrival":
            rid = ev.payload["rid"]
            remaining[rid] = int(ev.payload["tokens"])
            arrival[rid] = now
            route(rid, now)
        elif ev.kind == "kill":
            rep = replicas[ev.payload["replica"]]
            if not rep.alive:
                continue
            rep.alive = False
            stranded = list(rep.active) + rep.queue
            rep.active.clear()
            rep.queue.clear()
            for rid in stranded:  # partial progress preserved: remaining stands
                failovers += 1
                route(rid, now)
        elif ev.kind == "step":
            i = ev.payload["replica"]
            rep = replicas[i]
            rep.busy = False
            if not rep.alive:
                continue  # the kill beat the in-flight step; tokens lost
            steps += 1
            for rid in list(rep.active):
                remaining[rid] -= 1
                tokens += 1
                if remaining[rid] == 0:
                    del rep.active[rid]
                    latencies.append(now - arrival[rid])
                    completed += 1
                    last_done = max(last_done, now)
            pump(i, now)

    return ServeSimResult(
        completed=completed,
        shed=shed,
        lost=lost,
        failovers=failovers,
        steps=steps,
        tokens_emitted=tokens,
        duration_s=last_done,
        latencies_s=tuple(latencies),
    )
