"""Deterministic discrete-event core of the what-if simulator.

The simulator replays the plan lifecycle (gradient readiness, merged
all-reduce issue, decode steps) as an event-driven timeline rather than
a closed-form formula, so heterogeneous fleets — per-host straggler
multipliers, elastic shrink/grow, replica kills — fall out of the same
machinery that reproduces ``core.timeline.evaluate`` exactly in the
homogeneous case (pinned by ``tests/test_sim.py``).

Determinism contract: events are ordered by ``(time, seq)`` where
``seq`` is the push order — ties at the same simulated instant resolve
in insertion order, never by payload identity or hash order, so one
seed always yields one byte-identical trace.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence: ``kind`` at simulated ``time`` seconds.

    ``payload`` carries kind-specific data (host id, group index,
    replica id, request).  ``seq`` is the queue-assigned tiebreak — two
    events at the same instant fire in push order."""

    time: float
    kind: str
    payload: dict[str, Any]
    seq: int = 0


class EventQueue:
    """Min-heap of :class:`Event` ordered by ``(time, seq)``.

    ``pop`` enforces monotonic time (an event scheduled in the past is a
    simulator bug, not a tolerable race), and ``pushed``/``popped``
    counters make event volume observable in reports."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0
        self.now = 0.0
        self.pushed = 0
        self.popped = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time: float, kind: str, **payload: Any) -> Event:
        """Schedule ``kind`` at ``time``; returns the enqueued event."""
        if time < 0.0:
            raise ValueError(f"event {kind!r} scheduled at negative time {time}")
        ev = Event(time=float(time), kind=kind, payload=payload, seq=self._seq)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.pushed += 1
        return ev

    def pop(self) -> Event:
        """Next event in ``(time, seq)`` order; advances ``now``."""
        if not self._heap:
            raise IndexError("pop from empty EventQueue")
        time, _, ev = heapq.heappop(self._heap)
        if time < self.now - 1e-15:
            raise RuntimeError(
                f"event {ev.kind!r} at t={time} fires before now={self.now}"
            )
        self.now = max(self.now, time)
        self.popped += 1
        return ev
