"""Hypothetical-fleet description: the frozen ``ClusterSpec``.

A ``ClusterSpec`` is to the simulator what a mesh is to the launcher: a
JSON-serializable record of the fleet geometry the what-if run prices —
host count (up to :data:`MAX_HOSTS`), the two-tier ICI+DCN hierarchy
(``ici_size`` hosts per fast-tier domain, the rest rides the ``'pod'``
axis), the fabric preset that prices the collectives, seeded
heterogeneous per-host straggler multipliers, and a scripted list of
elastic :class:`ClusterEvent`\\ s (shrink / grow / kill).

Everything is a pure function of the spec's fields — two identical
specs simulate byte-identically, which is what lets ``SimReport``
promise determinism.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any

import numpy as np

#: Upper bound on simulated fleet size — the ISSUE's 512-host envelope.
MAX_HOSTS = 512

CLUSTER_SPEC_FORMAT = 1


@dataclasses.dataclass(frozen=True)
class ClusterEvent:
    """One scripted elastic transition, applied before iteration ``at_iter``.

    ``kind='shrink'`` removes ``count`` hosts (elastic scale-down or a
    correlated failure), ``kind='grow'`` adds ``count`` hosts back,
    ``kind='kill'`` is a hard replica kill — for the train replay it is
    a shrink that also counts toward the kill tally; the serve replay
    fails over the victim's in-flight requests."""

    at_iter: int
    kind: str  # 'shrink' | 'grow' | 'kill'
    count: int = 1

    def __post_init__(self) -> None:
        if self.kind not in ("shrink", "grow", "kill"):
            raise ValueError(f"unknown cluster event kind {self.kind!r}")
        if self.at_iter < 0 or self.count < 1:
            raise ValueError(f"bad cluster event {self}")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Frozen description of one hypothetical fleet.

    Attributes:
      n_hosts:          data-parallel hosts at iteration 0 (1..MAX_HOSTS).
      ici_size:         hosts per fast-tier (ICI/NVLink) domain; hosts
                        beyond one domain communicate over the ``'pod'``
                        (DCN) axis.  ``ici_size >= n_hosts`` = one flat
                        fast tier (the paper's single-switch 10GbE rack).
      fabric:           fabric-registry preset name pricing collectives.
      straggler_spread: per-host compute multipliers are drawn uniformly
                        from ``[1, 1 + spread]`` — 0.0 = homogeneous.
      seed:             seeds the straggler draw (and nothing else).
      events:           scripted elastic transitions (see ClusterEvent).
      name:             label for reports (defaults to a geometry string).
    """

    n_hosts: int
    ici_size: int = 0  # 0 = flat: one fast-tier domain spanning the fleet
    fabric: str = "tpu_v5e"
    straggler_spread: float = 0.0
    seed: int = 0
    events: tuple[ClusterEvent, ...] = ()
    name: str = ""

    def __post_init__(self) -> None:
        if not (1 <= self.n_hosts <= MAX_HOSTS):
            raise ValueError(
                f"n_hosts must be in 1..{MAX_HOSTS}, got {self.n_hosts}"
            )
        if self.ici_size < 0:
            raise ValueError(f"ici_size must be >= 0, got {self.ici_size}")
        if self.straggler_spread < 0:
            raise ValueError(
                f"straggler_spread must be >= 0, got {self.straggler_spread}"
            )
        if not self.name:
            object.__setattr__(
                self,
                "name",
                f"{self.fabric}x{self.n_hosts}"
                + (f"i{self.ici_size}" if self.ici_size else ""),
            )
        object.__setattr__(self, "events", tuple(self.events))

    # -- geometry -----------------------------------------------------------

    def axis_sizes(self, n_alive: int | None = None) -> dict[str, int]:
        """Two-tier mesh axes for ``n_alive`` hosts: the fast tier holds
        ``min(n, ici_size)`` hosts on ``'data'``, the remainder stacks on
        the cross-domain ``'pod'`` axis — exactly the shape every
        ``Fabric.cost`` composes hierarchically."""
        n = self.n_hosts if n_alive is None else int(n_alive)
        if n < 1:
            raise ValueError(f"n_alive must be >= 1, got {n}")
        ici = self.ici_size if self.ici_size else n
        fast = min(n, ici)
        pods = math.ceil(n / fast)
        return {"data": fast, "pod": pods} if pods > 1 else {"data": fast}

    def ar_model(self, n_alive: int | None = None):
        """The fleet's effective all-reduce ``AllReduceModel`` at
        ``n_alive`` hosts: the registered fabric priced at this spec's
        two-tier geometry (re-derived on every elastic transition)."""
        from ..fabric import Collective, get_fabric

        return get_fabric(self.fabric).cost(
            Collective.ALL_REDUCE, self.axis_sizes(n_alive)
        )

    def straggler_multipliers(self, n_alive: int | None = None) -> tuple[float, ...]:
        """Per-host compute multipliers (>= 1), seeded and stable: the
        draw is made once for all ``n_hosts`` slots, so host ``i`` keeps
        its multiplier across shrink/grow transitions."""
        n = self.n_hosts if n_alive is None else int(n_alive)
        if self.straggler_spread == 0.0:
            return (1.0,) * n
        rng = np.random.default_rng(self.seed)
        draw = 1.0 + self.straggler_spread * rng.random(max(n, self.n_hosts))
        return tuple(float(m) for m in draw[:n])

    def alive_after(self, iteration: int) -> tuple[int, int]:
        """(n_alive, n_kills) once every event with ``at_iter <=
        iteration`` has been applied, clamped to ``1..MAX_HOSTS``."""
        n, kills = self.n_hosts, 0
        for ev in self.events:
            if ev.at_iter > iteration:
                continue
            if ev.kind == "grow":
                n += ev.count
            else:
                n -= ev.count
                if ev.kind == "kill":
                    kills += ev.count
        return max(1, min(n, MAX_HOSTS)), kills

    # -- serialization (mirrors planning.Plan) ------------------------------

    def to_json_dict(self) -> dict[str, Any]:
        return {
            "format": CLUSTER_SPEC_FORMAT,
            "n_hosts": self.n_hosts,
            "ici_size": self.ici_size,
            "fabric": self.fabric,
            "straggler_spread": self.straggler_spread,
            "seed": self.seed,
            "events": [dataclasses.asdict(e) for e in self.events],
            "name": self.name,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), indent=1, sort_keys=True)

    @classmethod
    def from_json_dict(cls, d: dict[str, Any]) -> "ClusterSpec":
        if d.get("format") != CLUSTER_SPEC_FORMAT:
            raise ValueError(f"unsupported cluster spec format {d.get('format')!r}")
        return cls(
            n_hosts=int(d["n_hosts"]),
            ici_size=int(d["ici_size"]),
            fabric=d["fabric"],
            straggler_spread=float(d["straggler_spread"]),
            seed=int(d["seed"]),
            events=tuple(ClusterEvent(**e) for e in d["events"]),
            name=d.get("name", ""),
        )

    @classmethod
    def from_json(cls, text: str) -> "ClusterSpec":
        return cls.from_json_dict(json.loads(text))
