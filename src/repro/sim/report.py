"""The frozen ``SimReport`` artifact: what-if curves you can plan from.

A ``SimReport`` is the simulator's counterpart of ``Plan``/``ServePlan``:
a JSON-serializable, byte-deterministic record of every simulated
(policy x fleet x fabric) cell — scaling-efficiency and iteration-time
curves plus the calibration section that anchors them to real runs.
``best_policy`` makes the artifact directly reusable as a plan-selection
input: pick the argmin-t_iter policy for the fleet you intend to run,
exactly as ``Tuner.sweep`` does for measured costs.

Determinism contract: ``to_json`` serializes with sorted keys and no
timestamps, and every number is a pure function of the specs and seeds
that produced it — identical seeds => byte-identical report (asserted by
``BENCH_sim.json``'s determinism cell and ``tests/test_sim.py``).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

SIM_REPORT_FORMAT = 1


@dataclasses.dataclass(frozen=True)
class SimRow:
    """One simulated (policy x fleet x fabric) cell.

    ``t_iter_s``/``efficiency`` are means over the replayed iterations;
    ``n_groups`` is the final schedule's merge-set size."""

    arch: str
    policy: str
    fabric: str
    n_hosts: int
    n_groups: int
    t_iter_s: float
    t_compute_s: float
    t_comm_exposed_s: float
    efficiency: float

    def to_json_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def row_from_replay(result, arch: str, fabric: str, n_hosts: int) -> SimRow:
    """Condense one ``TrainReplayResult`` into a report row (means over
    its iterations; the last iteration's group count)."""
    last = result.iterations[-1]
    return SimRow(
        arch=arch,
        policy=result.policy,
        fabric=fabric,
        n_hosts=int(n_hosts),
        n_groups=int(last["n_groups"]),
        t_iter_s=result.mean_t_iter,
        t_compute_s=sum(r["t_compute_s"] for r in result.iterations)
        / len(result.iterations),
        t_comm_exposed_s=sum(r["t_comm_exposed_s"] for r in result.iterations)
        / len(result.iterations),
        efficiency=result.mean_efficiency,
    )


@dataclasses.dataclass(frozen=True)
class SimReport:
    """Frozen what-if record: rows + calibration + provenance."""

    rows: tuple[SimRow, ...]
    calibration: dict[str, Any] = dataclasses.field(default_factory=dict)
    provenance: dict[str, str] = dataclasses.field(default_factory=dict)

    def select(
        self,
        *,
        arch: str | None = None,
        fabric: str | None = None,
        n_hosts: int | None = None,
        policy: str | None = None,
    ) -> tuple[SimRow, ...]:
        """Rows matching every given filter (None = any)."""
        return tuple(
            r
            for r in self.rows
            if (arch is None or r.arch == arch)
            and (fabric is None or r.fabric == fabric)
            and (n_hosts is None or r.n_hosts == n_hosts)
            and (policy is None or r.policy == policy)
        )

    def best_policy(
        self,
        *,
        arch: str | None = None,
        fabric: str | None = None,
        n_hosts: int | None = None,
    ) -> str:
        """Argmin-``t_iter_s`` policy over the matching rows — the
        plan-selection read of the artifact (ties break by group count
        then name, mirroring ``Tuner.sweep``)."""
        rows = self.select(arch=arch, fabric=fabric, n_hosts=n_hosts)
        if not rows:
            raise ValueError(
                f"no rows match arch={arch} fabric={fabric} n_hosts={n_hosts}"
            )
        return min(rows, key=lambda r: (r.t_iter_s, r.n_groups, r.policy)).policy

    def efficiency_table(self) -> list[str]:
        """Human-readable per-(fleet, policy) scaling-efficiency lines —
        what ``launch/simulate.py --sweep-hosts`` prints."""
        lines = []
        for r in self.rows:
            lines.append(
                f"{r.arch},{r.fabric},hosts={r.n_hosts},{r.policy},"
                f"groups={r.n_groups},t_iter_ms={r.t_iter_s * 1e3:.3f},"
                f"exposed_ms={r.t_comm_exposed_s * 1e3:.3f},eff={r.efficiency:.4f}"
            )
        return lines

    # -- serialization ------------------------------------------------------

    def to_json_dict(self) -> dict[str, Any]:
        return {
            "format": SIM_REPORT_FORMAT,
            "rows": [r.to_json_dict() for r in self.rows],
            "calibration": dict(self.calibration),
            "provenance": dict(self.provenance),
        }

    def to_json(self) -> str:
        """Canonical byte-deterministic serialization (sorted keys, no
        timestamps): identical seeds => identical bytes."""
        return json.dumps(self.to_json_dict(), indent=1, sort_keys=True)

    @classmethod
    def from_json_dict(cls, d: dict[str, Any]) -> "SimReport":
        if d.get("format") != SIM_REPORT_FORMAT:
            raise ValueError(f"unsupported sim report format {d.get('format')!r}")
        return cls(
            rows=tuple(SimRow(**r) for r in d["rows"]),
            calibration=dict(d.get("calibration", {})),
            provenance=dict(d.get("provenance", {})),
        )

    @classmethod
    def from_json(cls, text: str) -> "SimReport":
        return cls.from_json_dict(json.loads(text))

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "SimReport":
        return cls.from_json(pathlib.Path(path).read_text())
