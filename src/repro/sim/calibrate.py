"""Calibration: anchor the simulator to the committed benchmark records.

A what-if extrapolation to 512 hosts is only worth reading if the same
simulator, run at the *real* small-mesh geometry, reproduces the numbers
the repo actually measured and committed.  This module replays:

* every row of ``benchmarks/results/BENCH_planning.json`` — the
  simulator rebuilds the sweep's exact inputs (the pinned
  ``_arch_sweep_inputs`` recipe: stacked layout at 16 model shards,
  analytic unit costs at 8192 tokens/device, the deterministic
  measured-3x profile) at the benchmark's {'data': 16, 'pod': 2}
  geometry and must match each committed ``t_iter_s``;

* ``benchmarks/results/BENCH_serve_exec.json`` — the serve replay runs
  the same slot-bound decode workload twice, once at the plan-predicted
  step time (``t_step_fixed_s + t_wire_s``) and once at the engine's
  measured ``observed_step_s``; the throughput ratio is the honest
  predicted-vs-observed decode figure.  (The record's end-to-end
  ``tokens_per_s`` includes admission/prefill/compile, which the plan
  deliberately does not price — the step wall is the calibrated term.)

Every comparison must land within :data:`DEFAULT_RATIO_BUDGET` (the
ISSUE's pinned <= 1.25x error budget); ``CalibrationReport.ok`` is the
gate CI asserts.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

from .cluster import ClusterSpec
from .replay import replay_serve, simulate_train_iteration

#: Pinned calibration error budget: simulated vs committed-observed ratio.
DEFAULT_RATIO_BUDGET = 1.25

#: The geometry every committed BENCH_planning row was priced at.
BENCH_PLANNING_CLUSTER = ClusterSpec(n_hosts=32, ici_size=16, fabric="tpu_v5e")

_RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"


@dataclasses.dataclass(frozen=True)
class CalibrationRow:
    """One simulated-vs-observed comparison (ratio is always >= 1)."""

    name: str
    predicted: float
    observed: float

    @property
    def ratio(self) -> float:
        lo, hi = sorted((self.predicted, self.observed))
        return hi / lo if lo > 0 else float("inf")

    def to_json_dict(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "predicted": self.predicted,
            "observed": self.observed,
            "ratio": self.ratio,
        }


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """All calibration rows of one kind plus the pinned budget."""

    kind: str
    rows: tuple[CalibrationRow, ...]
    budget: float = DEFAULT_RATIO_BUDGET

    @property
    def max_ratio(self) -> float:
        return max((r.ratio for r in self.rows), default=0.0)

    @property
    def ok(self) -> bool:
        return bool(self.rows) and self.max_ratio <= self.budget

    def to_json_dict(self) -> dict[str, Any]:
        return {
            "kind": self.kind,
            "budget": self.budget,
            "max_ratio": self.max_ratio,
            "ok": self.ok,
            "rows": [r.to_json_dict() for r in self.rows],
        }


def _bench_planning_inputs(arch: str):
    """Rebuild one arch's sweep inputs exactly as ``benchmarks/run.py``'s
    ``_arch_sweep_inputs`` does (the recipe is pinned here: any drift
    there must move this function and regenerate BENCH_planning)."""
    from ..configs import get_config
    from ..core.cost_model import TPU_V5E
    from ..core.trainer import lm_unit_costs
    from ..launch.specs import param_specs
    from ..planning import MEASURED_HW, MeasuredCosts

    cfg = get_config(arch)
    shapes = param_specs(cfg)
    analytic = lm_unit_costs(cfg, shapes, tokens_per_device=8192, model_shards=16)
    measured = MeasuredCosts.from_unit_times(
        analytic,
        [c.t_b(TPU_V5E) * 3.0 for c in analytic],
        [c.t_f(TPU_V5E) * 3.0 for c in analytic],
        name="measured_3x",
    )
    return {
        "analytic": (analytic, TPU_V5E),
        "measured_3x": (measured.layer_costs(), MEASURED_HW),
    }


def calibrate_train(
    bench_path: str | pathlib.Path | None = None,
    budget: float = DEFAULT_RATIO_BUDGET,
) -> CalibrationReport:
    """Replay every committed BENCH_planning row through the simulator.

    For each (arch, policy, cost_source) row the policy re-plans on the
    rebuilt cost vector at the benchmark's real geometry and the DES
    replays one homogeneous iteration; the simulated ``t_iter`` must
    match the committed ``t_iter_s`` within ``budget``."""
    from ..planning.registry import build_schedule

    path = pathlib.Path(bench_path or _RESULTS_DIR / "BENCH_planning.json")
    records = json.loads(path.read_text())
    ar = BENCH_PLANNING_CLUSTER.ar_model()
    mults = (1.0,) * BENCH_PLANNING_CLUSTER.n_hosts
    by_arch: dict[str, Any] = {}
    rows = []
    for rec in records:
        arch = rec["arch"]
        if arch not in by_arch:
            by_arch[arch] = _bench_planning_inputs(arch)
        costs, hw = by_arch[arch][rec["cost_source"]]
        schedule = build_schedule(rec["policy"], list(costs), ar, hw=hw)
        sim = simulate_train_iteration(
            schedule.groups, list(costs), ar, hw=hw, multipliers=mults
        )
        rows.append(
            CalibrationRow(
                name=f"{arch}/{rec['policy']}/{rec['cost_source']}/t_iter",
                predicted=sim.t_iter,
                observed=rec["t_iter_s"],
            )
        )
    return CalibrationReport(kind="train", rows=tuple(rows), budget=budget)


def calibrate_serve(
    bench_path: str | pathlib.Path | None = None,
    budget: float = DEFAULT_RATIO_BUDGET,
) -> CalibrationReport:
    """Replay the committed serve-exec step model through the simulator.

    The same seeded slot-bound workload is simulated twice — at the
    plan-predicted step (``t_step_fixed_s + t_wire_s``) and at the
    engine's measured ``observed_step_s`` — and the resulting decode
    throughputs must agree within ``budget`` (they differ by exactly the
    committed observed/predicted step ratio)."""
    from ..serving.fleet import LoadSpec

    path = pathlib.Path(bench_path or _RESULTS_DIR / "BENCH_serve_exec.json")
    rec = json.loads(path.read_text())
    slots = int(rec["slots"])
    step_pred = float(rec["t_step_fixed_s"]) + float(rec["t_wire_s"])
    step_obs = float(rec["observed_step_s"])
    load = LoadSpec(
        n_requests=2 * slots,
        prompt_len=1,
        max_new_tokens=8,
        kind="trace",
        trace_arrivals_s=(0.0,) * (2 * slots),
        seed=0,
    )
    sim_pred = replay_serve(load, step_pred, n_replicas=1, slots=slots)
    sim_obs = replay_serve(load, step_obs, n_replicas=1, slots=slots)
    rows = (
        CalibrationRow(
            name=f"{rec['arch']}/decode_step_s",
            predicted=step_pred,
            observed=step_obs,
        ),
        CalibrationRow(
            name=f"{rec['arch']}/decode_tok_per_s",
            predicted=sim_pred.tokens_per_s,
            observed=sim_obs.tokens_per_s,
        ),
    )
    return CalibrationReport(kind="serve", rows=rows, budget=budget)
