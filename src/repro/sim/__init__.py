"""Fleet-scale what-if simulator: replay the plan lifecycle without hardware.

The paper validated MG-WFBP at 64 nodes by trace-based simulation; this
package does the same with strictly better inputs — the repo's own
fabric cost models (analytic presets or measured α–β fits), per-unit
compute probes, frozen ``Plan``/``ServePlan`` artifacts, and the seeded
fleet traffic traces.  A deterministic discrete-event simulator
(``events``/``replay``) replays backward-pass gradient readiness,
merged-group all-reduce issue per any registered policy, and serve-side
decode steps over hypothetical fleets described by a ``ClusterSpec``
(``cluster``): up to 512 hosts, two-tier ICI+DCN hierarchies,
heterogeneous stragglers, elastic shrink/grow, replica kills.

``calibrate`` anchors every extrapolation to the committed benchmark
records within a pinned <= 1.25x budget, and ``report`` freezes the
scaling-efficiency / serve-throughput curves into a byte-deterministic
``SimReport`` usable as a plan-selection input.

Entry points: ``launch/simulate.py`` (CLI), ``benchmarks/run.py sim``
(the gated ``BENCH_sim.json`` table); see ``docs/simulator.md``.
"""

from .calibrate import (
    DEFAULT_RATIO_BUDGET,
    CalibrationReport,
    CalibrationRow,
    calibrate_serve,
    calibrate_train,
)
from .cluster import MAX_HOSTS, ClusterEvent, ClusterSpec
from .events import Event, EventQueue
from .replay import (
    ServeSimResult,
    SimIteration,
    TrainReplayResult,
    replay_serve,
    replay_train,
    simulate_train_iteration,
)
from .report import SimReport, SimRow, row_from_replay

__all__ = [
    "CalibrationReport",
    "CalibrationRow",
    "ClusterEvent",
    "ClusterSpec",
    "DEFAULT_RATIO_BUDGET",
    "Event",
    "EventQueue",
    "MAX_HOSTS",
    "ServeSimResult",
    "SimIteration",
    "SimReport",
    "SimRow",
    "TrainReplayResult",
    "calibrate_serve",
    "calibrate_train",
    "replay_serve",
    "replay_train",
    "row_from_replay",
    "simulate_train_iteration",
]
