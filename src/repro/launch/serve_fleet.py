"""Fleet launcher: N health-checked serving replicas behind the
SLO-aware router, under seeded chaos and seeded load.

    PYTHONPATH=src python -m repro.launch.serve_fleet --arch tinyllama-1.1b \\
        --reduced --replicas 4 --requests 32 --rate 200 \\
        --chaos-kill-at 3 --chaos-replicas 0 --deadline-ms 2000

One process, N ``ServingEngine`` replicas, one ``FleetController``
(``serving.fleet``): a seeded Poisson/trace ``LoadGenerator`` offers
traffic, the router places each request on the replica with the
cheapest plan-priced ETA (shedding it fleet-wide when no replica's
``ServePlan.predicted_step_time()`` meets its deadline), per-replica
``ChaosInjector`` fault domains are derived from one fleet seed
(``ChaosConfig.for_replica``), and a replica that spends its restore
budget fails its in-flight requests over to healthy peers with their
partial output preserved.  ``--elastic`` lets the plan-priced watchdog
add/retire replicas under backlog.  The run prints offered/completed/
shed counts, p50/p99 latency, goodput, and the failover ledger —
``failover_token_mismatches`` must always be 0.
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_config, get_reduced
from ..fabric import available_fabrics
from ..launch.specs import param_specs
from ..models.transformer import init_params
from ..planning import available_policies, build_serve_plan
from ..serving import (
    ChaosConfig,
    FleetConfig,
    FleetController,
    LoadGenerator,
    LoadSpec,
    ServingEngine,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch slots per replica")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson offered load, requests/second")
    ap.add_argument("--trace", default=None,
                    help="comma-separated arrival offsets (seconds); "
                         "overrides --rate with a trace schedule")
    ap.add_argument("--load-seed", type=int, default=0,
                    help="seed for arrivals and prompts (one seed replays "
                         "the whole offered load exactly)")
    ap.add_argument("--fabric", default="tpu_v5e",
                    choices=list(available_fabrics()))
    ap.add_argument("--policy", default="mg_wfbp",
                    choices=list(available_policies()))
    ap.add_argument("--virtual-tp", type=int, default=8,
                    help="TP size the serve plan prices collectives at")
    # SLO
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO relative to its arrival; requests "
                         "no replica can finish in time are shed at admission")
    # chaos
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="ONE fleet seed; each replica's fault domain is "
                         "derived deterministically (ChaosConfig.for_replica)")
    ap.add_argument("--chaos-kill-at", type=int, default=None,
                    help="kill each chaos replica at this local serve step")
    ap.add_argument("--chaos-kill-every", type=int, default=0)
    ap.add_argument("--chaos-slow-factor", type=float, default=1.0)
    ap.add_argument("--chaos-slow-after", type=int, default=None)
    ap.add_argument("--chaos-replicas", default=None,
                    help="comma-separated replica ids the chaos schedule "
                         "applies to (default: all replicas)")
    # fleet knobs
    ap.add_argument("--max-restores", type=int, default=1,
                    help="per-replica in-place snapshot-restore budget; past "
                         "it the replica dies and its requests fail over")
    ap.add_argument("--snapshot-every", type=int, default=8)
    ap.add_argument("--snapshot-root", default=None,
                    help="root dir for per-replica snapshots (temp dir "
                         "when unset)")
    ap.add_argument("--heartbeat-timeout-s", type=float, default=None)
    ap.add_argument("--elastic", action="store_true",
                    help="apply watchdog scale decisions (otherwise they "
                         "are recorded, not applied)")
    ap.add_argument("--max-replicas", type=int, default=8)
    ap.add_argument("--scale-up-backlog-s", type=float, default=float("inf"),
                    help="scale up when the plan-priced backlog drain time "
                         "exceeds this")
    ap.add_argument("--scale-down-idle-rounds", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.tokens + 1

    cache_bytes = 4  # fp32 decode caches: price the plan at what ships
    plan = build_serve_plan(
        cfg, param_specs(cfg), args.fabric, {"model": args.virtual_tp},
        batch_rows=args.slots, policy=args.policy,
        cache_dtype_bytes=cache_bytes, act_dtype_bytes=cache_bytes,
    )
    print(f"[fleet] {plan.describe()}")

    def engine_factory(rid: int) -> ServingEngine:
        eng = ServingEngine(cfg, params, slots=args.slots, max_seq=max_seq,
                            plan=plan)
        eng.warmup()
        return eng

    chaos = None
    if (args.chaos_kill_at is not None or args.chaos_kill_every > 0
            or args.chaos_slow_factor != 1.0):
        chaos = ChaosConfig(
            seed=args.chaos_seed,
            kill_at=(args.chaos_kill_at,) if args.chaos_kill_at is not None
            else (),
            kill_every=args.chaos_kill_every,
            slow_factor=args.chaos_slow_factor,
            slow_after=args.chaos_slow_after,
        )
    chaos_replicas = (
        tuple(int(x) for x in args.chaos_replicas.split(","))
        if args.chaos_replicas is not None else None
    )

    spec = LoadSpec(
        n_requests=args.requests,
        prompt_len=args.prompt_len,
        max_new_tokens=args.tokens,
        kind="trace" if args.trace else "poisson",
        rate_rps=args.rate,
        trace_arrivals_s=(
            tuple(float(x) for x in args.trace.split(","))
            if args.trace else ()
        ),
        deadline_s=(args.deadline_ms / 1e3
                    if args.deadline_ms is not None else None),
        seed=args.load_seed,
        vocab=cfg.vocab,
    )
    snap_root = args.snapshot_root or tempfile.mkdtemp(prefix="serve_fleet_")

    fleet = FleetController(
        engine_factory=engine_factory,
        config=FleetConfig(
            replicas=args.replicas,
            snapshot_every=args.snapshot_every,
            max_restores=args.max_restores,
            heartbeat_timeout_s=args.heartbeat_timeout_s,
            elastic=args.elastic,
            max_replicas=args.max_replicas,
            scale_up_backlog_s=args.scale_up_backlog_s,
            scale_down_idle_rounds=args.scale_down_idle_rounds,
        ),
        snapshot_root=snap_root,
        chaos=chaos,
        chaos_replicas=chaos_replicas,
    )
    print(f"[fleet] {args.replicas} replicas x {args.slots} slots, "
          f"{args.requests} requests "
          f"({'trace' if args.trace else f'poisson {args.rate:.0f} rps'}), "
          f"chaos={'on' if chaos else 'off'} (snapshots in {snap_root})")

    report = fleet.run(LoadGenerator(spec))
    s = report.summary()
    print(f"[fleet] offered={s['offered']} completed={s['completed']} "
          f"shed={s['shed']} expired={s['expired']} rounds={s['rounds']}")
    print(f"[fleet] p50={s['p50_latency_s'] * 1e3:.1f}ms "
          f"p99={s['p99_latency_s'] * 1e3:.1f}ms "
          f"goodput={s['goodput_tok_per_s']:.1f} tok/s "
          f"({s['goodput_tokens']} tokens in {s['wall_s']:.2f}s)")
    print(f"[fleet] deaths={s['replica_deaths']} failovers={s['failovers']} "
          f"restores={s['restores']} replans={s['replans']} "
          f"scale_ups={s['scale_ups']} scale_downs={s['scale_downs']} "
          f"token_mismatches={s['failover_token_mismatches']}")
    for rep in report.replicas:
        print(f"[fleet]   replica {rep['rid']}: steps={rep['steps']} "
              f"restarts={rep['restarts']} replans={rep['replans']} "
              f"failed_over={rep['failed_over']} retired={rep['retired']}")
    if report.failover_token_mismatches:
        raise SystemExit("[fleet] FAILOVER TOKEN MISMATCH — partial prefixes "
                         "were not preserved")


if __name__ == "__main__":
    main()
