import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, prove memory fits, and extract the roofline terms.

For each cell this script:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers and compiles the full train_step / serve_step with production
     shardings (chunk 'map' mode -> realistic buffer reuse), printing
     ``compiled.memory_analysis()`` and ``compiled.cost_analysis()``,
  3. lowers the cost segments ('unroll' mode) and recomposes exact
     per-device FLOPs / bytes / collective traffic (see segments.py),
  4. derives the three roofline terms (compute / memory / collective)
     with the v5e constants, and
  5. appends a JSON record under benchmarks/results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-segments]
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import LONG_CONTEXT_SKIP, SHAPES, applicable_shapes
from repro.core.profiler import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.segments import (
    head_fwd_segment,
    head_train_segment,
    stage_fwd_segment,
    stage_train_segment,
)
from repro.launch.specs import (
    arch_config_for_shape,
    batch_input_specs,
    cache_specs,
    decode_input_specs,
    opt_state_specs,
    param_specs,
)
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.optim import make_optimizer
from repro.parallel.sharding import (
    batch_specs,
    cache_pspecs,
    named,
    param_pspecs,
    rules_for_arch,
)

# v5e constants (per chip) — the roofline denominators
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def model_flops_per_step(cfg, shape) -> float:
    """Paper-style useful flops: 6·N_active·tokens (train), 2·N_active·tokens (serve)."""
    import numpy as np

    shapes = param_specs(cfg)
    n_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if cfg.moe is not None:
        stages = shapes["stages"]
        n_exp = sum(
            int(np.prod(x.shape))
            for k, x in jax.tree_util.tree_leaves_with_path(stages)
            if any(str(getattr(p, "key", "")) in ("w_gate", "w_up", "w_down") for p in k)
        )
        n_active = n_total - n_exp + n_exp * cfg.moe.top_k / cfg.moe.n_experts
    else:
        n_active = n_total
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per row


def lower_cell(arch: str, shape_name: str, multi_pod: bool, fsdp_data=True,
               n_microbatches: int = 1, skip_segments: bool = False,
               overrides: dict | None = None, comm_fit: dict | None = None,
               fabric: str = "tpu_v5e") -> dict:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = arch_config_for_shape(arch, shape_name, cost_mode=False)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rules = rules_for_arch(cfg, mesh, fsdp_data=fsdp_data)
    n_dev = mesh.devices.size

    # GShard groups: multiple of the token-shard count, tg ~ 4096
    from repro.launch.specs import moe_groups_for
    seq_for_groups = shape.seq_len if shape.kind != "decode" else 1
    cfg = dataclasses.replace(
        cfg, moe_groups=moe_groups_for(rules, shape.global_batch, seq_for_groups)
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "fsdp_data": fsdp_data,
        "n_microbatches": n_microbatches,
    }

    p_shapes = param_specs(cfg)
    p_sh = named(param_pspecs(p_shapes, rules), mesh)

    t0 = time.time()
    if shape.kind == "train":
        from repro.optim.optimizers import OptState

        opt = make_optimizer("adamw")
        o_shapes = opt_state_specs(cfg, opt)
        # optimizer state shards like its parameter (FSDP/ZeRO for free)
        o_sh = OptState(
            step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            m=p_sh,
            v=p_sh,
        )
        b_specs = batch_input_specs(cfg, shape)
        b_sh = named(batch_specs(cfg, rules, shape.global_batch, shape.seq_len), mesh)
        step = make_train_step(cfg, rules, opt, n_microbatches=n_microbatches)
        with set_mesh(mesh):
            lowered = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(p_shapes, o_shapes, b_specs)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        b_specs = batch_input_specs(cfg, shape)
        b_specs.pop("targets")
        bsp = batch_specs(cfg, rules, shape.global_batch, shape.seq_len)
        bsp.pop("targets")
        b_sh = named(bsp, mesh)
        step = make_prefill_step(cfg, rules, max_seq=shape.seq_len)
        with set_mesh(mesh):
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(p_shapes, b_specs)
            compiled = lowered.compile()
    else:  # decode
        c_shapes = cache_specs(cfg, batch=shape.global_batch, max_seq=shape.seq_len)
        c_sh = named(cache_pspecs(cfg, rules, c_shapes, shape.global_batch), mesh)
        b_specs = decode_input_specs(cfg, shape)
        bsp = batch_specs(cfg, rules, shape.global_batch, 1)
        bsp.pop("targets")
        b_sh = named(bsp, mesh)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        step = make_decode_step(cfg, rules)
        with set_mesh(mesh):
            lowered = jax.jit(
                step, in_shardings=(p_sh, c_sh, b_sh, None), donate_argnums=(1,),
            ).lower(p_shapes, c_shapes, b_specs, pos)
            compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_per_device_gib": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3
        ),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    rec["whole_program"] = {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": dataclasses.asdict(parse_collectives(compiled.as_text())),
    }
    print(f"[{arch} x {shape_name} x {rec['mesh']}] compile {rec['compile_s']}s")
    print("  memory_analysis:", ma)
    print("  cost_analysis flops/device:", rec["whole_program"]["flops_per_device"])

    if not skip_segments:
        rec["segments"] = segment_costs(arch, shape_name, mesh, rules, overrides)
        rec["totals"] = recompose(cfg, shape, rec, n_dev)
    if shape.kind == "train":
        rec["plan"] = plan_record(cfg, shape, rec.get("segments"), mesh, n_dev,
                                  comm_fit=comm_fit, fabric=fabric)
    elif shape.kind == "decode":
        rec["serve_plan"] = serve_plan_record(cfg, shape, mesh, fabric=fabric)
    return rec


def serve_plan_record(cfg, shape, mesh, fabric: str = "tpu_v5e") -> dict:
    """Serialized decode-side ServePlan for this cell: the same merge math
    as the train plan, pricing the decode collective (KV all-gather /
    expert all-to-all) on the selected fabric over the mesh's model axis."""
    from repro.launch.specs import param_specs
    from repro.planning import build_serve_plan

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    plan = build_serve_plan(
        cfg, param_specs(cfg), fabric,
        {"model": axis_sizes.get("model", 1)},
        batch_rows=shape.global_batch,
        provenance={"shape": shape.name},
    )
    import textwrap

    print(textwrap.indent(plan.describe(), "  "))
    return plan.to_json_dict()


def plan_record(cfg, shape, segs, mesh, n_dev, comm_fit=None,
                fabric: str = "tpu_v5e") -> dict:
    """Serialized MG-WFBP plan(s) for this train cell.

    The analytic plan comes from Eq. 18 costs priced by the selected
    ``--fabric`` preset; when HLO segments were profiled, a measured plan
    re-runs the policy on per-unit segment times
    (``MeasuredCosts.from_segment_times``) — the dry-run analogue of the
    journal version's online re-plan.  ``comm_fit`` (a serialized
    ``MeasuredComm`` sweep, --comm-fit) swaps the analytic α–β model for
    a measured fit.  Restarts and benchmarks reload these records
    instead of recomputing Algorithm 1; each plan carries its per-group
    arena wire layout (``fuse='arena'`` buffer sizes).
    """
    from repro.core.bucketing import stacked_lm_layout
    from repro.fabric import get_fabric
    from repro.core.cost_model import TPU_V5E as HW_V5E
    from repro.core.trainer import lm_unit_costs
    from repro.planning import MeasuredComm, MeasuredCosts, build_plan, replan_if_drifted

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_shards = axis_sizes.get("model", 1)
    dp_axes = {k: v for k, v in axis_sizes.items() if k in ("pod", "data")}
    shapes_tree = param_specs(cfg)
    costs = lm_unit_costs(
        cfg, shapes_tree,
        tokens_per_device=shape.global_batch * shape.seq_len // n_dev,
        model_shards=model_shards,
    )
    layout = stacked_lm_layout(shapes_tree, cfg.n_stages, model_shards=model_shards)
    if comm_fit is not None:
        ar_model = MeasuredComm(
            sizes_bytes=tuple(comm_fit["sizes_bytes"]),
            times_s=tuple(comm_fit["times_s"]),
            axes=tuple(comm_fit.get("axes", ("data",))),
        ).fit()
        comm_source = "measured_comm"
    else:
        ar_model = get_fabric(fabric).cost("all_reduce", dp_axes)
        comm_source = fabric
    plan = build_plan(
        layout, costs, ar_model,
        policy="mg_wfbp", n_scan_stages=cfg.n_stages,
        provenance={"arch": cfg.name, "comm_source": comm_source},
    )
    out = {"analytic": plan.to_json_dict()}
    out["arena"] = [
        {"nbytes": a.nbytes, "n_slots": len(a.slots)}
        for a in plan.group_arenas(shapes_tree)
    ]
    if segs:
        # Segment roofline time covers fwd+bwd of a train segment; split
        # it 1/3 fwd + 2/3 bwd (the 2:4 flops ratio of Eq. 17/18).
        def seg_t(s):
            return max(s["flops"] / PEAK_FLOPS, s["bytes_accessed"] / HBM_BW)

        unit_seconds = {f"stage_{i}": 2 / 3 * seg_t(segs["stage"])
                        for i in range(cfg.n_stages)}
        if "tail" in segs:
            unit_seconds["tail"] = 2 / 3 * seg_t(segs["tail"])
        unit_seconds["head"] = 2 / 3 * seg_t(segs["head"])
        measured = MeasuredCosts.from_segment_times(
            costs, HW_V5E, unit_seconds, name="hlo_segments"
        )
        mplan, replanned = replan_if_drifted(plan, measured, threshold=0.05)
        out["measured"] = mplan.to_json_dict()
        out["replanned"] = replanned
    return out


def segment_costs(arch: str, shape_name: str, mesh, rules, overrides=None) -> dict:
    shape = SHAPES[shape_name]
    cfg = arch_config_for_shape(arch, shape_name, cost_mode=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **{k: v for k, v in overrides.items()
                                          if k != "chunk_impl"})
    from repro.launch.specs import moe_groups_for
    seq_for_groups = shape.seq_len if shape.kind != "decode" else 1
    cfg = dataclasses.replace(
        cfg, moe_groups=moe_groups_for(rules, shape.global_batch, seq_for_groups)
    )
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind == "train":
        st = stage_train_segment(cfg, rules, mesh, B, S)
        out["stage"] = dataclasses.asdict(st)
        if cfg.tail_pattern:
            out["tail"] = dataclasses.asdict(
                stage_train_segment(cfg, rules, mesh, B, S, pattern=cfg.tail_pattern)
            )
        out["head"] = dataclasses.asdict(head_train_segment(cfg, rules, mesh, B, S))
    elif shape.kind == "prefill":
        out["stage"] = dataclasses.asdict(stage_fwd_segment(cfg, rules, mesh, B, S))
        if cfg.tail_pattern:
            out["tail"] = dataclasses.asdict(
                stage_fwd_segment(cfg, rules, mesh, B, S, pattern=cfg.tail_pattern)
            )
        out["head"] = dataclasses.asdict(head_fwd_segment(cfg, rules, mesh, B, S))
    else:  # decode: one stage with caches
        c_shapes = cache_specs(cfg, batch=B, max_seq=S)
        c_sh_all = named(cache_pspecs(cfg, rules, c_shapes, B), mesh)
        one_stage_c = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), c_shapes["stages"]
        )
        one_stage_sh = jax.tree.map(
            lambda s: jax.NamedSharding(
                mesh, jax.sharding.PartitionSpec(*tuple(s.spec)[1:])
            ),
            c_sh_all["stages"],
            is_leaf=lambda x: isinstance(x, jax.NamedSharding),
        )
        out["stage"] = dataclasses.asdict(
            stage_fwd_segment(
                cfg, rules, mesh, B, 1,
                caches=one_stage_c, cache_sh=one_stage_sh, pos_value=S - 2,
            )
        )
        if cfg.tail_pattern:
            tail_c = c_shapes["tail"]
            tail_sh = c_sh_all["tail"]
            out["tail"] = dataclasses.asdict(
                stage_fwd_segment(
                    cfg, rules, mesh, B, 1,
                    caches=tail_c, cache_sh=tail_sh, pos_value=S - 2,
                    pattern=cfg.tail_pattern,
                )
            )
        out["head"] = dataclasses.asdict(head_fwd_segment(cfg, rules, mesh, B, 1))
    return out


def recompose(cfg, shape, rec, n_dev) -> dict:
    segs = rec["segments"]
    n_stages = cfg.n_stages

    def total(field):
        t = segs["head"][field] + segs["stage"][field] * n_stages
        if "tail" in segs:
            t += segs["tail"][field]
        return t

    flops_dev = total("flops")
    bytes_dev = total("bytes_accessed")
    coll_bytes_dev = (
        sum(segs["head"]["coll_bytes"].values())
        + sum(segs["stage"]["coll_bytes"].values()) * n_stages
        + (sum(segs["tail"]["coll_bytes"].values()) if "tail" in segs else 0)
    )
    mf = model_flops_per_step(cfg, shape)
    compute_t = flops_dev / PEAK_FLOPS
    memory_t = bytes_dev / HBM_BW
    coll_t = coll_bytes_dev / LINK_BW
    dom = max(("compute", compute_t), ("memory", memory_t), ("collective", coll_t),
              key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes_dev,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops_dev if flops_dev else 0.0,
        "compute_term_s": compute_t,
        "memory_term_s": memory_t,
        "collective_term_s": coll_t,
        "dominant": dom,
        "roofline_bound_s": max(compute_t, memory_t, coll_t),
        "ideal_compute_s": mf / n_dev / PEAK_FLOPS,
        "roofline_fraction": (mf / n_dev / PEAK_FLOPS) / max(compute_t, memory_t, coll_t)
        if max(compute_t, memory_t, coll_t) > 0
        else 0.0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp-data", action="store_true",
                    help="paper-faithful baseline: params replicated over data")
    ap.add_argument("--remat", default=None, choices=["full", "dots", "none"])
    ap.add_argument("--qchunk", type=int, default=None)
    ap.add_argument("--serve-sharding", default="experts_only",
                    choices=["experts_only", "full", "model_only"],
                    help="decode/prefill param sharding override")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--skip-segments", action="store_true")
    ap.add_argument("--comm-fit", default=None,
                    help="JSON file with a serialized MeasuredComm sweep "
                         "({sizes_bytes, times_s[, axes]}); plan records use "
                         "its α–β fit instead of the analytic fabric model")
    from repro.fabric import available_fabrics
    ap.add_argument("--fabric", default="tpu_v5e",
                    choices=list(available_fabrics()),
                    help="interconnect preset pricing the plan records "
                         "(train plans AND decode serve plans)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    comm_fit = json.loads(pathlib.Path(args.comm_fit).read_text()) if args.comm_fit else None

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shp in applicable_shapes(arch):
                cells.append((arch, shp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        if args.shape == "long_500k" and args.arch in LONG_CONTEXT_SKIP:
            print(f"SKIP {args.arch} x long_500k (pure full-attention; DESIGN.md §4)")
            return
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    ok, failed = 0, []
    for arch, shp in cells:
        for mp in meshes:
            tag = f"{arch}__{shp}__{'2x16x16' if mp else '16x16'}"
            try:
                overrides = {}
                if args.remat:
                    overrides["remat"] = args.remat
                if args.qchunk:
                    overrides["q_chunk"] = args.qchunk
                fsdp = not args.no_fsdp_data
                if args.serve_sharding and SHAPES[shp].kind == "decode":
                    # experts_only only matters (and only helps) for MoE
                    # archs — non-MoE decode keeps full ZeRO-3 sharding
                    from repro.configs import get_config as _gc
                    if _gc(arch).moe is not None or args.serve_sharding != "experts_only":
                        fsdp = {"experts_only": "experts_only", "full": True,
                                "model_only": False}[args.serve_sharding]
                rec = lower_cell(
                    arch, shp, mp,
                    fsdp_data=fsdp,
                    n_microbatches=args.microbatches,
                    skip_segments=args.skip_segments,
                    overrides=overrides or None,
                    comm_fit=comm_fit,
                    fabric=args.fabric,
                )
                out = pathlib.Path(args.out) if args.out else RESULTS_DIR / f"{tag}.json"
                out.write_text(json.dumps(rec, indent=1))
                ok += 1
            except Exception as e:
                failed.append((tag, repr(e)))
                print(f"FAILED {tag}: {e}")
                traceback.print_exc()
    print(f"\ndry-run complete: {ok} ok, {len(failed)} failed")
    for tag, err in failed:
        print(" FAIL:", tag, err[:200])
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
