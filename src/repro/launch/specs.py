"""ShapeDtypeStruct stand-ins for every model input of every (arch, shape)
cell — weak-type-correct, shardable, no device allocation."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..configs.shapes import SHAPES, ShapeSpec
from ..models import init_caches
from ..models.common import ArchConfig
from ..models.transformer import init_params
from ..optim.optimizers import Optimizer

Pytree = Any


def sds(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def param_specs(cfg: ArchConfig) -> Pytree:
    return sds(jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0)))


def opt_state_specs(cfg: ArchConfig, optimizer: Optimizer) -> Pytree:
    p = param_specs(cfg)
    return sds(jax.eval_shape(optimizer.init, p))


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int) -> Pytree:
    return sds(
        jax.eval_shape(lambda: init_caches(cfg, batch=batch, max_seq=max_seq))
    )


def batch_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Training/prefill inputs: tokens or stub frontend embeddings."""
    B, S = shape.global_batch, shape.seq_len
    out = {"targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.input_mode == "embeds":
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return out


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B = shape.global_batch
    if cfg.input_mode == "embeds":
        return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def moe_groups_for(rules, global_batch: int, seq_len: int, target_tg: int = 4096) -> int:
    """GShard group count: a multiple of the token-shard count keeping
    tokens-per-group near ``target_tg`` — the dispatch one-hot einsums
    cost 2·T·E·C·D with C ∝ tg, so large groups make dispatch dominate
    expert compute (tg/3F ratio; see models/moe.py)."""
    ba = rules.batch_axes(global_batch)
    shards = rules._axes_size(ba) if ba else 1
    tokens = global_batch * seq_len
    per_shard = tokens // shards
    m = max(1, per_shard // target_tg)
    while m > 1 and per_shard % m != 0:
        m -= 1
    return shards * m


def arch_config_for_shape(arch: str, shape_name: str, cost_mode: bool = False) -> ArchConfig:
    """Config tuned per shape: chunk sizes that bound dry-run memory in
    'map' mode, or 'unroll' for exact cost accounting in segments."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    q_chunk = 512 if shape.kind == "train" else 2048
    # the model sees *global* shapes under GSPMD: chunk counts must be set
    # from global token counts (map: ~16 chunks bounds per-chunk buffers;
    # unroll: ~4 keeps the cost-mode HLO small enough to compile)
    global_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_chunks = 4 if cost_mode else 16
    moe_chunk = max(2048, global_tokens // n_chunks)
    overrides = dict(
        q_chunk=min(q_chunk, shape.seq_len),
        chunk_impl="unroll" if cost_mode else "map",
        moe_token_chunk=min(moe_chunk, global_tokens),
        rec_chunk=128,
        remat="full" if shape.kind == "train" else "none",
    )
    return dataclasses.replace(cfg, **overrides)
