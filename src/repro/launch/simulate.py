"""Fleet-scale what-if CLI: replay the plan lifecycle over hypothetical
clusters and print per-policy scaling-efficiency curves.

    PYTHONPATH=src python -m repro.launch.simulate \\
        --arch googlenet --batch 64 --fabric paper_10gbe \\
        --sweep-hosts 8,64,512 --policies synceasgd,wfbp,mg_wfbp \\
        --report-out /tmp/simreport.json

No accelerator is touched: the discrete-event simulator (``repro.sim``)
re-plans each policy at every fleet geometry, prices the merged
all-reduces through the fabric registry, and replays the backward-pass /
comm overlap event by event.  ``--report-out`` freezes the sweep into a
byte-deterministic ``SimReport`` — directly reusable as a plan-selection
input (``SimReport.best_policy``).  ``--calibrate`` first replays the
real small-mesh geometry against the committed BENCH records and refuses
to extrapolate when the simulator is out of budget.

CNN archs (googlenet / resnet50, the paper's own workloads) price on the
K80-calibrated hardware model; LM archs price on the TPU analytic model
at the standard 16-way model sharding.
"""

from __future__ import annotations

import argparse

from ..configs import ARCH_NAMES
from ..fabric import available_fabrics
from ..planning import available_policies
from ..sim import (
    ClusterSpec,
    SimReport,
    calibrate_serve,
    calibrate_train,
    replay_train,
    row_from_replay,
)

CNN_ARCHS = ("googlenet", "resnet50")


def sim_layer_costs(arch: str, batch: int, tokens_per_device: int = 8192):
    """(costs, hw) for one arch: the paper's CNN profiles on calibrated
    K80 hardware, or an LM config's analytic unit costs on TPU."""
    if arch in CNN_ARCHS:
        from ..configs.cnn_profiles import cnn_layer_costs
        from ..core.cost_model import K80_CALIBRATED

        return cnn_layer_costs(arch, batch), K80_CALIBRATED
    from ..configs import get_config
    from ..core.cost_model import TPU_V5E
    from ..core.trainer import lm_unit_costs
    from ..launch.specs import param_specs

    cfg = get_config(arch)
    return (
        lm_unit_costs(cfg, param_specs(cfg),
                      tokens_per_device=tokens_per_device, model_shards=16),
        TPU_V5E,
    )


def main() -> None:
    ap = argparse.ArgumentParser(
        description="what-if simulator: policies x fleets x fabrics")
    ap.add_argument("--arch", default="googlenet",
                    choices=list(CNN_ARCHS) + list(ARCH_NAMES),
                    help="workload: the paper's CNNs (K80-calibrated "
                         "hardware) or an LM config (TPU analytic model)")
    ap.add_argument("--batch", type=int, default=64,
                    help="per-host batch size (CNN archs; paper uses "
                         "googlenet 64 / resnet50 32)")
    ap.add_argument("--sweep-hosts", default="8,64,512",
                    help="comma-separated fleet sizes to simulate")
    ap.add_argument("--policies", default="synceasgd,wfbp,mg_wfbp",
                    help="comma-separated scheduler policies "
                         f"(available: {', '.join(available_policies())})")
    ap.add_argument("--fabric", default="paper_10gbe",
                    choices=available_fabrics(),
                    help="interconnect preset pricing the all-reduce: "
                         f"{', '.join(available_fabrics())}")
    ap.add_argument("--ici-size", type=int, default=0,
                    help="hosts per fast-tier domain (0 = one flat tier; "
                         "the remainder rides the cross-pod DCN axis)")
    ap.add_argument("--straggler-spread", type=float, default=0.0,
                    help="per-host compute multipliers drawn from "
                         "[1, 1+spread] (0 = homogeneous fleet)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds the straggler draw (determinism contract: "
                         "identical seeds => byte-identical report)")
    ap.add_argument("--iters", type=int, default=1,
                    help="iterations replayed per cell (means reported)")
    ap.add_argument("--report-out", default=None,
                    help="write the frozen SimReport JSON here")
    ap.add_argument("--calibrate", action="store_true",
                    help="replay the committed BENCH records at the real "
                         "small-mesh geometry first; abort the what-if if "
                         "the error budget is blown")
    args = ap.parse_args()

    hosts = [int(h) for h in args.sweep_hosts.split(",") if h.strip()]
    policies = [p.strip() for p in args.policies.split(",") if p.strip()]
    known = set(available_policies())
    for p in policies:
        if p not in known:
            ap.error(f"unknown policy {p!r}; available: {sorted(known)}")

    calibration = {}
    if args.calibrate:
        for rep in (calibrate_train(), calibrate_serve()):
            calibration[rep.kind] = rep.to_json_dict()
            print(f"[simulate] calibration/{rep.kind}: rows={len(rep.rows)} "
                  f"max_ratio={rep.max_ratio:.4f} budget={rep.budget} "
                  f"ok={rep.ok}")
            if not rep.ok:
                raise SystemExit(
                    f"calibration/{rep.kind} blew the {rep.budget}x budget "
                    f"(max ratio {rep.max_ratio:.4f}) — the what-if "
                    "extrapolation would not be trustworthy")

    costs, hw = sim_layer_costs(args.arch, args.batch)
    rows = []
    for n in hosts:
        cluster = ClusterSpec(
            n_hosts=n, ici_size=args.ici_size, fabric=args.fabric,
            straggler_spread=args.straggler_spread, seed=args.seed,
        )
        for policy in policies:
            res = replay_train(cluster, list(costs), policy,
                               hw=hw, n_iters=args.iters)
            rows.append(row_from_replay(res, args.arch, args.fabric, n))

    report = SimReport(
        rows=tuple(rows),
        calibration=calibration,
        provenance={
            "arch": args.arch,
            "batch": str(args.batch),
            "fabric": args.fabric,
            "seed": str(args.seed),
            "source": "launch/simulate",
        },
    )
    print(f"[simulate] arch={args.arch} fabric={args.fabric} "
          f"hosts={hosts} policies={policies}")
    for line in report.efficiency_table():
        print("  " + line)
    for n in hosts:
        print(f"[simulate] best policy at {n} hosts: "
              f"{report.best_policy(n_hosts=n)}")
    if args.report_out:
        path = report.save(args.report_out)
        print(f"[simulate] report written to {path}")


if __name__ == "__main__":
    main()
