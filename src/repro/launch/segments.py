"""Segment cost accounting — exact roofline inputs on a CPU-only host.

``compiled.cost_analysis()`` counts a ``lax.scan`` body once (verified in
prototyping), so whole-program numbers undercount the layer loop.  We
therefore lower three *segments* with production shardings and 'unroll'
chunk mode (exact flops) and recompose:

    total = head_segment + stage_segment * n_stages (+ tail_segment)

Collective traffic per segment comes from the compiled HLO text
(core.profiler.parse_collectives).  All numbers are per device.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import set_mesh
from ..core.profiler import CollectiveStats, parse_collectives
from ..models.common import ArchConfig
from ..models.transformer import apply_stage, init_params
from ..models.layers import apply_norm, softcap_logits
from ..parallel.context import activation_sharding, from_rules
from ..parallel.sharding import (
    ShardingRules,
    batch_specs,
    param_pspecs,
    cache_pspecs,
)
from .specs import cache_specs, param_specs

Pytree = Any


@dataclasses.dataclass
class SegCost:
    name: str
    flops: float
    bytes_accessed: float
    coll_counts: dict[str, int]
    coll_bytes: dict[str, int]

    @property
    def coll_total_bytes(self) -> int:
        return sum(self.coll_bytes.values())


def _cost_of(name: str, compiled) -> SegCost:
    ca = compiled.cost_analysis() or {}
    st = parse_collectives(compiled.as_text())
    return SegCost(
        name=name,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_counts=st.counts,
        coll_bytes=st.bytes_by_kind,
    )


def _stage_tree_and_specs(cfg: ArchConfig, rules: ShardingRules, mesh):
    """(stage param ShapeDtypeStructs, NamedShardings) for ONE stage."""
    full = param_specs(cfg)
    pspecs = param_pspecs(full, rules)
    stage_shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), full["stages"]
    )
    stage_specs = jax.tree.map(
        lambda s: P(*tuple(s)[1:]) if len(tuple(s)) > 0 else P(),
        pspecs["stages"],
        is_leaf=lambda x: isinstance(x, P),
    )
    stage_sh = jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), stage_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return stage_shapes, stage_sh


def _x_sharding(rules: ShardingRules, mesh, batch: int):
    return jax.NamedSharding(mesh, P(rules.batch_axes(batch), None, None))


def stage_train_segment(
    cfg: ArchConfig, rules: ShardingRules, mesh, batch: int, seq: int,
    pattern: tuple[str, ...] | None = None,
) -> SegCost:
    """One stage forward+backward at training shape."""
    pattern = pattern or cfg.pattern
    stage_shapes, stage_sh = _stage_tree_and_specs(cfg, rules, mesh)
    if pattern is cfg.tail_pattern or pattern == cfg.tail_pattern:
        full = param_specs(cfg)
        pspecs = param_pspecs(full, rules)
        stage_shapes = full["tail"]
        stage_sh = jax.tree.map(
            lambda s: jax.NamedSharding(mesh, s), pspecs["tail"],
            is_leaf=lambda x: isinstance(x, P),
        )
    x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.param_dtype)
    x_sh = _x_sharding(rules, mesh, batch)

    def seg(stage_p, x, dy):
        pos_shape = (3, batch, seq) if (cfg.attention and cfg.attention.rope == "mrope") else (batch, seq)
        pos = jnp.broadcast_to(jnp.arange(seq), pos_shape)

        def f(sp, xx):
            prefer = "tp" if rules.reserve_model else "fsdp"
            with activation_sharding(from_rules(rules, batch, prefer=prefer)):
                y, _, aux = apply_stage(sp, xx, cfg, pattern, positions=pos)
            return y, aux

        (y, aux), vjp = jax.vjp(f, stage_p, x)
        dsp, dx = vjp((dy, jnp.zeros((), jnp.float32)))
        return y, dsp, dx

    with set_mesh(mesh):
        compiled = (
            jax.jit(seg, in_shardings=(stage_sh, x_sh, x_sh))
            .lower(stage_shapes, x_spec, x_spec)
            .compile()
        )
    return _cost_of("stage_train", compiled)


def stage_fwd_segment(
    cfg: ArchConfig, rules: ShardingRules, mesh, batch: int, seq: int,
    caches: Pytree | None = None, cache_sh: Pytree | None = None,
    pos_value: int = 0,
    pattern: tuple[str, ...] | None = None,
) -> SegCost:
    """One stage forward (prefill / decode)."""
    pattern = pattern or cfg.pattern
    stage_shapes, stage_sh = _stage_tree_and_specs(cfg, rules, mesh)
    x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.param_dtype)
    x_sh = _x_sharding(rules, mesh, batch)

    def seg(stage_p, x, cache):
        pos_shape = (3, batch, seq) if (cfg.attention and cfg.attention.rope == "mrope") else (batch, seq)
        pos = jnp.broadcast_to(jnp.arange(seq) + pos_value, pos_shape)
        if caches is not None:
            prefer = "fsdp"  # decode: caches carry the TP
        else:
            prefer = "tp" if rules.reserve_model else "seq_tp"
        with activation_sharding(from_rules(rules, batch, prefer=prefer)):
            y, new_cache, _ = apply_stage(
                stage_p, x, cfg, pattern,
                positions=pos, caches=cache, q_offset=pos_value,
            )
        return y, new_cache

    args = (stage_shapes, x_spec, caches)
    shardings = (stage_sh, x_sh, cache_sh)
    with set_mesh(mesh):
        compiled = jax.jit(seg, in_shardings=shardings).lower(*args).compile()
    return _cost_of("stage_fwd", compiled)


def head_train_segment(
    cfg: ArchConfig, rules: ShardingRules, mesh, batch: int, seq: int
) -> SegCost:
    """Embed lookup + final norm + head matmul + CE, forward+backward."""
    full = param_specs(cfg)
    pspecs = param_pspecs(full, rules)
    keys = ["embed", "final_norm"] + ([] if cfg.tie_embeddings else ["head"])
    hp_shapes = {k: full[k] for k in keys}
    hp_sh = jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), {k: pspecs[k] for k in keys},
        is_leaf=lambda x: isinstance(x, P),
    )
    ba = rules.batch_axes(batch)
    x_sh = _x_sharding(rules, mesh, batch)
    tok_sh = jax.NamedSharding(mesh, P(ba, None))
    vocab_ax = "model" if cfg.vocab % rules.model_size == 0 else None
    if ba and rules.model_axis in ba:
        vocab_ax = None

    def seg(hp, batch_in, x_mid):
        if cfg.input_mode == "embeds":
            x = batch_in["embeds"].astype(cfg.param_dtype)
        else:
            x = hp["embed"][batch_in["tokens"]].astype(cfg.param_dtype)
        x = x + x_mid  # stand-in for the stage stack output
        x = apply_norm(cfg, hp["final_norm"], x)
        head = hp["embed"].T.astype(cfg.param_dtype) if cfg.tie_embeddings else hp["head"]
        logits = (x @ head).astype(jnp.float32)
        logits = jax.lax.with_sharding_constraint(logits, P(ba, None, vocab_ax))
        logits = softcap_logits(logits, cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch_in["targets"][..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    bspecs = batch_specs(cfg, rules, batch, seq)
    batch_in = {
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.input_mode == "embeds":
        batch_in["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
    else:
        batch_in["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    b_sh = jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), bspecs, is_leaf=lambda x: isinstance(x, P)
    )
    x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.param_dtype)

    def seg_grad(hp, batch_in, x_mid):
        return jax.value_and_grad(seg)(hp, batch_in, x_mid)

    with set_mesh(mesh):
        compiled = (
            jax.jit(seg_grad, in_shardings=(hp_sh, b_sh, x_sh))
            .lower(hp_shapes, batch_in, x_spec)
            .compile()
        )
    return _cost_of("head_train", compiled)


def head_fwd_segment(
    cfg: ArchConfig, rules: ShardingRules, mesh, batch: int, seq: int
) -> SegCost:
    """Embed + final norm + head, forward only (serving)."""
    full = param_specs(cfg)
    pspecs = param_pspecs(full, rules)
    keys = ["embed", "final_norm"] + ([] if cfg.tie_embeddings else ["head"])
    hp_shapes = {k: full[k] for k in keys}
    hp_sh = jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), {k: pspecs[k] for k in keys},
        is_leaf=lambda x: isinstance(x, P),
    )
    ba = rules.batch_axes(batch)
    x_sh = _x_sharding(rules, mesh, batch)
    vocab_ax = "model" if cfg.vocab % rules.model_size == 0 else None
    if ba and rules.model_axis in ba:
        vocab_ax = None

    def seg(hp, x):
        x = apply_norm(cfg, hp["final_norm"], x)
        head = hp["embed"].T.astype(cfg.param_dtype) if cfg.tie_embeddings else hp["head"]
        logits = (x @ head).astype(jnp.float32)
        logits = jax.lax.with_sharding_constraint(logits, P(ba, None, vocab_ax))
        return softcap_logits(logits, cfg.logit_softcap)

    x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.param_dtype)
    with set_mesh(mesh):
        compiled = jax.jit(seg, in_shardings=(hp_sh, x_sh)).lower(hp_shapes, x_spec).compile()
    return _cost_of("head_fwd", compiled)
