"""Serving launcher: continuous batching through ``serving.ServingEngine``
under a fabric-priced ``ServePlan`` — and, with ``--sharded``, the plan
*executed* on a virtual TP mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
        --slots 4 --requests 8 --prompt-len 32 --tokens 16 \\
        --fabric gpu_nccl --plan-out /tmp/serve_plan.json

    # execute the plan: sharded decode over a virtual TP mesh, measured
    # serve fabrics, predicted-vs-observed per group
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \\
        --reduced --virtual-tp 4 --sharded --measure-comm

There is ONE serving code path: this launcher builds the decode-side
``ServePlan`` (the same merge math as training, priced by the selected
fabric preset — KV all-gathers for dense archs, expert all-to-alls for
MoE), hands it to the ``ServingEngine`` (continuous batching: requests
join free slots, finished rows free them immediately), and reports
throughput against the plan's predicted step time.  ``--sharded`` runs
the engine's decode under ``shard_map`` on a ``--virtual-tp``-wide mesh
where every scheduled serve group issues exactly one fused collective
(``serving.sharded``); ``--measure-comm`` times the real per-group
collectives, fits op-specific (α, β) constants into a ``MeasuredFabric``
(``'all_gather@model'``-style keys), and prints the predicted-vs-measured
per-group table.
"""

from __future__ import annotations

import sys


def _requested_virtual_tp() -> int:
    """Pre-argparse scan for ``--virtual-tp N`` / ``--virtual-tp=N``."""
    for i, arg in enumerate(sys.argv):
        try:
            if arg == "--virtual-tp":
                return int(sys.argv[i + 1])
            if arg.startswith("--virtual-tp="):
                return int(arg.split("=", 1)[1])
        except (IndexError, ValueError):
            break
    return 8


if "--sharded" in sys.argv or "--measure-comm" in sys.argv:
    # the TP mesh needs the virtual CPU devices before jax initializes
    from ..compat import ensure_virtual_devices

    ensure_virtual_devices(_requested_virtual_tp())

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import make_mesh
from ..configs import ARCH_NAMES, get_config, get_reduced
from ..fabric import MeasuredFabric, available_fabrics
from ..launch.specs import param_specs
from ..models.transformer import init_params
from ..planning import (
    available_policies,
    build_serve_plan,
    group_comparison_lines,
    serve_fabric_fits,
    time_serve_groups,
)
from ..serving import Request, ServeTimer, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch slots (continuous batching)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fabric", default="tpu_v5e",
                    choices=list(available_fabrics()),
                    help="interconnect preset pricing the decode collectives")
    ap.add_argument("--policy", default="mg_wfbp",
                    choices=list(available_policies()),
                    help="scheduler policy for the serve plan")
    ap.add_argument("--virtual-tp", type=int, default=8,
                    help="TP size of the serve-plan collective model (and of "
                         "the virtual mesh under --sharded)")
    ap.add_argument("--sharded", action="store_true",
                    help="execute the plan: sharded decode on a virtual TP "
                         "mesh, one fused collective per serve group")
    ap.add_argument("--measure-comm", action="store_true",
                    help="time the real per-group collectives, fit a "
                         "MeasuredFabric, and print predicted-vs-measured "
                         "(implies --sharded's mesh)")
    ap.add_argument("--plan-out", default=None,
                    help="write the ServePlan JSON here")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.tokens + 1

    mesh = None
    tp = args.virtual_tp
    if args.sharded or args.measure_comm:
        tp = min(args.virtual_tp, jax.device_count())
        if tp < args.virtual_tp:
            print(f"[serve] only {jax.device_count()} devices visible; "
                  f"clamping TP {args.virtual_tp} -> {tp}")
        mesh = make_mesh((tp,), ("model",))

    # ServingEngine allocates fp32 decode caches, so the executed wire
    # ships 4-byte elements — price the plan at what the step ships
    cache_bytes = 4
    plan = build_serve_plan(
        cfg, param_specs(cfg), args.fabric, {"model": tp},
        batch_rows=args.slots, policy=args.policy,
        cache_dtype_bytes=cache_bytes, act_dtype_bytes=cache_bytes,
    )
    print(f"[serve] {plan.describe()}")

    sample = None
    if args.temperature > 0:
        # two-arg (logits, key) form: the key threads through the jitted
        # step's donated state, so sampling never forces a host round-trip
        def sample(logits, key):
            return jax.random.categorical(key, logits / args.temperature, axis=-1)

    timer = ServeTimer()
    engine = ServingEngine(
        cfg, params, slots=args.slots, max_seq=max_seq, sample=sample,
        sample_seed=2, plan=plan, mesh=mesh if args.sharded else None,
        timer=timer,
    )

    engine.warmup()  # compile the full-batch step before anything is timed
    if plan.schedule.result is not None:
        plan = engine.calibrate_plan()
        wire = plan.schedule.result.t_iter
        print(f"[serve] calibrated step: fixed={plan.t_step_fixed * 1e6:.1f}us"
              f" + wire={wire * 1e6:.1f}us"
              f" = {(plan.t_step_fixed + wire) * 1e6:.1f}us")
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=args.prompt_len, dtype=np.int32),
            max_new_tokens=args.tokens,
        ))

    t0 = time.time()
    completed = engine.run_to_completion()
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in completed)
    mode = f"sharded TP={tp}" if args.sharded else "unsharded"
    print(f"[serve] {len(completed)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s, {args.slots} slots, {mode})")
    predicted = engine.predicted_step_time()
    observed = engine.observed_step_time()
    if predicted is not None:
        print(f"[serve] plan predicted step: {predicted * 1e3:.3f}ms "
              f"({plan.op} over {plan.axis_sizes} on {plan.fabric})")
    if observed is not None:
        print(f"[serve] observed step: {observed * 1e3:.3f}ms "
              f"(observed/predicted = {observed / predicted:.1f}x)"
              if predicted else
              f"[serve] observed step: {observed * 1e3:.3f}ms")

    if args.measure_comm:
        assert mesh is not None
        fits = serve_fabric_fits(mesh, ops=(plan.op,), axes=("model",))
        fab = MeasuredFabric(models=fits, name="measured_serve")
        for key, fit in fits.items():
            print(f"[serve] measured fit {key}: a={fit.a:.3e}s b={fit.b:.3e}s/B")
        measured_plan = build_serve_plan(
            cfg, param_specs(cfg), fab, {"model": tp},
            batch_rows=args.slots, policy=args.policy, op=plan.op,
            cache_dtype_bytes=cache_bytes, act_dtype_bytes=cache_bytes,
        )
        print(f"[serve] measured-fabric plan: {measured_plan.describe()}")
        group_s = time_serve_groups(plan, mesh)
        timer.group_times = group_s
        print("[serve] per-group predicted vs measured:")
        for line in group_comparison_lines(plan, group_s):
            print("  " + line)

    if args.plan_out:
        path = plan.save(args.plan_out)
        print(f"[serve] serve plan written to {path}")


if __name__ == "__main__":
    main()
