"""Serving launcher: batched prefill + decode loop over request batches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
        --batch 4 --prompt-len 32 --tokens 16

Production notes: on a pod the same prefill/decode steps lower with the
serve shardings of launch/dryrun.py (KV sequence-sharded over 'model',
decode-EP MoE).  Continuous batching (per-row positions / eviction) sits
above `make_decode_step`; this launcher runs the simple batch-synchronous
variant the benchmark shapes use.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_config, get_reduced
from ..launch.steps import make_decode_step, make_prefill_step
from ..models.transformer import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.tokens

    prefill = jax.jit(make_prefill_step(cfg, None, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg, None))

    key = jax.random.PRNGKey(1)
    if cfg.input_mode == "embeds":
        batch = {"embeds": jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32) * 0.02}
    else:
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)}

    t0 = time.time()
    logits, caches = prefill(params, batch)
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: {time.time() - t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = args.prompt_len + i
        if cfg.input_mode == "embeds":
            step_in = {"embeds": params["embed"][tok[:, 0]][:, None].astype(jnp.float32)}
        else:
            step_in = {"tokens": tok}
        logits, caches = decode(params, caches, step_in, jnp.asarray(pos, jnp.int32))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
    dt = time.time() - t0
    print(f"[serve] decode {args.tokens} x {args.batch}: {dt:.2f}s "
          f"({args.tokens * args.batch / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
