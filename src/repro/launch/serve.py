"""Serving launcher: continuous batching through ``serving.ServingEngine``
under a fabric-priced ``ServePlan`` — and, with ``--sharded``, the plan
*executed* on a virtual TP mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
        --slots 4 --requests 8 --prompt-len 32 --tokens 16 \\
        --fabric gpu_nccl --plan-out /tmp/serve_plan.json

    # execute the plan: sharded decode over a virtual TP mesh, measured
    # serve fabrics, predicted-vs-observed per group
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \\
        --reduced --virtual-tp 4 --sharded --measure-comm

There is ONE serving code path: this launcher builds the decode-side
``ServePlan`` (the same merge math as training, priced by the selected
fabric preset — KV all-gathers for dense archs, expert all-to-alls for
MoE), hands it to the ``ServingEngine`` (continuous batching: requests
join free slots, finished rows free them immediately), and reports
throughput against the plan's predicted step time.  ``--sharded`` runs
the engine's decode under ``shard_map`` on a ``--virtual-tp``-wide mesh
where every scheduled serve group issues exactly one fused collective
(``serving.sharded``); ``--measure-comm`` times the real per-group
collectives, fits op-specific (α, β) constants into a ``MeasuredFabric``
(``'all_gather@model'``-style keys), and prints the predicted-vs-measured
per-group table.
"""

from __future__ import annotations

import sys


def _requested_virtual_tp() -> int:
    """Pre-argparse scan for ``--virtual-tp N`` / ``--virtual-tp=N``."""
    for i, arg in enumerate(sys.argv):
        try:
            if arg == "--virtual-tp":
                return int(sys.argv[i + 1])
            if arg.startswith("--virtual-tp="):
                return int(arg.split("=", 1)[1])
        except (IndexError, ValueError):
            break
    return 8


if "--sharded" in sys.argv or "--measure-comm" in sys.argv:
    # the TP mesh needs the virtual CPU devices before jax initializes
    from ..compat import ensure_virtual_devices

    ensure_virtual_devices(_requested_virtual_tp())

import argparse
import dataclasses
import signal
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import make_mesh
from ..configs import ARCH_NAMES, get_config, get_reduced
from ..fabric import MeasuredFabric, available_fabrics
from ..launch.specs import param_specs
from ..models.transformer import init_params
from ..planning import (
    available_policies,
    build_serve_plan,
    group_comparison_lines,
    serve_fabric_fits,
    time_serve_groups,
)
from ..runtime import StragglerMonitor
from ..serving import (
    ChaosConfig,
    ChaosInjector,
    Request,
    ServeTimer,
    ServingEngine,
    resilient_serve_loop,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch slots (continuous batching)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fabric", default="tpu_v5e",
                    choices=available_fabrics(),
                    help="interconnect preset pricing the decode collectives: "
                         f"{', '.join(available_fabrics())}")
    ap.add_argument("--policy", default="mg_wfbp",
                    choices=list(available_policies()),
                    help="scheduler policy for the serve plan")
    ap.add_argument("--virtual-tp", type=int, default=8,
                    help="TP size of the serve-plan collective model (and of "
                         "the virtual mesh under --sharded)")
    ap.add_argument("--sharded", action="store_true",
                    help="execute the plan: sharded decode on a virtual TP "
                         "mesh, one fused collective per serve group")
    ap.add_argument("--measure-comm", action="store_true",
                    help="time the real per-group collectives, fit a "
                         "MeasuredFabric, and print predicted-vs-measured "
                         "(implies --sharded's mesh)")
    ap.add_argument("--plan-out", default=None,
                    help="write the ServePlan JSON here")
    # resilience: any of these routes the run through resilient_serve_loop
    ap.add_argument("--chaos-kill-every", type=int, default=0,
                    help="inject a deterministic kill every N serve steps "
                         "(0 = off); the loop must recover token-identically")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos fault schedule")
    ap.add_argument("--chaos-slow-factor", type=float, default=1.0,
                    help="multiply observed step/collective times by this "
                         "once --chaos-slow-after is reached (degraded wire)")
    ap.add_argument("--chaos-slow-after", type=int, default=None,
                    help="serve step after which the injected slowdown starts")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO: deadline = now + this; expired "
                         "requests retire with partial output, unmeetable "
                         "waiting requests are shed")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="serve snapshot cadence in steps")
    ap.add_argument("--snapshot-dir", default=None,
                    help="serve snapshot directory (temp dir when resilience "
                         "is active and this is unset)")
    ap.add_argument("--max-restarts", type=int, default=5,
                    help="restart budget for the resilient serve loop")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.tokens + 1

    mesh = None
    tp = args.virtual_tp
    if args.sharded or args.measure_comm:
        tp = min(args.virtual_tp, jax.device_count())
        if tp < args.virtual_tp:
            print(f"[serve] only {jax.device_count()} devices visible; "
                  f"clamping TP {args.virtual_tp} -> {tp}")
        mesh = make_mesh((tp,), ("model",))

    # ServingEngine allocates fp32 decode caches, so the executed wire
    # ships 4-byte elements — price the plan at what the step ships
    cache_bytes = 4
    plan = build_serve_plan(
        cfg, param_specs(cfg), args.fabric, {"model": tp},
        batch_rows=args.slots, policy=args.policy,
        cache_dtype_bytes=cache_bytes, act_dtype_bytes=cache_bytes,
    )
    print(f"[serve] {plan.describe()}")

    sample = None
    if args.temperature > 0:
        # two-arg (logits, key) form: the key threads through the jitted
        # step's donated state, so sampling never forces a host round-trip
        def sample(logits, key):
            return jax.random.categorical(key, logits / args.temperature, axis=-1)

    timer = ServeTimer()
    engine = ServingEngine(
        cfg, params, slots=args.slots, max_seq=max_seq, sample=sample,
        sample_seed=2, plan=plan, mesh=mesh if args.sharded else None,
        timer=timer,
    )

    engine.warmup()  # compile the full-batch step before anything is timed
    if plan.schedule.result is not None:
        plan = engine.calibrate_plan()
        wire = plan.schedule.result.t_iter
        print(f"[serve] calibrated step: fixed={plan.t_step_fixed * 1e6:.1f}us"
              f" + wire={wire * 1e6:.1f}us"
              f" = {(plan.t_step_fixed + wire) * 1e6:.1f}us")
    resilient = (
        args.chaos_kill_every > 0
        or args.chaos_slow_factor != 1.0
        or args.deadline_ms is not None
        or args.snapshot_dir is not None
    )

    def submit_all(eng, deadline_s=None):
        rng = np.random.default_rng(0)
        for rid in range(args.requests):
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=args.prompt_len,
                                    dtype=np.int32),
                max_new_tokens=args.tokens,
                deadline_s=deadline_s,
            ))

    if resilient:
        baseline_tokens = None
        if args.chaos_kill_every > 0 and args.deadline_ms is None:
            # uninterrupted reference run: the chaos run must reproduce it
            ref = ServingEngine(
                cfg, params, slots=args.slots, max_seq=max_seq, sample=sample,
                sample_seed=2, plan=plan, mesh=mesh if args.sharded else None,
            )
            submit_all(ref)
            baseline_tokens = {
                r.rid: r.generated for r in ref.run_to_completion()
            }

        chaos = ChaosInjector(ChaosConfig(
            seed=args.chaos_seed,
            kill_every=args.chaos_kill_every,
            slow_factor=args.chaos_slow_factor,
            slow_after=args.chaos_slow_after,
        ))
        straggler = (
            StragglerMonitor(window=16, factor=2.0, patience=2)
            if args.chaos_slow_factor != 1.0 else None
        )
        snap_dir = args.snapshot_dir or tempfile.mkdtemp(prefix="serve_snap_")
        deadline_s = (
            time.monotonic() + args.deadline_ms / 1e3
            if args.deadline_ms is not None else None
        )
        submit_all(engine, deadline_s=deadline_s)

        # graceful SIGINT: first ^C snapshots and exits cleanly; the
        # loop's own handler re-raises a second one immediately
        stop = {"flag": False}

        def _sigint(signum, frame):
            print("[serve] SIGINT: snapshotting before exit...")
            stop["flag"] = True

        prev_handler = signal.signal(signal.SIGINT, _sigint)
        t0 = time.time()
        try:
            report = resilient_serve_loop(
                engine,
                snapshot_dir=snap_dir,
                snapshot_every=args.snapshot_every,
                max_restarts=args.max_restarts,
                chaos=chaos,
                straggler=straggler,
                stop_flag=lambda: stop["flag"],
            )
        finally:
            signal.signal(signal.SIGINT, prev_handler)
        dt = time.time() - t0
        completed = report.completed

        mean_rec = (
            sum(report.recovery_times_s) / len(report.recovery_times_s)
            if report.recovery_times_s else 0.0
        )
        tokens_match = ""
        if baseline_tokens is not None:
            got = {r.rid: r.generated for r in completed}
            tokens_match = f" tokens_match={got == baseline_tokens}"
        print(f"[serve] resilience: restarts={report.restarts} "
              f"recovery_mean_s={mean_rec:.3f} snapshots={report.snapshots} "
              f"fallbacks={report.snapshot_fallbacks} shed={report.shed} "
              f"expired={report.expired} replans={report.replans} "
              f"interrupted={report.interrupted} "
              f"goodput_tok_s={report.goodput_tok_per_s:.1f}"
              f"{tokens_match} (snapshots in {snap_dir})")
    else:
        submit_all(engine)
        t0 = time.time()
        completed = engine.run_to_completion()
        dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in completed)
    mode = f"sharded TP={tp}" if args.sharded else "unsharded"
    print(f"[serve] {len(completed)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s, {args.slots} slots, {mode})")
    predicted = engine.predicted_step_time()
    observed = engine.observed_step_time()
    if predicted is not None:
        print(f"[serve] plan predicted step: {predicted * 1e3:.3f}ms "
              f"({plan.op} over {plan.axis_sizes} on {plan.fabric})")
    if observed is not None:
        print(f"[serve] observed step: {observed * 1e3:.3f}ms "
              f"(observed/predicted = {observed / predicted:.1f}x)"
              if predicted else
              f"[serve] observed step: {observed * 1e3:.3f}ms")

    if args.measure_comm:
        assert mesh is not None
        fits = serve_fabric_fits(mesh, ops=(plan.op,), axes=("model",))
        fab = MeasuredFabric(models=fits, name="measured_serve")
        for key, fit in fits.items():
            print(f"[serve] measured fit {key}: a={fit.a:.3e}s b={fit.b:.3e}s/B")
        measured_plan = build_serve_plan(
            cfg, param_specs(cfg), fab, {"model": tp},
            batch_rows=args.slots, policy=args.policy, op=plan.op,
            cache_dtype_bytes=cache_bytes, act_dtype_bytes=cache_bytes,
        )
        print(f"[serve] measured-fabric plan: {measured_plan.describe()}")
        group_s = time_serve_groups(plan, mesh)
        timer.group_times = group_s
        print("[serve] per-group predicted vs measured:")
        for line in group_comparison_lines(plan, group_s):
            print("  " + line)

    if args.plan_out:
        path = plan.save(args.plan_out)
        print(f"[serve] serve plan written to {path}")


if __name__ == "__main__":
    main()
