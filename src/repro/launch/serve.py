"""Serving launcher: continuous batching through ``serving.ServingEngine``
under a fabric-priced ``ServePlan``.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
        --slots 4 --requests 8 --prompt-len 32 --tokens 16 \\
        --fabric gpu_nccl --plan-out /tmp/serve_plan.json

There is ONE serving code path: this launcher builds the decode-side
``ServePlan`` (the same merge math as training, priced by the selected
fabric preset — KV all-gathers for dense archs, expert all-to-alls for
MoE), hands it to the ``ServingEngine`` (continuous batching: requests
join free slots, finished rows free them immediately), and reports
throughput against the plan's predicted step time.  On a pod the same
engine steps lower with the serve shardings of launch/dryrun.py and the
plan's groups drive ``planning.serve.make_group_collective``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_NAMES, get_config, get_reduced
from ..fabric import available_fabrics
from ..launch.specs import param_specs
from ..models.transformer import init_params
from ..planning import available_policies, build_serve_plan
from ..serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch slots (continuous batching)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fabric", default="tpu_v5e",
                    choices=list(available_fabrics()),
                    help="interconnect preset pricing the decode collectives")
    ap.add_argument("--policy", default="mg_wfbp",
                    choices=list(available_policies()),
                    help="scheduler policy for the serve plan")
    ap.add_argument("--virtual-tp", type=int, default=8,
                    help="TP size assumed by the serve-plan collective model")
    ap.add_argument("--plan-out", default=None,
                    help="write the ServePlan JSON here")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.tokens + 1

    plan = build_serve_plan(
        cfg, param_specs(cfg), args.fabric, {"model": args.virtual_tp},
        batch_rows=args.slots, policy=args.policy,
    )
    print(f"[serve] {plan.describe()}")

    sample = None
    if args.temperature > 0:
        key_box = {"key": jax.random.PRNGKey(2)}

        def sample(logits):
            key_box["key"], sub = jax.random.split(key_box["key"])
            return jax.random.categorical(sub, logits / args.temperature, axis=-1)

    engine = ServingEngine(
        cfg, params, slots=args.slots, max_seq=max_seq, sample=sample, plan=plan,
    )
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=args.prompt_len, dtype=np.int32),
            max_new_tokens=args.tokens,
        ))

    t0 = time.time()
    completed = engine.run_to_completion()
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in completed)
    print(f"[serve] {len(completed)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s, {args.slots} slots)")
    predicted = engine.predicted_step_time()
    if predicted is not None:
        print(f"[serve] plan predicted step: {predicted * 1e3:.3f}ms "
              f"({plan.op} over {plan.axis_sizes} on {plan.fabric})")
    if args.plan_out:
        path = plan.save(args.plan_out)
        print(f"[serve] serve plan written to {path}")


if __name__ == "__main__":
    main()
