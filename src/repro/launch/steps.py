"""Step builders: train_step (loss + grad + optimizer), prefill, decode.

These are the functions the dry-run lowers and the trainer/server jit —
one definition for both, parameterized by ArchConfig + ShardingRules.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import forward, init_caches, loss_fn
from ..models.common import ArchConfig
from ..optim import clip_by_global_norm
from ..optim.optimizers import Optimizer
from ..parallel.context import activation_sharding, from_rules
from ..parallel.sharding import ShardingRules, act_constraint, logits_constraint

Pytree = Any


def _ctx(rules, batch: int, prefer: str | None = None):
    if rules is None:
        return activation_sharding(None)
    if prefer is None:
        # EP archs reserve the model axis for experts; the dense parts
        # (attention) then need TP on that axis to stay parallel.
        prefer = "tp" if getattr(rules, "reserve_model", False) else "fsdp"
    return activation_sharding(from_rules(rules, batch, prefer=prefer))


def make_train_step(
    cfg: ArchConfig,
    rules: ShardingRules,
    optimizer: Optimizer,
    *,
    lr: float = 3e-4,
    grad_clip: float = 1.0,
    n_microbatches: int = 1,
    segments: tuple[tuple[int, int], ...] | None = None,
    batch_size: int | None = None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def one_loss(params, batch):
        b = batch.get("tokens", batch.get("embeds"))
        micro_b = b.shape[0]  # per-microbatch rows — what the constraints shard
        with _ctx(rules, micro_b):
            return loss_fn(
                params,
                batch,
                cfg,
                segments=segments,
                act_sharding_constraint=act_constraint(cfg, rules, micro_b)
                if rules is not None
                else None,
                logits_sharding_constraint=logits_constraint(cfg, rules, micro_b)
                if rules is not None
                else None,
            )

    grad_fn = jax.value_and_grad(one_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((n_microbatches, x.shape[0] // n_microbatches) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc_step, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = loss_sum / n_microbatches
            metrics = {}

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, out_metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, rules: ShardingRules | None, max_seq: int):
    """prefill(params, batch) -> (last_logits, caches)."""

    def prefill(params, batch):
        b = batch.get("tokens", batch.get("embeds"))
        prefer = "tp" if (rules is not None and rules.reserve_model) else "seq_tp"
        with _ctx(rules, b.shape[0], prefer=prefer):
            caches = init_caches(cfg, batch=b.shape[0], max_seq=max_seq)
            logits, caches, _ = forward(
                params,
                cfg,
                tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                caches=caches,
                act_sharding_constraint=act_constraint(cfg, rules, b.shape[0])
                if rules is not None
                else None,
            )
        return logits[:, -1], caches

    return prefill


def make_decode_step(cfg: ArchConfig, rules: ShardingRules | None):
    """decode(params, caches, batch, pos) -> (logits, caches).

    ``batch`` holds one token per sequence: tokens (B, 1) or embeds
    (B, 1, D); ``pos`` is the scalar absolute position (same across the
    batch — continuous batching with per-row positions is a serving-engine
    feature layered above this step).
    """

    def decode(params, caches, batch, pos):
        b = batch.get("tokens", batch.get("embeds"))
        with _ctx(rules, b.shape[0], prefer="fsdp"):  # caches carry the TP
            logits, caches, _ = forward(
                params,
                cfg,
                tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                caches=caches,
                q_offset=pos,
            )
        return logits[:, 0], caches

    return decode
