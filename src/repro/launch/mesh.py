"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax

from ..compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests, reduced smoke runs)."""
    return _make_mesh(shape, axes)
