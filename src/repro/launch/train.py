"""Production training launcher: MG-WFBP Tier-2 engine + data pipeline +
fault-tolerant loop + async checkpointing, driven by --arch configs.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \\
        --reduced --steps 100 --batch 8 --seq 256 --method mg_wfbp

On a real TPU slice the same entry point runs under `jax.distributed`
(one process per host); this container runs it single-process.  The
schedule method, comm dtype, checkpoint cadence and restart budget are
flags; everything else comes from the arch config and the mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..checkpoint import AsyncCheckpointer, latest_step, restore
from ..configs import ARCH_NAMES, get_config, get_reduced
from ..core import tpu_psum_model
from ..core.sync import SyncConfig
from ..core.trainer import MGWFBPEngine
from ..data import DataConfig, make_stream
from ..launch.mesh import make_mesh
from ..launch.specs import param_specs
from ..models.transformer import init_params
from ..optim import make_optimizer
from ..runtime import RunState, StragglerMonitor, resilient_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--method", default="mg_wfbp",
                    choices=["mg_wfbp", "dp_optimal", "wfbp", "synceasgd", "fixed"])
    ap.add_argument("--comm-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--virtual-dp", type=int, default=32,
                    help="DP size assumed by the α–β schedule model")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--max-restarts", type=int, default=5)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev, 1), ("data", "model"))

    sync_cfg = SyncConfig(
        comm_dtype=jnp.bfloat16 if args.comm_dtype == "bf16" else jnp.float32,
        compression="bf16" if args.comm_dtype == "bf16" else None,
    )
    eng = MGWFBPEngine.build(
        cfg,
        param_specs(cfg),
        dp_axes=("data",),
        ar_model=tpu_psum_model({"data": args.virtual_dp}),
        tokens_per_device=args.batch * args.seq // n_dev,
        method=args.method,
        sync_config=sync_cfg,
    )
    print(f"[train] {eng.schedule.describe()}")
    print(f"[train] scan segments: {eng.segments}")

    opt = make_optimizer(args.optimizer)
    step_fn = eng.make_train_step(opt, mesh, lr=args.lr)
    data = make_stream(
        DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            input_mode=cfg.input_mode, d_model=cfg.d_model,
        )
    )
    monitor = StragglerMonitor()

    def init_state() -> RunState:
        params = init_params(jax.random.PRNGKey(0), cfg)
        return RunState(step=0, params=params, opt_state=opt.init(params))

    def do_step(state: RunState, step: int) -> RunState:
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        with jax.set_mesh(mesh):
            p, o, m = step_fn(state.params, state.opt_state, batch)
        if step % 10 == 0:
            print(f"[train] step {step} loss {float(m['loss']):.4f}")
        return RunState(step=state.step, params=p, opt_state=o,
                        restarts=state.restarts)

    t0 = time.time()
    final = resilient_loop(
        num_steps=args.steps,
        init_state=init_state,
        train_step=do_step,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        max_restarts=args.max_restarts,
        straggler=monitor,
    )
    print(f"[train] done: {final.step} steps, {final.restarts} restarts, "
          f"{time.time() - t0:.1f}s, {monitor.remediations} straggler remediations")


if __name__ == "__main__":
    main()
