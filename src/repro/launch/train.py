"""Production training launcher: MG-WFBP Tier-2 engine + data pipeline +
fault-tolerant loop + async checkpointing, driven by --arch configs.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \\
        --reduced --steps 100 --batch 8 --seq 256 --policy mg_wfbp

On a real TPU slice the same entry point runs under `jax.distributed`
(one process per host); this container runs it single-process.

Planning lifecycle wiring (journal MG-WFBP's online re-planning):

  * the engine builds (or loads, ``--plan-in``) a frozen ``Plan``;
  * ``--autotune`` closes the loop: per-unit segment probes
    (``runtime/timeline.py``) feed ``MeasuredCosts.from_segment_times``
    and a registry-wide ``planning.Tuner`` sweep picks the argmin
    predicted-t_iter plan — at startup, on drift, and on restart;
  * every ``--replan-every`` steps the measured profile (per-unit probe
    times under --autotune, else the median step time's uniform rescale)
    drives ``replan_if_drifted`` / a tuner sweep (threshold
    ``--replan-threshold``); a re-plan rebuilds the train step;
  * every ``--comm-refit-every`` steps a slim timed-psum sweep is
    exponentially weighted into the (α, β) fit (``CommRefitter``); when
    the fitted constants drift past ``--comm-drift-threshold`` the plan
    search reruns under the fresh comm model — the journal version's
    online comm loop;
  * fault-tolerant restarts restore the plan AND the tuner state saved
    beside the latest checkpoint, or re-enter the plan search when none
    is stored, through the ``resilient_loop`` hooks;
  * ``--plan-out`` additionally serializes the final plan for elastic
    restarts, dry-runs, and benchmarks to reuse;
  * ``--fuse arena`` ships gradients over the packed-arena wire path and
    ``--compression bf16_ef`` threads the error-feedback residual through
    the train step and checkpoints (EF survives restarts).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..checkpoint import latest_step, load_plan, load_tuner_state
from ..compat import set_mesh
from ..configs import ARCH_NAMES, get_config, get_reduced
from ..core.sync import SyncConfig
from ..core.trainer import MGWFBPEngine
from ..data import DataConfig, make_stream
from ..fabric import MeasuredFabric, available_fabrics, get_fabric
from ..launch.mesh import make_mesh
from ..launch.specs import param_specs
from ..models.transformer import init_params
from ..optim import make_optimizer
from ..planning import (
    CommRefitter,
    DEFAULT_COMM_SWEEP,
    MeasuredComm,
    MeasuredCosts,
    Plan,
    Tuner,
    available_policies,
    cost_drift,
    psum_time_fn,
)
from ..runtime import RunState, StragglerMonitor, StepTimer, resilient_loop
from ..runtime.timeline import make_unit_probes, probe_unit_times


def _dryrun(args, eng, make_step, init_state, data, mesh) -> None:
    """Trace-first smoke: run ``args.dryrun`` steps under a span recorder
    and report how much of the wire the chosen issue order actually hides
    under backward — measured from the parsed trace, not the model."""
    from ..core.profiler import TraceRecorder, overlap_report

    rec = TraceRecorder()
    step_fn = make_step(eng, recorder=rec)
    state = init_state()
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))

    def one(state):
        with set_mesh(mesh):
            if eng.stateful:
                p, o, res, m = step_fn(
                    state.params, state.opt_state, state.residual, batch
                )
            else:
                p, o, m = step_fn(state.params, state.opt_state, batch)
                res = state.residual
        return RunState(step=state.step + 1, params=p, opt_state=o,
                        residual=res), m

    # warm-up step compiles; drop its spans so the report is steady-state
    state, m = one(state)
    jax.block_until_ready(state.params)
    jax.effects_barrier()
    if args.dryrun > 1:
        rec.clear()
        for _ in range(args.dryrun - 1):
            state, m = one(state)
        jax.block_until_ready(state.params)
        jax.effects_barrier()

    report = overlap_report(rec.spans())
    sched = eng.plan.schedule
    print(f"[dryrun] issue={args.issue_order} loss={float(m['loss']):.4f} "
          f"groups={list(sched.groups)}")
    print(f"[dryrun] overlap fraction {report['overlap_fraction']:.3f} "
          f"({report['windowed_comm_us']:.0f}us of {report['total_comm_us']:.0f}us "
          f"comm inside the backward window; strict concurrent overlap "
          f"{report['hidden_fraction']:.3f}; {report['n_overlapped_starts']}/"
          f"{report['n_comm_spans']} comm spans start inside backward)")
    print("[dryrun] " + json.dumps(
        {k: report[k] for k in ("n_devices", "n_comm_spans", "n_bwd_spans",
                                "total_comm_us", "windowed_comm_us",
                                "hidden_comm_us", "overlap_fraction",
                                "hidden_fraction", "n_overlapped_starts")}
    ))
    if args.trace_out:
        rec.save(args.trace_out)
        print(f"[dryrun] trace written to {args.trace_out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--policy", "--method", dest="policy", default=None,
                    choices=list(available_policies()),
                    help="scheduler policy (planning registry; default mg_wfbp). "
                         "With --plan-in, only valid if it matches the plan's policy; "
                         "ignored under --autotune (the sweep picks).")
    ap.add_argument("--comm-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--compression", default=None,
                    choices=["bf16", "bf16_ef"],
                    help="wire compression (default: follows --comm-dtype). "
                         "bf16_ef carries the error-feedback residual through "
                         "the train step and checkpoints (requires --fuse arena)")
    ap.add_argument("--fuse", default="concat",
                    choices=["concat", "variadic", "arena"],
                    help="wire layout: concat (one flat buffer, copy each way), "
                         "variadic (zero-copy tuple psum), arena (packed flat "
                         "buffer via kernels/comm_pack — one all-reduce per "
                         "group AND no concatenate copies)")
    ap.add_argument("--virtual-dp", type=int, default=32,
                    help="DP size assumed by the α–β schedule model")
    ap.add_argument("--fabric", default="tpu_v5e",
                    choices=available_fabrics(),
                    help="interconnect preset pricing the DP all-reduce: "
                         f"{', '.join(available_fabrics())} "
                         "(tpu_v5e matches the historical analytic TPU "
                         "model; tree_10gbe / pipeline_10gbe / "
                         "tpu_v5e_tree_dcn are the hierarchical Wang-Vuduc "
                         "reductions)")
    ap.add_argument("--measure-comm", action="store_true",
                    help="fit (α, β) from timed psums on the live mesh "
                         "(a MeasuredFabric, journal §V-A) instead of the "
                         "--fabric preset at --virtual-dp")
    ap.add_argument("--autotune", action="store_true",
                    help="closed-loop auto-tuner: per-unit segment probes feed "
                         "MeasuredCosts, and a registry-wide Tuner sweep picks "
                         "the argmin predicted-t_iter plan at startup, on "
                         "drift, and on restart")
    ap.add_argument("--comm-refit-every", type=int, default=0,
                    help="steps between slim timed-psum (α, β) re-fits "
                         "(EWMA into the stored sweep; 0 = off)")
    ap.add_argument("--comm-drift-threshold", type=float, default=0.25,
                    help="relative (α, β) drift that triggers a comm re-plan")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--plan-in", default=None,
                    help="load a serialized Plan instead of planning")
    ap.add_argument("--plan-out", default=None,
                    help="write the final Plan JSON here")
    ap.add_argument("--replan-every", type=int, default=25,
                    help="steps between measured-profile drift checks (0 = off)")
    ap.add_argument("--replan-threshold", type=float, default=0.25,
                    help="relative per-unit backward-time drift that triggers a re-plan")
    ap.add_argument("--issue-order", default="post", choices=["post", "dag"],
                    help="when each schedule group's merged all-reduce issues: "
                         "after the whole backward (post) or at the group's "
                         "last-gradient event inside backward (dag) — the "
                         "WFBP overlap path (requires scan segments)")
    ap.add_argument("--dryrun", type=int, default=0, metavar="N",
                    help="trace-first smoke: run N steps with the span "
                         "recorder, print the measured overlap report "
                         "(comm hidden under backward, from parsed "
                         "wfbp_group*/bwd_* spans), and exit — no "
                         "checkpoints, no resilience loop")
    ap.add_argument("--trace-out", default=None,
                    help="with --dryrun: write the Chrome-trace JSON here "
                         "(.gz for gzip)")
    args = ap.parse_args()
    if args.plan_in and args.autotune:
        ap.error("--plan-in and --autotune are mutually exclusive: the "
                 "tuner's sweep picks the plan (drop --autotune to pin a "
                 "serialized plan)")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev, 1), ("data", "model"))

    compression = args.compression
    if compression is None and args.comm_dtype == "bf16":
        compression = "bf16"
    if compression == "bf16_ef" and args.fuse != "arena":
        ap.error("--compression bf16_ef requires --fuse arena")
    sync_cfg = SyncConfig(
        comm_dtype=jnp.bfloat16 if args.comm_dtype == "bf16" else jnp.float32,
        compression=compression,
        fuse=args.fuse,
    )

    if args.measure_comm:
        comm_obs = MeasuredComm.time_psums(mesh, ("data",))
        fabric = MeasuredFabric.from_comm(comm_obs)
        ar_model = fabric.cost("all_reduce", {"data": n_dev})
        print(f"[train] measured comm fit: α={ar_model.a:.3e}s β={ar_model.b:.3e}s/B")
    else:
        fabric = get_fabric(args.fabric)
        ar_model = fabric.cost("all_reduce", {"data": args.virtual_dp})
        # analytic prior sampled on the standard sweep, so the online
        # EWMA re-fit has observations to blend fresh probes into
        comm_obs = MeasuredComm(
            sizes_bytes=DEFAULT_COMM_SWEEP,
            times_s=tuple(ar_model(s) for s in DEFAULT_COMM_SWEEP),
            name="analytic_prior",
        )

    def build_engine(plan: Plan | None = None, from_tuner: bool = False) -> MGWFBPEngine:
        return MGWFBPEngine.build(
            cfg,
            param_specs(cfg),
            dp_axes=("data",),
            ar_model=ar_model,
            tokens_per_device=args.batch * args.seq // n_dev,
            # a loaded plan carries its own policy; an explicitly requested
            # one is forwarded so the engine can reject a mismatch instead
            # of silently losing it.  Tuner-chosen plans own their policy.
            policy=(None if from_tuner else args.policy)
            if plan is not None
            else (args.policy or "mg_wfbp"),
            sync_config=sync_cfg,
            plan=plan,
        )

    plan_in = Plan.load(args.plan_in) if args.plan_in else None
    state_box = {"eng": build_engine(plan_in)}

    opt = make_optimizer(args.optimizer)
    data = make_stream(
        DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            input_mode=cfg.input_mode, d_model=cfg.d_model,
        )
    )
    monitor = StragglerMonitor()
    timer = StepTimer(window=max(8, args.replan_every or 8))

    def make_step(eng: MGWFBPEngine, recorder=None):
        return eng.make_train_step(
            opt, mesh, lr=args.lr, issue=args.issue_order, recorder=recorder
        )

    tuner: Tuner | None = None
    if args.autotune:
        tuner = Tuner(
            layout=state_box["eng"].plan.layout,
            n_scan_stages=cfg.n_stages,
            shapes=param_specs(cfg),
            wire_dtype=jnp.dtype(sync_cfg.wire_dtype).name,
            provenance={"arch": cfg.name},
        )
        # probe inputs are only materialized (and their jitted probes only
        # built) when the tuner actually needs them — a plain run must not
        # pin a second copy of the parameters
        probe_batch = jax.tree.map(jnp.asarray, data.batch_at(0))
        probe_params = init_params(jax.random.PRNGKey(0), cfg)
        state_box["probes"] = make_unit_probes(cfg, probe_params, probe_batch)
    if args.comm_refit_every:
        state_box["refitter"] = CommRefitter(
            base=comm_obs, threshold=args.comm_drift_threshold,
        )
        state_box["psum_time"] = psum_time_fn(mesh, ("data",))

    def measured_unit_costs() -> MeasuredCosts:
        """Per-unit probe times -> measured cost vector (non-uniform drift,
        unlike the whole-step rescale)."""
        eng = state_box["eng"]
        profile = probe_unit_times(
            cfg, probe_params, probe_batch, eng.plan.layout,
            probes=state_box["probes"],
        )
        return MeasuredCosts.from_segment_times(
            list(eng.plan.costs), eng.plan.hw, profile.unit_seconds,
            name="probe_segments",
        )

    def adopt_plan(plan: Plan, why: str) -> None:
        state_box["eng"] = build_engine(plan, from_tuner=True)
        state_box["step_fn"] = make_step(state_box["eng"])
        timer.reset()
        print(f"[train] {why} -> {state_box['eng'].plan.describe()}")

    def tuner_sweep(costs: MeasuredCosts, model, comm_source: str, trigger: str) -> Plan:
        assert tuner is not None
        return tuner.sweep(
            costs.layer_costs(), model, costs.hw,
            cost_source=costs.name, comm_source=comm_source, trigger=trigger,
        )

    if args.autotune:
        measured = measured_unit_costs()
        plan = tuner_sweep(
            measured, ar_model,
            "measured" if args.measure_comm else "analytic", "startup",
        )
        adopt_plan(plan, "autotune startup sweep "
                         f"({tuner.last_record.chosen}, "
                         f"{len(tuner.last_record.candidates)} candidates)")
    else:
        state_box["step_fn"] = make_step(state_box["eng"])
        print(f"[train] {state_box['eng'].plan.describe()}")
    print(f"[train] scan segments: {state_box['eng'].segments}")

    def init_state() -> RunState:
        params = init_params(jax.random.PRNGKey(0), cfg)
        return RunState(
            step=0, params=params, opt_state=opt.init(params),
            residual=state_box["eng"].init_residual(params, mesh),
        )

    if args.dryrun:
        _dryrun(args, state_box["eng"], make_step, init_state, data, mesh)
        return

    def maybe_replan(step: int) -> None:
        """Measured-profile drift check (journal MG-WFBP online re-plan)."""
        eng = state_box["eng"]
        modeled = eng.plan.schedule.result
        measured_t = timer.median()
        if modeled is None or measured_t is None or len(timer) < 5:
            return
        if tuner is not None:
            tuner.observe(measured_t)
            measured = measured_unit_costs()
            drift = cost_drift(eng.plan, measured)
            if drift > args.replan_threshold:
                plan = tuner_sweep(
                    measured, eng.plan.ar_model,
                    eng.plan.provenance.get("comm_source", "analytic"),
                    "cost_drift",
                )
                adopt_plan(plan, f"step {step}: cost drift {drift:.3f} re-sweep")
            return
        measured = MeasuredCosts.from_step_timing(
            list(eng.plan.costs), eng.plan.hw, measured_t, modeled.t_iter
        )
        new_eng, replanned = eng.replan(measured, threshold=args.replan_threshold)
        if replanned:
            state_box["eng"] = new_eng
            state_box["step_fn"] = make_step(new_eng)
            # The rebuilt step recompiles and the old engine's samples no
            # longer describe the new segmentation — restart the window.
            timer.reset()
            print(f"[train] step {step}: re-planned "
                  f"(drift {new_eng.plan.provenance['drift']}) -> "
                  f"{new_eng.plan.schedule.describe()}")

    def maybe_refit_comm(step: int) -> None:
        """Amortized comm-side drift check: slim psum sweep -> EWMA ->
        (α, β) re-fit -> re-plan past the threshold."""
        refitter = state_box.get("refitter")
        if refitter is None:
            return
        fit, drift, drifted = refitter.check(state_box["psum_time"])
        if not drifted:
            return
        eng = state_box["eng"]
        if tuner is not None:
            plan = tuner_sweep(
                MeasuredCosts(costs=tuple(eng.plan.costs), hw=eng.plan.hw,
                              name=eng.plan.provenance.get("cost_source", "analytic")),
                fit, "measured_comm_refit", "comm_drift",
            )
            adopt_plan(plan, f"step {step}: comm drift {drift:.3f} "
                             f"(α={fit.a:.3e} β={fit.b:.3e}) re-sweep")
        else:
            new_plan, replanned = refitter.replan(eng.plan, fit)
            if replanned:
                adopt_plan(new_plan, f"step {step}: comm drift {drift:.3f} re-plan")

    track_time = bool(args.replan_every or args.comm_refit_every or args.autotune)

    def do_step(state: RunState, step: int) -> RunState:
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        eng = state_box["eng"]
        timer.start()
        with set_mesh(mesh):
            if eng.stateful:
                p, o, res, m = state_box["step_fn"](
                    state.params, state.opt_state, state.residual, batch
                )
            else:
                p, o, m = state_box["step_fn"](state.params, state.opt_state, batch)
                res = state.residual
        if track_time:
            # timing needs a host-device sync; skip both when every online
            # check is off so the dispatch pipeline stays async
            jax.block_until_ready(p)
            timer.stop()
            if args.replan_every and step and step % args.replan_every == 0:
                maybe_replan(step)
            if args.comm_refit_every and step and step % args.comm_refit_every == 0:
                maybe_refit_comm(step)
        if step % 10 == 0:
            print(f"[train] step {step} loss {float(m['loss']):.4f}")
        return RunState(step=state.step, params=p, opt_state=o,
                        restarts=state.restarts, residual=res)

    def on_restart(state: RunState) -> RunState:
        # Same-shape restart: resume under the exact plan the checkpoint
        # was trained with (saved beside the weights), and under --autotune
        # resume the tuner's sweep history too; elastic restarts (no stored
        # plan / different N) re-enter the plan search instead.
        plan = None
        how = "re-planned"
        ck = latest_step(args.ckpt_dir)
        if ck is not None:
            try:
                plan = load_plan(args.ckpt_dir, ck)
                if plan is not None:
                    state_box["eng"] = build_engine(plan, from_tuner=args.autotune)
                    how = "restored plan"
            except Exception as e:  # corrupt/foreign/mismatched plan -> re-plan
                print(f"[train] stored plan unusable ({e}); re-planning")
                plan = None
            if tuner is not None:
                try:
                    st = load_tuner_state(args.ckpt_dir, ck)
                    if st is not None:
                        tuner.load_state(st)
                        if st.get("comm_refitter") and "refitter" in state_box:
                            state_box["refitter"] = CommRefitter.from_state_dict(
                                st["comm_refitter"]
                            )
                except Exception as e:
                    print(f"[train] stored tuner state unusable ({e}); starting fresh")
        if plan is None:
            if tuner is not None:
                plan = tuner_sweep(
                    measured_unit_costs(), ar_model,
                    "measured" if args.measure_comm else "analytic", "restart",
                )
                state_box["eng"] = build_engine(plan, from_tuner=True)
                how = "restart sweep"
            else:
                state_box["eng"] = build_engine()
        state_box["step_fn"] = make_step(state_box["eng"])
        timer.reset()
        print(f"[train] restart at step {state.step}: {how} -> "
              f"{state_box['eng'].plan.schedule.describe()}")
        return state

    def tuner_state() -> dict:
        """Checkpointed tuner state: sweep history + the comm refitter's
        EWMA'd observations, so BOTH online loops resume after a restart."""
        st = tuner.state_dict()
        if state_box.get("refitter") is not None:
            st["comm_refitter"] = state_box["refitter"].state_dict()
        return st

    t0 = time.time()
    final = resilient_loop(
        num_steps=args.steps,
        init_state=init_state,
        train_step=do_step,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        max_restarts=args.max_restarts,
        straggler=monitor,
        on_restart=on_restart,
        # every checkpoint carries the live plan (--plan-out made automatic)
        # and, when auto-tuning, the tuner's sweep history + comm observations
        plan_provider=lambda: state_box["eng"].plan,
        tuner_provider=tuner_state if tuner is not None else None,
    )
    if tuner is not None and timer.median() is not None and tuner.history:
        rec = tuner.observe(timer.median())
        print(f"[train] tuner: chosen={rec.chosen} "
              f"predicted_t_iter={rec.predicted_t_iter:.3e}s "
              f"observed_t_iter={rec.observed_t_iter:.3e}s "
              f"over {len(rec.candidates)} candidates")
    print(f"[train] done: {final.step} steps, {final.restarts} restarts, "
          f"{time.time() - t0:.1f}s, {monitor.remediations} straggler remediations")
    if args.plan_out:
        path = state_box["eng"].plan.save(args.plan_out)
        print(f"[train] plan written to {path}")


if __name__ == "__main__":
    main()
