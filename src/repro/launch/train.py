"""Production training launcher: MG-WFBP Tier-2 engine + data pipeline +
fault-tolerant loop + async checkpointing, driven by --arch configs.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \\
        --reduced --steps 100 --batch 8 --seq 256 --policy mg_wfbp

On a real TPU slice the same entry point runs under `jax.distributed`
(one process per host); this container runs it single-process.

Planning lifecycle wiring (journal MG-WFBP's online re-planning):

  * the engine builds (or loads, ``--plan-in``) a frozen ``Plan``;
  * every ``--replan-every`` steps the measured median step time
    calibrates a ``MeasuredCosts`` vector and ``replan_if_drifted``
    decides whether the policy reruns (threshold ``--replan-threshold``);
    a re-plan rebuilds the train step (scan segmentation changed);
  * fault-tolerant restarts restore the plan saved beside the latest
    checkpoint (every checkpoint carries the active plan JSON —
    ``--plan-out`` made automatic) or re-enter planning when none is
    stored, through the ``resilient_loop`` hooks;
  * ``--plan-out`` additionally serializes the final plan for elastic
    restarts, dry-runs, and benchmarks to reuse;
  * ``--fuse arena`` ships gradients over the packed-arena wire path
    (kernels/comm_pack) and ``--measure-comm`` replaces the analytic
    α–β model with a live timed-psum fit (``MeasuredComm``).
"""

from __future__ import annotations

import argparse
import dataclasses
import statistics
import time

import jax
import jax.numpy as jnp

from ..checkpoint import AsyncCheckpointer, latest_step, load_plan, restore
from ..compat import set_mesh
from ..configs import ARCH_NAMES, get_config, get_reduced
from ..core import tpu_psum_model
from ..core.sync import SyncConfig
from ..core.trainer import MGWFBPEngine
from ..data import DataConfig, make_stream
from ..launch.mesh import make_mesh
from ..launch.specs import param_specs
from ..models.transformer import init_params
from ..optim import make_optimizer
from ..planning import MeasuredComm, MeasuredCosts, Plan, available_policies
from ..runtime import RunState, StragglerMonitor, resilient_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--policy", "--method", dest="policy", default=None,
                    choices=list(available_policies()),
                    help="scheduler policy (planning registry; default mg_wfbp). "
                         "With --plan-in, only valid if it matches the plan's policy.")
    ap.add_argument("--comm-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--fuse", default="concat",
                    choices=["concat", "variadic", "arena"],
                    help="wire layout: concat (one flat buffer, copy each way), "
                         "variadic (zero-copy tuple psum), arena (packed flat "
                         "buffer via kernels/comm_pack — one all-reduce per "
                         "group AND no concatenate copies)")
    ap.add_argument("--virtual-dp", type=int, default=32,
                    help="DP size assumed by the α–β schedule model")
    ap.add_argument("--measure-comm", action="store_true",
                    help="fit (α, β) from timed psums on the live mesh "
                         "(MeasuredComm, journal §V-A) instead of the "
                         "analytic --virtual-dp TPU model")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--plan-in", default=None,
                    help="load a serialized Plan instead of planning")
    ap.add_argument("--plan-out", default=None,
                    help="write the final Plan JSON here")
    ap.add_argument("--replan-every", type=int, default=25,
                    help="steps between measured-profile drift checks (0 = off)")
    ap.add_argument("--replan-threshold", type=float, default=0.25,
                    help="relative per-unit backward-time drift that triggers a re-plan")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32)
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev, 1), ("data", "model"))

    sync_cfg = SyncConfig(
        comm_dtype=jnp.bfloat16 if args.comm_dtype == "bf16" else jnp.float32,
        compression="bf16" if args.comm_dtype == "bf16" else None,
        fuse=args.fuse,
    )

    if args.measure_comm:
        ar_model = MeasuredComm.time_psums(mesh, ("data",)).fit()
        print(f"[train] measured comm fit: α={ar_model.a:.3e}s β={ar_model.b:.3e}s/B")
    else:
        ar_model = tpu_psum_model({"data": args.virtual_dp})

    def build_engine(plan: Plan | None = None) -> MGWFBPEngine:
        return MGWFBPEngine.build(
            cfg,
            param_specs(cfg),
            dp_axes=("data",),
            ar_model=ar_model,
            tokens_per_device=args.batch * args.seq // n_dev,
            # a loaded plan carries its own policy; an explicitly requested
            # one is forwarded so the engine can reject a mismatch instead
            # of silently losing it
            policy=args.policy if plan is not None else (args.policy or "mg_wfbp"),
            sync_config=sync_cfg,
            plan=plan,
        )

    plan_in = Plan.load(args.plan_in) if args.plan_in else None
    state_box = {"eng": build_engine(plan_in)}
    print(f"[train] {state_box['eng'].plan.describe()}")
    print(f"[train] scan segments: {state_box['eng'].segments}")

    opt = make_optimizer(args.optimizer)
    state_box["step_fn"] = state_box["eng"].make_train_step(opt, mesh, lr=args.lr)
    data = make_stream(
        DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            input_mode=cfg.input_mode, d_model=cfg.d_model,
        )
    )
    monitor = StragglerMonitor()
    step_times: list[float] = []

    def init_state() -> RunState:
        params = init_params(jax.random.PRNGKey(0), cfg)
        return RunState(step=0, params=params, opt_state=opt.init(params))

    def maybe_replan(step: int) -> None:
        """Measured-profile drift check (journal MG-WFBP online re-plan)."""
        eng = state_box["eng"]
        modeled = eng.plan.schedule.result
        if modeled is None or len(step_times) < 5:
            return
        measured_t = statistics.median(step_times[-args.replan_every :])
        measured = MeasuredCosts.from_step_timing(
            list(eng.plan.costs), eng.plan.hw, measured_t, modeled.t_iter
        )
        new_eng, replanned = eng.replan(measured, threshold=args.replan_threshold)
        if replanned:
            state_box["eng"] = new_eng
            state_box["step_fn"] = new_eng.make_train_step(opt, mesh, lr=args.lr)
            # The rebuilt step recompiles and the old engine's samples no
            # longer describe the new segmentation — restart the window.
            step_times.clear()
            state_box["skip_samples"] = 2
            print(f"[train] step {step}: re-planned "
                  f"(drift {new_eng.plan.provenance['drift']}) -> "
                  f"{new_eng.plan.schedule.describe()}")

    def do_step(state: RunState, step: int) -> RunState:
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        t0 = time.monotonic()
        with set_mesh(mesh):
            p, o, m = state_box["step_fn"](state.params, state.opt_state, batch)
        if args.replan_every:
            # timing needs a host-device sync; skip both when re-planning
            # is off so the dispatch pipeline stays async
            jax.block_until_ready(p)
            if step > 1 and not state_box.get("skip_samples"):  # skip compile steps
                step_times.append(time.monotonic() - t0)
            elif state_box.get("skip_samples"):
                state_box["skip_samples"] -= 1
            if step and step % args.replan_every == 0:
                maybe_replan(step)
        if step % 10 == 0:
            print(f"[train] step {step} loss {float(m['loss']):.4f}")
        return RunState(step=state.step, params=p, opt_state=o,
                        restarts=state.restarts)

    def on_restart(state: RunState) -> RunState:
        # Same-shape restart: resume under the exact plan the checkpoint
        # was trained with (saved beside the weights); elastic restarts
        # (no stored plan / different N) re-enter planning instead.
        plan = None
        ck = latest_step(args.ckpt_dir)
        if ck is not None:
            try:
                plan = load_plan(args.ckpt_dir, ck)
                if plan is not None:
                    state_box["eng"] = build_engine(plan)
            except Exception as e:  # corrupt/foreign/mismatched plan -> re-plan
                print(f"[train] stored plan unusable ({e}); re-planning")
                plan = None
        if plan is None:
            state_box["eng"] = build_engine()
        state_box["step_fn"] = state_box["eng"].make_train_step(opt, mesh, lr=args.lr)
        step_times.clear()
        how = "restored plan" if plan is not None else "re-planned"
        print(f"[train] restart at step {state.step}: {how} -> "
              f"{state_box['eng'].plan.schedule.describe()}")
        return state

    t0 = time.time()
    final = resilient_loop(
        num_steps=args.steps,
        init_state=init_state,
        train_step=do_step,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        max_restarts=args.max_restarts,
        straggler=monitor,
        on_restart=on_restart,
        # every checkpoint carries the live plan (--plan-out made automatic)
        plan_provider=lambda: state_box["eng"].plan,
    )
    print(f"[train] done: {final.step} steps, {final.restarts} restarts, "
          f"{time.time() - t0:.1f}s, {monitor.remediations} straggler remediations")
    if args.plan_out:
        path = state_box["eng"].plan.save(args.plan_out)
        print(f"[train] plan written to {path}")


if __name__ == "__main__":
    main()
