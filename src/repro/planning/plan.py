"""The frozen ``Plan`` artifact.

A Plan is everything the training system decided *before* the first step:
the communication-unit layout of the parameter pytree, the per-layer cost
vector those units were scheduled with, the α–β all-reduce model, the
hardware model the costs are expressed against, the resulting
gradient-merge schedule, and the scan segmentation derived from it —
plus provenance (which policy, which cost source) so a re-plan is
reproducible.

Plans serialize to JSON.  That makes them *artifacts*: an elastic
restart, a dry-run, or a benchmark reloads the plan instead of
recomputing Algorithm 1, and the measured-profile re-planning loop
(journal MG-WFBP, arXiv:1912.09268) diffs a live plan against measured
costs and emits a successor plan with updated provenance.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

from ..core.bucketing import (
    CommUnit,
    GroupArena,
    ParamLayout,
    group_arenas,
    layer_buckets_for_scan,
)
from ..core.comm_model import AllReduceModel
from ..core.cost_model import Hardware, LayerCost, TPU_V5E
from ..core.schedule import Schedule
from ..core.timeline import GroupTrace, TimelineResult
from .registry import build_schedule, resolve_policy_name

PLAN_FORMAT = 1


@dataclasses.dataclass(frozen=True)
class Plan:
    """Immutable record of one planning decision.

    Attributes:
      layout:        communication units over the parameter pytree.
      costs:         per-unit LayerCost vector the schedule was built from,
                     expressed against ``hw``.
      ar_model:      affine all-reduce model used (Eq. 9).
      hw:            hardware model converting cost flops/bytes to seconds.
      schedule:      the gradient-merge schedule (with evaluated timeline).
      n_scan_stages: leading-axis length of the stacked scan (None for
                     layouts without a scan).
      segments:      (start, stop) scan segments derived from the schedule
                     (None when n_scan_stages is None).
      policy_opts:   extra keyword options the policy was run with (e.g.
                     ``fixed``'s ``bucket_bytes``); re-plans reuse them.
      provenance:    string map — at least ``policy`` and ``cost_source``.
    """

    layout: ParamLayout
    costs: tuple[LayerCost, ...]
    ar_model: AllReduceModel
    hw: Hardware
    schedule: Schedule
    n_scan_stages: int | None = None
    segments: tuple[tuple[int, int], ...] | None = None
    policy_opts: dict[str, Any] = dataclasses.field(default_factory=dict)
    provenance: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def num_layers(self) -> int:
        return self.layout.num_layers

    @property
    def policy(self) -> str:
        return self.provenance.get("policy", self.schedule.method)

    def describe(self) -> str:
        src = self.provenance.get("cost_source", "?")
        return (
            f"plan[{self.policy}|{src}|{self.hw.name}] "
            f"{self.schedule.describe()}"
        )

    def group_arenas(self, shapes: Any, comm_dtype: Any = "float32") -> list[GroupArena]:
        """Per-group flat wire layouts for this plan's schedule — what
        ``fuse='arena'`` packs into (``shapes``: the parameter pytree or a
        ``path -> shape`` callable; see ``bucketing.group_arenas``)."""
        return group_arenas(self.layout, self.schedule, shapes, comm_dtype)

    # -- serialization ------------------------------------------------------

    def to_json_dict(self) -> dict[str, Any]:
        sched: dict[str, Any] = {
            "groups": [list(g) for g in self.schedule.groups],
            "method": self.schedule.method,
            "result": None,
        }
        if self.schedule.result is not None:
            r = self.schedule.result
            sched["result"] = {
                "t_iter": r.t_iter,
                "t_f": r.t_f,
                "t_b": r.t_b,
                "t_comm_total": r.t_comm_total,
                "t_comm_exposed": r.t_comm_exposed,
                "groups": [
                    {
                        "layers": list(tr.layers),
                        "nbytes": tr.nbytes,
                        "avail": tr.avail,
                        "start": tr.start,
                        "finish": tr.finish,
                    }
                    for tr in r.groups
                ],
            }
        return {
            "format": PLAN_FORMAT,
            "layout": [
                {
                    "name": u.name,
                    "index": u.index,
                    "grad_bytes": u.grad_bytes,
                    "params": u.params,
                    "paths": [list(p) for p in u.paths],
                    "kind": u.kind,
                    "stack_index": u.stack_index,
                }
                for u in self.layout.units
            ],
            "costs": [dataclasses.asdict(c) for c in self.costs],
            "ar_model": dataclasses.asdict(self.ar_model),
            "hw": dataclasses.asdict(self.hw),
            "schedule": sched,
            "n_scan_stages": self.n_scan_stages,
            "segments": [list(s) for s in self.segments] if self.segments is not None else None,
            "policy_opts": dict(self.policy_opts),
            "provenance": dict(self.provenance),
        }

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_json_dict(), indent=indent)

    @classmethod
    def from_json_dict(cls, d: dict[str, Any]) -> "Plan":
        if d.get("format") != PLAN_FORMAT:
            raise ValueError(f"unsupported plan format {d.get('format')!r}")
        units = tuple(
            CommUnit(
                name=u["name"],
                index=u["index"],
                grad_bytes=u["grad_bytes"],
                params=u["params"],
                paths=tuple(tuple(p) for p in u["paths"]),
                kind=u["kind"],
                stack_index=u["stack_index"],
            )
            for u in d["layout"]
        )
        result = None
        if d["schedule"]["result"] is not None:
            r = d["schedule"]["result"]
            result = TimelineResult(
                t_iter=r["t_iter"],
                t_f=r["t_f"],
                t_b=r["t_b"],
                t_comm_total=r["t_comm_total"],
                t_comm_exposed=r["t_comm_exposed"],
                groups=tuple(
                    GroupTrace(
                        layers=tuple(tr["layers"]),
                        nbytes=tr["nbytes"],
                        avail=tr["avail"],
                        start=tr["start"],
                        finish=tr["finish"],
                    )
                    for tr in r["groups"]
                ),
            )
        schedule = Schedule(
            groups=tuple(tuple(g) for g in d["schedule"]["groups"]),
            method=d["schedule"]["method"],
            result=result,
        )
        return cls(
            layout=ParamLayout(units=units),
            costs=tuple(LayerCost(**c) for c in d["costs"]),
            ar_model=AllReduceModel(**d["ar_model"]),
            hw=Hardware(**d["hw"]),
            schedule=schedule,
            n_scan_stages=d["n_scan_stages"],
            segments=tuple(tuple(s) for s in d["segments"]) if d["segments"] is not None else None,
            policy_opts=dict(d.get("policy_opts", {})),
            provenance=dict(d["provenance"]),
        )

    @classmethod
    def from_json(cls, text: str) -> "Plan":
        return cls.from_json_dict(json.loads(text))

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "Plan":
        return cls.from_json(pathlib.Path(path).read_text())


def build_plan(
    layout: ParamLayout,
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    *,
    policy: str = "mg_wfbp",
    hw: Hardware = TPU_V5E,
    n_scan_stages: int | None = None,
    cost_source: str = "analytic",
    policy_opts: dict[str, Any] | None = None,
    provenance: dict[str, str] | None = None,
) -> Plan:
    """Cost vector + policy -> evaluated Plan (the cost-source -> policy ->
    plan leg of the planning lifecycle)."""
    if len(costs) != layout.num_layers:
        raise ValueError(
            f"cost vector covers {len(costs)} units, layout has {layout.num_layers}"
        )
    policy = resolve_policy_name(policy)
    schedule = build_schedule(policy, costs, ar_model, hw=hw, **(policy_opts or {}))
    segments = (
        layer_buckets_for_scan(schedule, n_scan_stages)
        if n_scan_stages is not None
        else None
    )
    prov = {"policy": policy, "cost_source": cost_source}
    if provenance:
        prov.update(provenance)
    return Plan(
        layout=layout,
        costs=tuple(costs),
        ar_model=ar_model,
        hw=hw,
        schedule=schedule,
        n_scan_stages=n_scan_stages,
        segments=segments,
        policy_opts=dict(policy_opts or {}),
        provenance=prov,
    )
