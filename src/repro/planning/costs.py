"""Cost sources: where the scheduler's per-layer time vector comes from.

The paper seeds Algorithm 1 with *benchmarked* backward times ("the first
several iterations"); our repo historically only had the analytic Eq. 18
path.  This module makes the source pluggable:

  * ``AnalyticCosts``  — the Eq. 18 / roofline estimate (flops and bytes
    per unit converted to seconds by a ``Hardware`` preset);
  * ``MeasuredCosts``  — wall-clock observations: per-unit times from HLO
    segment profiling (``core/profiler.py``), or a whole-step timing that
    rescales the analytic compute model.  Measured times are expressed
    against ``MEASURED_HW`` (unit hardware: 1 flop == 1 second) so the
    scheduler math is unchanged.

``replan_if_drifted`` is the journal version's online re-planning: when a
live cost measurement drifts from the vector a plan was built with, the
same policy reruns on the measured vector and a successor plan is
emitted.  The training loop and the fault-tolerant restart path both call
it (see ``launch/train.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

from ..core.bucketing import layer_buckets_for_scan
from ..core.cost_model import Hardware, LayerCost, TPU_V5E
from .plan import Plan
from .registry import build_schedule, resolve_policy_name

#: Unit hardware: costs carry wall-clock seconds directly in ``bwd_flops``
#: / ``fwd_flops`` (1 FLOP == 1 s, no memory term).
MEASURED_HW = Hardware(
    name="measured_wallclock", peak_flops=1.0, hbm_bw=1.0, mxu_eff=1.0, hbm_eff=1.0
)


@runtime_checkable
class CostSource(Protocol):
    """A producer of the scheduler's per-layer cost vector."""

    name: str
    hw: Hardware

    def layer_costs(self) -> list[LayerCost]: ...


@dataclasses.dataclass(frozen=True)
class AnalyticCosts:
    """Eq. 18-style analytic cost vector (today's default path)."""

    costs: tuple[LayerCost, ...]
    hw: Hardware = TPU_V5E
    name: str = "analytic"

    def layer_costs(self) -> list[LayerCost]:
        return list(self.costs)


@dataclasses.dataclass(frozen=True)
class MeasuredCosts:
    """Wall-clock per-unit cost vector (seconds, against ``MEASURED_HW``)."""

    costs: tuple[LayerCost, ...]
    hw: Hardware = MEASURED_HW
    name: str = "measured"

    def layer_costs(self) -> list[LayerCost]:
        return list(self.costs)

    @classmethod
    def from_unit_times(
        cls,
        base: list[LayerCost],
        bwd_seconds: list[float],
        fwd_seconds: list[float] | None = None,
        name: str = "measured",
    ) -> "MeasuredCosts":
        """Directly measured per-unit backward (and optional forward) times.

        Message sizes and param counts are carried over from ``base`` —
        measurement changes *times*, never payloads.
        """
        if len(bwd_seconds) != len(base):
            raise ValueError(f"{len(bwd_seconds)} times for {len(base)} units")
        if fwd_seconds is not None and len(fwd_seconds) != len(base):
            raise ValueError(f"{len(fwd_seconds)} fwd times for {len(base)} units")
        out = []
        for i, c in enumerate(base):
            out.append(
                LayerCost(
                    name=c.name,
                    params=c.params,
                    grad_bytes=c.grad_bytes,
                    bwd_flops=float(bwd_seconds[i]),
                    fwd_flops=float(fwd_seconds[i]) if fwd_seconds is not None else 0.0,
                )
            )
        return cls(costs=tuple(out), name=name)

    @classmethod
    def from_step_timing(
        cls,
        base: list[LayerCost],
        base_hw: Hardware,
        measured_t_iter: float,
        modeled_t_iter: float,
        name: str = "measured_step",
    ) -> "MeasuredCosts":
        """Whole-step wall-clock calibration (cheapest online signal).

        One measured iteration time rescales every analytic compute time by
        ``measured / modeled`` — the single-free-parameter fit the paper
        itself uses to calibrate Eq. 18 constants.  Comm (α–β) stays fixed,
        so the compute/comm overlap balance — and hence the optimal merge
        set — genuinely shifts.
        """
        if modeled_t_iter <= 0 or measured_t_iter <= 0:
            raise ValueError("step times must be positive")
        scale = measured_t_iter / modeled_t_iter
        bwd = [c.t_b(base_hw) * scale for c in base]
        fwd = [c.t_f(base_hw) * scale for c in base]
        return cls.from_unit_times(base, bwd, fwd, name=name)

    @classmethod
    def from_segment_times(
        cls,
        base: list[LayerCost],
        base_hw: Hardware,
        unit_seconds: dict[str, float],
        name: str = "measured_segments",
    ) -> "MeasuredCosts":
        """Per-unit overrides from HLO segment profiling.

        ``unit_seconds`` maps unit names (``embed``, ``stage_0``, ...,
        ``head``) to measured backward seconds; unmeasured units keep their
        analytic time.  This is the compiled-segment analogue of the
        paper's first-iterations benchmark (see ``core/profiler.py``).
        """
        bwd = [unit_seconds.get(c.name, c.t_b(base_hw)) for c in base]
        fwd = [c.t_f(base_hw) for c in base]
        return cls.from_unit_times(base, bwd, fwd, name=name)


def cost_drift(plan: Plan, measured: CostSource) -> float:
    """Max relative per-unit backward-time deviation of measured vs plan.

    0.0 == identical; 0.5 == some layer's measured backward time is 50%
    away from what the plan was scheduled with.
    """
    base = [c.t_b(plan.hw) for c in plan.costs]
    new = [c.t_b(measured.hw) for c in measured.layer_costs()]
    if len(base) != len(new):
        raise ValueError(f"measured {len(new)} units, plan has {len(base)}")
    worst = 0.0
    for b, n in zip(base, new):
        denom = max(abs(b), 1e-12)
        worst = max(worst, abs(n - b) / denom)
    return worst


def replan_if_drifted(
    plan: Plan,
    measured: CostSource,
    threshold: float = 0.15,
    policy: str | None = None,
) -> tuple[Plan, bool]:
    """Re-run the plan's policy on measured costs when drift exceeds
    ``threshold``; returns ``(plan, replanned)``.

    The successor plan keeps the layout and α–β model, swaps in the
    measured cost vector and its hardware basis, and records the drift and
    cost source in provenance.  Below threshold the original plan is
    returned untouched — re-planning recompiles the train step (new scan
    segments), so it must be rare and deliberate.
    """
    drift = cost_drift(plan, measured)
    if drift <= threshold:
        return plan, False
    policy = resolve_policy_name(policy or plan.policy)
    costs = measured.layer_costs()
    schedule = build_schedule(
        policy, costs, plan.ar_model, hw=measured.hw, **plan.policy_opts
    )
    segments = (
        layer_buckets_for_scan(schedule, plan.n_scan_stages)
        if plan.n_scan_stages is not None
        else None
    )
    prov = dict(plan.provenance)
    prov.update(
        {
            "policy": policy,
            "cost_source": measured.name,
            "replanned_from": plan.provenance.get("cost_source", "?"),
            "drift": f"{drift:.4f}",
        }
    )
    new_plan = dataclasses.replace(
        plan,
        costs=tuple(costs),
        hw=measured.hw,
        schedule=schedule,
        segments=segments,
        provenance=prov,
    )
    return new_plan, True
