"""Cost sources: where the scheduler's per-layer time vector comes from.

The paper seeds Algorithm 1 with *benchmarked* backward times ("the first
several iterations"); our repo historically only had the analytic Eq. 18
path.  This module makes the source pluggable:

  * ``AnalyticCosts``  — the Eq. 18 / roofline estimate (flops and bytes
    per unit converted to seconds by a ``Hardware`` preset);
  * ``MeasuredCosts``  — wall-clock observations: per-unit times from HLO
    segment profiling (``core/profiler.py``), or a whole-step timing that
    rescales the analytic compute model.  Measured times are expressed
    against ``MEASURED_HW`` (unit hardware: 1 flop == 1 second) so the
    scheduler math is unchanged.

``replan_if_drifted`` is the journal version's online re-planning: when a
live cost measurement drifts from the vector a plan was built with, the
same policy reruns on the measured vector and a successor plan is
emitted.  The training loop and the fault-tolerant restart path both call
it (see ``launch/train.py``).

The *communication* side has the same analytic/measured split:
``MeasuredComm`` times real psums over a size sweep and least-squares
fits the (α, β) of Eq. 9 per mesh axis (journal §V-A Fig. 5(b), online)
— the measured counterpart of ``core.comm_model``'s analytic
``tpu_psum_model``.  Its ``fit()`` is an ordinary ``AllReduceModel``, so
plans and every registered policy consume measured comm models
transparently.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from ..core.bucketing import layer_buckets_for_scan
from ..core.comm_model import AllReduceModel, fit_affine
from ..core.cost_model import Hardware, LayerCost, TPU_V5E
from .plan import Plan
from .registry import build_schedule, resolve_policy_name

#: Unit hardware: costs carry wall-clock seconds directly in ``bwd_flops``
#: / ``fwd_flops`` (1 FLOP == 1 s, no memory term).
MEASURED_HW = Hardware(
    name="measured_wallclock", peak_flops=1.0, hbm_bw=1.0, mxu_eff=1.0, hbm_eff=1.0
)


@runtime_checkable
class CostSource(Protocol):
    """A producer of the scheduler's per-layer cost vector."""

    name: str
    hw: Hardware

    def layer_costs(self) -> list[LayerCost]: ...


@dataclasses.dataclass(frozen=True)
class AnalyticCosts:
    """Eq. 18-style analytic cost vector (today's default path)."""

    costs: tuple[LayerCost, ...]
    hw: Hardware = TPU_V5E
    name: str = "analytic"

    def layer_costs(self) -> list[LayerCost]:
        return list(self.costs)


@dataclasses.dataclass(frozen=True)
class MeasuredCosts:
    """Wall-clock per-unit cost vector (seconds, against ``MEASURED_HW``)."""

    costs: tuple[LayerCost, ...]
    hw: Hardware = MEASURED_HW
    name: str = "measured"

    def layer_costs(self) -> list[LayerCost]:
        return list(self.costs)

    @classmethod
    def from_unit_times(
        cls,
        base: list[LayerCost],
        bwd_seconds: list[float],
        fwd_seconds: list[float] | None = None,
        name: str = "measured",
    ) -> "MeasuredCosts":
        """Directly measured per-unit backward (and optional forward) times.

        Message sizes and param counts are carried over from ``base`` —
        measurement changes *times*, never payloads.
        """
        if len(bwd_seconds) != len(base):
            raise ValueError(f"{len(bwd_seconds)} times for {len(base)} units")
        if fwd_seconds is not None and len(fwd_seconds) != len(base):
            raise ValueError(f"{len(fwd_seconds)} fwd times for {len(base)} units")
        out = []
        for i, c in enumerate(base):
            out.append(
                LayerCost(
                    name=c.name,
                    params=c.params,
                    grad_bytes=c.grad_bytes,
                    bwd_flops=float(bwd_seconds[i]),
                    fwd_flops=float(fwd_seconds[i]) if fwd_seconds is not None else 0.0,
                )
            )
        return cls(costs=tuple(out), name=name)

    @classmethod
    def from_step_timing(
        cls,
        base: list[LayerCost],
        base_hw: Hardware,
        measured_t_iter: float,
        modeled_t_iter: float,
        name: str = "measured_step",
    ) -> "MeasuredCosts":
        """Whole-step wall-clock calibration (cheapest online signal).

        One measured iteration time rescales every analytic compute time by
        ``measured / modeled`` — the single-free-parameter fit the paper
        itself uses to calibrate Eq. 18 constants.  Comm (α–β) stays fixed,
        so the compute/comm overlap balance — and hence the optimal merge
        set — genuinely shifts.
        """
        if modeled_t_iter <= 0 or measured_t_iter <= 0:
            raise ValueError("step times must be positive")
        scale = measured_t_iter / modeled_t_iter
        bwd = [c.t_b(base_hw) * scale for c in base]
        fwd = [c.t_f(base_hw) * scale for c in base]
        return cls.from_unit_times(base, bwd, fwd, name=name)

    @classmethod
    def from_segment_times(
        cls,
        base: list[LayerCost],
        base_hw: Hardware,
        unit_seconds: dict[str, float],
        name: str = "measured_segments",
    ) -> "MeasuredCosts":
        """Per-unit overrides from HLO segment profiling.

        ``unit_seconds`` maps unit names (``embed``, ``stage_0``, ...,
        ``head``) to measured backward seconds; unmeasured units keep their
        analytic time.  This is the compiled-segment analogue of the
        paper's first-iterations benchmark (see ``core/profiler.py``).
        """
        bwd = [unit_seconds.get(c.name, c.t_b(base_hw)) for c in base]
        fwd = [c.t_f(base_hw) for c in base]
        return cls.from_unit_times(base, bwd, fwd, name=name)


#: A timed probe this many times slower than the running min is treated
#: as an outlier (GC pause, noisy neighbor) and re-taken rather than
#: recorded — see ``min_of_k``.
PROBE_OUTLIER_FACTOR = 10.0


def min_of_k(
    sample_fn: Callable[[], float],
    repeats: int,
    *,
    outlier_factor: float = PROBE_OUTLIER_FACTOR,
    max_retries: int | None = None,
) -> float:
    """Min of ``repeats`` samples with an outlier retry.

    A sample exceeding ``outlier_factor`` × the running min is discarded
    and re-taken (a GC pause or noisy neighbor would otherwise burn one
    of the ``repeats`` slots and, with small ``repeats``, silently skew
    the calibration the sample feeds — ``t_step_fixed``, (α, β) fits).
    Retries are bounded by ``max_retries`` (default ``repeats``) so a
    *genuine* sustained slowdown is reported, not spun on: once the
    budget is spent every sample counts.  Shared by
    ``time_collective_call`` and ``ServingEngine.probe_step_time``.
    """
    repeats = max(1, repeats)
    budget = repeats if max_retries is None else max(0, max_retries)
    best = float("inf")
    taken = retried = 0
    while taken < repeats:
        t = float(sample_fn())
        if t > outlier_factor * best and retried < budget:
            retried += 1
            continue
        best = min(best, t)
        taken += 1
    return best


def time_collective_call(
    f, x, repeats: int = 3, warmup: int = 1,
    clock: Callable[[], float] = time.perf_counter,
) -> float:
    """Run ``warmup`` discarded calls (the first compiles — compile time
    must NEVER reach a timed sample, it would poison every (α, β) fit
    min-of-N merely hides) and return the min of ``repeats`` timed calls
    — the one latency estimator shared by ``MeasuredComm.time_psums``
    (train psums) and ``planning.serve.measure_serve_comm`` (serve
    gathers/all-to-alls), so compute- and comm-side measured costs stay
    directly comparable.  Samples run through ``min_of_k``: a probe 10×
    slower than the running min is re-taken, so one scheduler hiccup
    cannot poison a 3-sample calibration.  ``clock`` is injectable (the
    FakeClock pattern) so tests never assert on real wall-clock deltas."""
    import jax

    for _ in range(max(1, warmup)):  # at least one: compile + warm
        jax.block_until_ready(f(x))

    def sample() -> float:
        t0 = clock()
        jax.block_until_ready(f(x))
        return clock() - t0

    return min_of_k(sample, repeats)


#: Default psum size sweep: 4 KiB … 16 MiB in ×8 steps — small enough to
#: expose α, large enough to pin β (the journal sweeps the same decades).
DEFAULT_COMM_SWEEP = tuple(4 * 1024 * 8**i for i in range(6))

#: Amortized re-fit sweep: one small, one mid, one large size.  Three
#: timed psums per drift check keep the online comm monitor cheap while
#: still moving both ends of the affine fit (α from the small size, β
#: from the large one).
SLIM_COMM_SWEEP = (DEFAULT_COMM_SWEEP[0], DEFAULT_COMM_SWEEP[2], DEFAULT_COMM_SWEEP[5])


@dataclasses.dataclass(frozen=True)
class MeasuredComm:
    """Measured (α, β) all-reduce model for one set of mesh axes.

    Raw observations are kept (sizes in bytes, wall seconds) so the fit
    is reproducible and re-fittable; ``fit()`` returns the affine
    ``AllReduceModel`` every policy/plan already consumes.
    """

    sizes_bytes: tuple[int, ...]
    times_s: tuple[float, ...]
    axes: tuple[str, ...] = ("data",)
    name: str = "measured_comm"

    def fit(self) -> AllReduceModel:
        return fit_affine(
            self.sizes_bytes, self.times_s,
            name=f"{self.name}[{'+'.join(self.axes)}]",
        )

    def update(
        self,
        sizes_bytes: tuple[int, ...] | list[int],
        times_s: tuple[float, ...] | list[float],
        weight: float = 0.5,
    ) -> "MeasuredComm":
        """Fold fresh observations into the sweep (returns a new record).

        Re-observed sizes are exponentially weighted (``new = (1-w)·old +
        w·fresh``) so a transient spike does not whiplash the (α, β) fit,
        while sustained congestion converges in a few checks; unseen sizes
        are appended.  This is the amortized online fit of the journal
        version: a slim ``SLIM_COMM_SWEEP`` re-probe per check instead of
        the full startup sweep.
        """
        if not 0.0 < weight <= 1.0:
            raise ValueError(f"EWMA weight must be in (0, 1], got {weight}")
        obs = dict(zip(self.sizes_bytes, self.times_s))
        for s, t in zip(sizes_bytes, times_s):
            s = int(s)
            obs[s] = (1.0 - weight) * obs[s] + weight * float(t) if s in obs else float(t)
        items = sorted(obs.items())
        return dataclasses.replace(
            self,
            sizes_bytes=tuple(s for s, _ in items),
            times_s=tuple(t for _, t in items),
        )

    @classmethod
    def time_psums(
        cls,
        mesh,
        axes: tuple[str, ...] = ("data",),
        sizes_bytes: tuple[int, ...] = DEFAULT_COMM_SWEEP,
        dtype=None,
        repeats: int = 3,
        name: str = "measured_comm",
    ) -> "MeasuredComm":
        """Time real psums over a size sweep on ``mesh``'s ``axes``.

        One jitted ``shard_map`` psum per size; the first (compiling)
        call is discarded and the min of ``repeats`` timed calls is kept
        — the standard latency estimator, robust to scheduler noise.
        """
        import jax
        import jax.numpy as jnp

        from ..compat import shard_map

        dtype = jnp.float32 if dtype is None else dtype
        P = jax.sharding.PartitionSpec
        axis_arg = axes if len(axes) > 1 else axes[0]
        times = []
        for nb in sizes_bytes:
            n = max(1, int(nb) // np.dtype(dtype).itemsize)
            x = jnp.ones((n,), dtype)

            def body(v):
                return jax.lax.psum(v, axis_arg)

            f = jax.jit(
                shard_map(
                    body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                    axis_names=set(axes), check_vma=False,
                )
            )
            times.append(time_collective_call(f, x, repeats))
        return cls(
            sizes_bytes=tuple(int(s) for s in sizes_bytes),
            times_s=tuple(times), axes=tuple(axes), name=name,
        )


def measure_comm_models(
    mesh, axes: tuple[str, ...] | None = None, **kwargs
) -> dict[str, AllReduceModel]:
    """Per-mesh-axis measured (α, β) fits — one ``MeasuredComm`` sweep
    and fit per axis (plus every axis jointly when there are several,
    under the ``'+'``-joined key), so hierarchical meshes get per-stage
    measured constants the way ``TpuInterconnect.psum_model`` composes
    analytic ones."""
    axes = tuple(mesh.axis_names) if axes is None else tuple(axes)
    out = {ax: MeasuredComm.time_psums(mesh, (ax,), **kwargs).fit() for ax in axes}
    if len(axes) > 1:
        out["+".join(axes)] = MeasuredComm.time_psums(mesh, axes, **kwargs).fit()
    return out


def cost_drift(plan: Plan, measured: CostSource) -> float:
    """Max relative per-unit backward-time deviation of measured vs plan.

    0.0 == identical; 0.5 == some layer's measured backward time is 50%
    away from what the plan was scheduled with.
    """
    base = [c.t_b(plan.hw) for c in plan.costs]
    new = [c.t_b(measured.hw) for c in measured.layer_costs()]
    if len(base) != len(new):
        raise ValueError(f"measured {len(new)} units, plan has {len(base)}")
    worst = 0.0
    for b, n in zip(base, new):
        denom = max(abs(b), 1e-12)
        worst = max(worst, abs(n - b) / denom)
    return worst


def replan_if_drifted(
    plan: Plan,
    measured: CostSource,
    threshold: float = 0.15,
    policy: str | None = None,
) -> tuple[Plan, bool]:
    """Re-run the plan's policy on measured costs when drift exceeds
    ``threshold``; returns ``(plan, replanned)``.

    The successor plan keeps the layout and α–β model, swaps in the
    measured cost vector and its hardware basis, and records the drift and
    cost source in provenance.  Below threshold the original plan is
    returned untouched — re-planning recompiles the train step (new scan
    segments), so it must be rare and deliberate.
    """
    drift = cost_drift(plan, measured)
    if drift <= threshold:
        return plan, False
    policy = resolve_policy_name(policy or plan.policy)
    costs = measured.layer_costs()
    schedule = build_schedule(
        policy, costs, plan.ar_model, hw=measured.hw, **plan.policy_opts
    )
    segments = (
        layer_buckets_for_scan(schedule, plan.n_scan_stages)
        if plan.n_scan_stages is not None
        else None
    )
    prov = dict(plan.provenance)
    prov.update(
        {
            "policy": policy,
            "cost_source": measured.name,
            "replanned_from": plan.provenance.get("cost_source", "?"),
            "drift": f"{drift:.4f}",
        }
    )
    new_plan = dataclasses.replace(
        plan,
        costs=tuple(costs),
        hw=measured.hw,
        schedule=schedule,
        segments=segments,
        provenance=prov,
    )
    return new_plan, True


def comm_drift(old: AllReduceModel, new: AllReduceModel) -> float:
    """Max relative deviation of the fitted (α, β) pair vs a reference.

    0.0 == identical constants; 9.0 == one of α/β moved ×10 (congestion,
    a degraded link).  Denominators are floored so a near-zero reference
    constant does not turn measurement noise into infinite drift.
    """
    da = abs(new.a - old.a) / max(abs(old.a), 1e-9)
    db = abs(new.b - old.b) / max(abs(old.b), 1e-15)
    return max(da, db)


def replan_if_comm_drifted(
    plan: Plan,
    new_model: AllReduceModel,
    threshold: float = 0.25,
    policy: str | None = None,
) -> tuple[Plan, bool]:
    """The comm-side analogue of ``replan_if_drifted``: re-run the plan's
    policy under a freshly fitted (α, β) model when it drifts past
    ``threshold``; returns ``(plan, replanned)``.

    The successor plan keeps the cost vector and layout, swaps in the
    measured all-reduce model, and records the drift in provenance.  α is
    the merge gain itself (Eq. 10), so a drifted α directly moves the
    optimal merge set — this is what completes the journal version's
    online loop (arXiv:1912.09268 Fig. 5(b)) for the wire side.
    """
    drift = comm_drift(plan.ar_model, new_model)
    if drift <= threshold:
        return plan, False
    policy = resolve_policy_name(policy or plan.policy)
    costs = list(plan.costs)
    schedule = build_schedule(policy, costs, new_model, hw=plan.hw, **plan.policy_opts)
    segments = (
        layer_buckets_for_scan(schedule, plan.n_scan_stages)
        if plan.n_scan_stages is not None
        else None
    )
    prov = dict(plan.provenance)
    prov.update(
        {
            "policy": policy,
            "comm_source": new_model.name,
            "replanned_from_comm": plan.ar_model.name,
            "comm_drift": f"{drift:.4f}",
        }
    )
    new_plan = dataclasses.replace(
        plan,
        ar_model=new_model,
        schedule=schedule,
        segments=segments,
        provenance=prov,
    )
    return new_plan, True
