"""The Plan lifecycle extended to serving: the frozen ``ServePlan``.

Decode has the same shape of problem as training: per-stage compute runs
sequentially while per-stage collectives — the KV-cache all-gather of
TP-sharded attention, the expert all-to-all of EP MoE — can overlap and
*merge*.  Eq. 9/10 apply verbatim: each collective costs ``a + b·M`` on
the serving fabric, so merging adjacent stages' messages recovers ``a``
per merge exactly as in training.  This module reuses the existing
planner machinery end to end:

  * ``decode_unit_costs`` builds the per-stage cost vector (decode flops
    per token step + collective payload bytes per stage);
  * ``build_serve_plan`` selects the dominant decode collective
    (``all_to_all`` for MoE archs, ``all_gather`` otherwise), prices it
    through a registry ``Fabric`` (``fabric.cost(op, axis_sizes)``), and
    runs a registered scheduler policy — the same Algorithm 1 / exact DP
    the training plan uses — into a frozen, JSON-serializable
    ``ServePlan``;
  * ``make_group_collective`` is the executable leg: one fused collective
    per scheduled serve group (``fabric.ops.issue``), the decode analogue
    of ``core.sync``'s one-all-reduce-per-group invariant (pinned by the
    serve lowering test in ``tests/test_fabric.py``).

Consumers: ``serving.engine.ServingEngine`` carries the plan,
``launch/serve.py`` builds/saves it (``--fabric``/``--plan-out``), and
``launch/dryrun.py`` records one per decode cell.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

import numpy as np

from ..core.comm_model import AllReduceModel
from ..core.cost_model import Hardware, LayerCost, TPU_V5E
from ..core.schedule import Schedule
from ..fabric import Collective, Fabric, get_fabric, issue
from .registry import build_schedule, resolve_policy_name

SERVE_PLAN_FORMAT = 1


def _tree_size(tree: Any) -> int:
    import jax

    return sum(
        int(np.prod(getattr(x, "shape", ()) or (1,))) for x in jax.tree.leaves(tree)
    )


def decode_unit_costs(
    cfg: Any,
    param_shapes: Any,
    batch_rows: int,
    *,
    cache_dtype_bytes: int = 2,
    act_dtype_bytes: int = 2,
) -> list[LayerCost]:
    """Per-scan-stage decode cost vector (one token per row per step).

    ``grad_bytes`` is repurposed as the stage's *collective payload* per
    decode step: the fresh KV rows every attention layer in the stage
    must all-gather across the TP shards, plus (MoE) the dispatch+combine
    activations of the expert all-to-all.  ``bwd_flops`` carries the
    stage's decode compute (the timeline's sequential axis; ``t_f`` is 0
    for decode).  Head/embed run outside the scan and ship nothing, so
    units are exactly the ``n_stages`` scan stages — what
    ``make_group_collective`` slices a stacked cache tree by.
    """
    stage_p = _tree_size(param_shapes["stages"]) // cfg.n_stages
    # every non-recurrent block carries an attention sublayer with a KV
    # cache (models/transformer._init_sublayer) — 'moe' included
    attn_layers = sum(1 for kind in cfg.pattern if kind not in ("rwkv", "rec"))
    kv_row = (
        cfg.attention.n_kv_heads * cfg.attention.head_dim if cfg.attention else 0
    )
    # K and V, one fresh row per sequence per attention layer per step
    kv_bytes = 2 * batch_rows * kv_row * cache_dtype_bytes * attn_layers
    a2a_bytes = 0
    active = 1.0
    if cfg.moe is not None:
        active = cfg.moe.top_k / cfg.moe.n_experts
        active = 0.25 + 0.75 * active if active < 1 else 1.0
        # dispatch + combine of top_k expert activations per token
        a2a_bytes = (
            2 * batch_rows * cfg.moe.top_k * cfg.d_model * act_dtype_bytes * len(cfg.pattern)
        )
    out = []
    for i in range(cfg.n_stages):
        out.append(
            LayerCost(
                name=f"stage_{i}",
                params=stage_p,
                grad_bytes=max(1, kv_bytes + a2a_bytes),
                bwd_flops=2.0 * stage_p * batch_rows * active,
                fwd_flops=0.0,
            )
        )
    return out


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """Immutable record of one decode-side scheduling decision.

    Attributes:
      arch:       architecture name the plan was built for.
      op:         the scheduled collective (``Collective`` value string).
      axis:       mesh axis the collective runs over at execution time.
      axis_sizes: mesh axis sizes the fabric priced the op at.
      fabric:     registry name of the fabric the model came from.
      costs:      per-stage decode cost vector (see ``decode_unit_costs``).
      model:      affine (a, b) model of ``op`` on the fabric.
      hw:         hardware model converting cost flops to seconds.
      schedule:   the merge schedule over stages (with evaluated timeline).
      t_step_fixed: measured per-step fixed (dispatch+compute) seconds —
                  the startup term of the *step*, not the wire.  0.0
                  until a probe fills it (``ServingEngine.calibrate_plan``
                  / ``with_step_fixed``); ``predicted_step_time`` adds it
                  to the wire timeline so predictions stay honest.
      provenance: string map — at least ``policy`` and ``fabric``.
    """

    arch: str
    op: str
    axis: str
    axis_sizes: dict[str, int]
    fabric: str
    costs: tuple[LayerCost, ...]
    model: AllReduceModel
    hw: Hardware
    schedule: Schedule
    t_step_fixed: float = 0.0
    provenance: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def num_stages(self) -> int:
        return len(self.costs)

    @property
    def policy(self) -> str:
        return self.provenance.get("policy", self.schedule.method)

    def predicted_step_time(self) -> float | None:
        """Modeled decode-step seconds: the evaluated wire timeline
        (``schedule.result.t_iter``) plus the measured per-step fixed
        term — the two-term cost model MG-WFBP's startup/bandwidth
        decomposition suggests for the step itself.  None before the
        schedule is evaluated."""
        if self.schedule.result is None:
            return None
        return self.schedule.result.t_iter + self.t_step_fixed

    def predicted_completion_s(self, n_tokens: int) -> float | None:
        """Modeled seconds for one request to decode ``n_tokens`` more
        tokens: the engine emits one token per request per step, so a
        request's remaining work is ``n_tokens`` steps no matter how many
        rows share the batch.  Fleet-level admission prices a request's
        ETA with this (queue wait + this) against its deadline.  None
        before the schedule is evaluated."""
        step = self.predicted_step_time()
        return None if step is None else step * max(0, int(n_tokens))

    def capacity_tok_per_s(self, rows: int) -> float | None:
        """Modeled steady-state throughput of one replica running this
        plan with ``rows`` busy decode slots: ``rows`` tokens per
        predicted step.  The fleet watchdog prices scale-up/down
        decisions with this — adding a replica buys exactly this much
        capacity, removing one sheds it.  None before the schedule is
        evaluated."""
        step = self.predicted_step_time()
        if step is None or step <= 0:
            return None
        return int(rows) / step

    def with_step_fixed(self, t_step_fixed: float) -> "ServePlan":
        """A copy of this plan with the measured fixed (dispatch+compute)
        per-step term installed (provenance records the source)."""
        prov = dict(self.provenance)
        prov["t_step_fixed_source"] = "probe"
        return dataclasses.replace(
            self, t_step_fixed=float(t_step_fixed), provenance=prov
        )

    def group_summaries(self) -> tuple[dict[str, Any], ...]:
        """Per scheduled group: stage span, wire bytes, the fabric's
        predicted collective seconds (``a + b·M`` at the group's
        payload), and the plan-level fixed term (``t_fixed_s``, same on
        every row) — the rows ``describe()`` renders and the serve
        benchmarks compare measured gather times against."""
        if self.schedule.result is None:
            return ()
        return tuple(
            {
                "stages": tr.layers,
                "nbytes": tr.nbytes,
                "t_pred_s": self.model(tr.nbytes),
                "t_fixed_s": self.t_step_fixed,
                "start_s": tr.start,
                "finish_s": tr.finish,
            }
            for tr in self.schedule.result.groups
        )

    def describe(self) -> str:
        """Human-readable plan summary including the fixed-vs-wire step
        decomposition and per-group predicted collective times and wire
        bytes, so a ``--plan-out`` artifact is reviewable without
        loading the JSON."""
        head = (
            f"serve_plan[{self.policy}|{self.fabric}|{self.op}] "
            f"{self.schedule.describe()}"
        )
        if self.schedule.result is not None:
            wire = self.schedule.result.t_iter
            head += (
                f" step=fixed {self.t_step_fixed * 1e6:.1f}us"
                f" + wire {wire * 1e6:.1f}us"
                f" = {(self.t_step_fixed + wire) * 1e6:.1f}us"
            )
        rows = self.group_summaries()
        if not rows:
            return head
        lines = [head]
        for g in rows:
            lo, hi = g["stages"]
            lines.append(
                f"  group[{lo}..{hi}] wire={g['nbytes']}B "
                f"t_pred={g['t_pred_s'] * 1e6:.1f}us "
                f"start={g['start_s'] * 1e6:.1f}us "
                f"finish={g['finish_s'] * 1e6:.1f}us"
            )
        return "\n".join(lines)

    # -- serialization (mirrors planning.Plan) ------------------------------

    def to_json_dict(self) -> dict[str, Any]:
        sched: dict[str, Any] = {
            "groups": [list(g) for g in self.schedule.groups],
            "method": self.schedule.method,
            "result": None,
        }
        if self.schedule.result is not None:
            r = self.schedule.result
            sched["result"] = {
                "t_iter": r.t_iter,
                "t_f": r.t_f,
                "t_b": r.t_b,
                "t_comm_total": r.t_comm_total,
                "t_comm_exposed": r.t_comm_exposed,
                "groups": [
                    {
                        "layers": list(tr.layers),
                        "nbytes": tr.nbytes,
                        "avail": tr.avail,
                        "start": tr.start,
                        "finish": tr.finish,
                    }
                    for tr in r.groups
                ],
            }
        return {
            "format": SERVE_PLAN_FORMAT,
            "arch": self.arch,
            "op": self.op,
            "axis": self.axis,
            "axis_sizes": dict(self.axis_sizes),
            "fabric": self.fabric,
            "costs": [dataclasses.asdict(c) for c in self.costs],
            "model": dataclasses.asdict(self.model),
            "hw": dataclasses.asdict(self.hw),
            "schedule": sched,
            "t_step_fixed": self.t_step_fixed,
            "provenance": dict(self.provenance),
        }

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_json_dict(), indent=indent)

    @classmethod
    def from_json_dict(cls, d: dict[str, Any]) -> "ServePlan":
        from ..core.timeline import GroupTrace, TimelineResult

        if d.get("format") != SERVE_PLAN_FORMAT:
            raise ValueError(f"unsupported serve plan format {d.get('format')!r}")
        result = None
        if d["schedule"]["result"] is not None:
            r = d["schedule"]["result"]
            result = TimelineResult(
                t_iter=r["t_iter"],
                t_f=r["t_f"],
                t_b=r["t_b"],
                t_comm_total=r["t_comm_total"],
                t_comm_exposed=r["t_comm_exposed"],
                groups=tuple(
                    GroupTrace(
                        layers=tuple(tr["layers"]),
                        nbytes=tr["nbytes"],
                        avail=tr["avail"],
                        start=tr["start"],
                        finish=tr["finish"],
                    )
                    for tr in r["groups"]
                ),
            )
        return cls(
            arch=d["arch"],
            op=d["op"],
            axis=d["axis"],
            axis_sizes={k: int(v) for k, v in d["axis_sizes"].items()},
            fabric=d["fabric"],
            costs=tuple(LayerCost(**c) for c in d["costs"]),
            model=AllReduceModel(**d["model"]),
            hw=Hardware(**d["hw"]),
            schedule=Schedule(
                groups=tuple(tuple(g) for g in d["schedule"]["groups"]),
                method=d["schedule"]["method"],
                result=result,
            ),
            # optional: plans saved before the fixed-term model load as 0.0
            t_step_fixed=float(d.get("t_step_fixed", 0.0)),
            provenance=dict(d["provenance"]),
        )

    @classmethod
    def from_json(cls, text: str) -> "ServePlan":
        return cls.from_json_dict(json.loads(text))

    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "ServePlan":
        return cls.from_json(pathlib.Path(path).read_text())


def build_serve_plan(
    cfg: Any,
    param_shapes: Any,
    fabric: str | Fabric,
    axis_sizes: dict[str, int],
    *,
    batch_rows: int,
    policy: str = "mg_wfbp",
    hw: Hardware = TPU_V5E,
    axis: str = "model",
    op: Collective | str | None = None,
    policy_opts: dict[str, Any] | None = None,
    provenance: dict[str, str] | None = None,
    cache_dtype_bytes: int = 2,
    act_dtype_bytes: int = 2,
) -> ServePlan:
    """Cost vector + fabric + policy -> evaluated ServePlan.

    The collective defaults to the arch's dominant decode op
    (``all_to_all`` for MoE, ``all_gather`` otherwise); any registered
    fabric prices it — the same registry, the same merge math, training
    and serving.  ``cache_dtype_bytes``/``act_dtype_bytes`` size the wire
    payload: the production default is bf16 (2); pass 4 when pricing an
    engine whose caches run fp32 (the reduced CPU engines) so measured
    group collectives compare against the bytes the step actually ships.

    Example::

        cfg = get_config("tinyllama-1.1b")
        plan = build_serve_plan(cfg, param_specs(cfg), "gpu_nccl",
                                {"model": 8}, batch_rows=16)
        print(plan.describe())          # per-group bytes + predicted times
        run = make_group_collective(plan)   # the executable wire
    """
    fab = get_fabric(fabric)
    if op is None:
        op = Collective.ALL_TO_ALL if cfg.moe is not None else Collective.ALL_GATHER
    op = Collective(op)
    model = fab.cost(op, axis_sizes)
    costs = decode_unit_costs(
        cfg, param_shapes, batch_rows,
        cache_dtype_bytes=cache_dtype_bytes, act_dtype_bytes=act_dtype_bytes,
    )
    policy = resolve_policy_name(policy)
    schedule = build_schedule(
        policy, costs, model, hw=hw, t_f=0.0, **(policy_opts or {})
    )
    prov = {"policy": policy, "fabric": fab.name, "op": op.value}
    if provenance:
        prov.update(provenance)
    return ServePlan(
        arch=cfg.name,
        op=op.value,
        axis=axis,
        axis_sizes=dict(axis_sizes),
        fabric=fab.name,
        costs=tuple(costs),
        model=model,
        hw=hw,
        schedule=schedule,
        provenance=prov,
    )


def make_group_collective(plan: ServePlan, axis: str | None = None):
    """Executable serve wire: ``fn(stacked) -> list`` issuing exactly ONE
    collective per scheduled group.

    ``stacked`` is a per-stage payload array with the scan axis leading
    (``(n_stages, ...)`` — e.g. the fresh KV rows of every stage).  Each
    group's stage slice is flattened into one buffer and shipped with the
    plan's collective over ``axis`` — the decode analogue of the training
    sync's one-all-reduce-per-group guarantee.  All-to-all buffers are
    padded up to a multiple of the axis size (padding is a local reshape,
    never an extra collective).
    """
    import jax
    import jax.numpy as jnp

    from ..compat import axis_size

    ax = axis or plan.axis
    op = Collective(plan.op)
    groups = plan.schedule.groups

    def run(stacked):
        if stacked.shape[0] != plan.num_stages:
            raise ValueError(
                f"payload has {stacked.shape[0]} stages, plan has {plan.num_stages}"
            )
        outs = []
        for gi, (lo, hi) in enumerate(groups):
            flat = stacked[lo - 1 : hi].reshape(-1)
            with jax.named_scope(f"serve_group{gi}_s{lo}_{hi}"):
                if op is Collective.ALL_TO_ALL:
                    n = axis_size(ax)
                    pad = (-flat.shape[0]) % n
                    if pad:
                        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
                    outs.append(issue(op, flat.reshape(n, -1), ax))
                else:
                    outs.append(issue(op, flat, ax))
        return outs

    return run


def rebuild_serve_plan(
    plan: ServePlan,
    model: AllReduceModel,
    *,
    policy: str | None = None,
    trigger: str = "degraded_fabric",
) -> ServePlan:
    """Re-plan an existing ``ServePlan`` at a new (α, β) — the
    degraded-fabric replan.

    The cost vector, hardware model, and policy are reused unchanged;
    only the collective model is swapped and the merge schedule re-solved
    — MG-WFBP's merge decision is a function of (α, β), so when the wire
    slows down (a flaky link, congestion, a failed NIC renegotiating
    speed) the *merge set itself* must be allowed to change, not just the
    predicted times (pinned by ``tests/test_resilience.py``).  The
    measured ``t_step_fixed`` carries over — degradation is modeled on
    the wire, the compute+dispatch term is untouched.  Provenance records
    the trigger and the model it replaced so a ``--plan-out`` artifact
    shows the replan happened.

    ``serving.resilience.resilient_serve_loop`` calls this when its
    ``StragglerMonitor`` flags sustained step-time degradation, with
    ``model`` coming from ``refit_serve_fit`` (live probes through
    ``serve_collective_time_fn`` on a real mesh, or the chaos-wrapped
    analytic pricing in tests)."""
    pol = resolve_policy_name(policy or plan.policy)
    schedule = build_schedule(pol, list(plan.costs), model, hw=plan.hw, t_f=0.0)
    prov = dict(plan.provenance)
    prov.update({
        "policy": pol,
        "refit": trigger,
        "replaced_model": plan.model.name or "",
    })
    return dataclasses.replace(
        plan, model=model, schedule=schedule, provenance=prov
    )


def refit_serve_fit(
    time_fn,
    probe_sizes: tuple[int, ...] | None = None,
    name: str = "serve_refit",
) -> AllReduceModel:
    """Slim serve-side (α, β) re-fit — the ``CommRefitter`` pattern
    applied through the serve wire.

    ``time_fn(nbytes) -> seconds`` prices one collective at one message
    size; a few probe sizes (``SLIM_COMM_SWEEP`` by default: one small
    for α, one large for β) are timed and least-squares fitted.  Pass
    ``serve_collective_time_fn(mesh, op)`` for live measurements, or any
    injectable stand-in (``ChaosInjector.wrap_time_fn`` in tests) — the
    same seam ``planning.tuner.CommRefitter`` uses on the train side."""
    from ..core.comm_model import fit_affine

    from .costs import SLIM_COMM_SWEEP

    sizes = tuple(int(s) for s in (probe_sizes or SLIM_COMM_SWEEP))
    return fit_affine(
        sizes, tuple(float(time_fn(s)) for s in sizes), name=name
    )


def serve_collective_time_fn(mesh, op: Collective | str, axis: str = "model",
                             repeats: int = 3):
    """``time_fn(nbytes) -> seconds`` pricing one real serve collective on
    ``mesh`` — the production probe behind ``refit_serve_fit`` (the
    serve-side ``psum_time_fn``)."""
    op = Collective(op)

    def fn(nbytes: int) -> float:
        return measure_serve_comm(
            mesh, op, (axis,), sizes_bytes=(int(nbytes),), repeats=repeats
        ).times_s[0]

    return fn


# ---------------------------------------------------------------------------
# Measured serve fabrics: time the real decode collectives
# ---------------------------------------------------------------------------


def measure_serve_comm(
    mesh,
    op: Collective | str = Collective.ALL_GATHER,
    axes: tuple[str, ...] = ("model",),
    sizes_bytes: tuple[int, ...] | None = None,
    dtype=None,
    repeats: int = 3,
    name: str | None = None,
):
    """Time real serve collectives over a size sweep on ``mesh``'s axis.

    The serve-side analogue of ``MeasuredComm.time_psums``: one jitted
    ``shard_map`` collective per size (compile call discarded, min of
    ``repeats`` kept).  ``sizes_bytes`` are the *message* bytes ``M`` the
    ``ServePlan`` timeline prices — for ``all_gather`` the gathered
    result (each rank contributes ``M/N``), for ``all_to_all`` the full
    local volume — so the returned ``MeasuredComm``'s ``fit()`` is an
    (α, β) model directly comparable to ``fabric.cost(op, axis_sizes)``.
    """
    import jax
    import jax.numpy as jnp

    from ..compat import shard_map
    from .costs import DEFAULT_COMM_SWEEP, MeasuredComm, time_collective_call

    if len(axes) != 1:
        raise ValueError(f"serve collectives run over one axis, got {axes}")
    op = Collective(op)
    sizes_bytes = DEFAULT_COMM_SWEEP if sizes_bytes is None else tuple(sizes_bytes)
    dtype = jnp.float32 if dtype is None else dtype
    P = jax.sharding.PartitionSpec
    axis = axes[0]
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    itemsize = np.dtype(dtype).itemsize
    replicated_out = op in (Collective.ALL_REDUCE, Collective.ALL_GATHER)
    times = []
    for nb in sizes_bytes:
        if op is Collective.ALL_GATHER:
            x = jnp.ones((max(1, int(nb) // (itemsize * n)),), dtype)
        else:
            elems = max(n, int(nb) // itemsize)
            elems -= elems % n
            x = jnp.ones((n, elems // n), dtype) if op is Collective.ALL_TO_ALL \
                else jnp.ones((elems,), dtype)

        def body(v):
            return issue(op, v, axis)

        f = jax.jit(
            shard_map(
                body, mesh=mesh, in_specs=(P(),),
                out_specs=P() if replicated_out else P(axis),
                axis_names={axis}, check_vma=False,
            )
        )
        times.append(time_collective_call(f, x, repeats))
    return MeasuredComm(
        sizes_bytes=tuple(int(s) for s in sizes_bytes),
        times_s=tuple(times),
        axes=tuple(axes),
        name=name or f"{op.value}@{'+'.join(axes)}",
    )


def serve_fabric_fits(
    mesh,
    ops: tuple[Collective | str, ...] = (Collective.ALL_GATHER,),
    axes: tuple[str, ...] = ("model",),
    **kwargs: Any,
) -> dict[str, AllReduceModel]:
    """Op-specific measured fits keyed for ``fabric.MeasuredFabric``.

    Times each op's sweep on ``mesh`` and returns
    ``{'all_gather@model': AllReduceModel, ...}`` — drop the dict into
    ``MeasuredFabric(models=...)`` (or ``.with_fits``) and the registry
    prices serve plans from live decode-collective measurements, the
    serve-side analogue of the ``CommRefitter`` loop::

        fits = serve_fabric_fits(mesh, ops=("all_gather",))
        fab = MeasuredFabric(models=fits, name="measured_serve")
        plan = build_serve_plan(cfg, shapes, fab, {"model": 8}, batch_rows=4)
    """
    key = "+".join(sorted(axes))
    return {
        f"{Collective(op).value}@{key}": measure_serve_comm(
            mesh, op, axes, **kwargs
        ).fit()
        for op in ops
    }


def group_comparison_lines(
    plan: ServePlan, measured_s: tuple[float, ...]
) -> list[str]:
    """Render ``group[lo..hi] wire=..B pred=..us meas=..us`` rows pairing
    ``group_summaries()`` with ``time_serve_groups`` output — the one
    predicted-vs-measured table ``launch/serve.py --measure-comm`` and
    ``examples/serve_decode.py`` both print.  A calibrated plan
    (``t_step_fixed > 0``) leads with the fixed-vs-wire step
    decomposition so the per-group wire rows read against the honest
    whole-step prediction."""
    lines = []
    if plan.t_step_fixed > 0 and plan.schedule.result is not None:
        wire = plan.schedule.result.t_iter
        lines.append(
            f"step: fixed={plan.t_step_fixed * 1e6:8.1f}us "
            f"wire={wire * 1e6:8.1f}us "
            f"pred_total={(plan.t_step_fixed + wire) * 1e6:8.1f}us"
        )
    for g, t_meas in zip(plan.group_summaries(), measured_s):
        lo, hi = g["stages"]
        lines.append(
            f"group[{lo}..{hi}] wire={g['nbytes']}B "
            f"pred={g['t_pred_s'] * 1e6:8.1f}us "
            f"meas={t_meas * 1e6:8.1f}us"
        )
    return lines


def time_serve_groups(
    plan: ServePlan, mesh, *, axis: str | None = None, repeats: int = 3, dtype=None
) -> tuple[float, ...]:
    """Measured seconds per scheduled serve group: one real collective of
    the plan's op at each group's exact wire payload, in schedule order —
    what ``ServeTimer.group_times`` holds and the ``serve_exec``
    benchmark compares against ``group_summaries()``'s predictions."""
    if plan.schedule.result is None:
        raise ValueError("plan has no evaluated timeline to read group bytes from")
    sizes = tuple(max(1, tr.nbytes) for tr in plan.schedule.result.groups)
    mc = measure_serve_comm(
        mesh, plan.op, (axis or plan.axis,), sizes_bytes=sizes,
        repeats=repeats, dtype=dtype, name="serve_groups",
    )
    return mc.times_s
