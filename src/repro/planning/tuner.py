"""Closed-loop auto-tuner: registry-wide plan search over measured costs.

MG-WFBP's optimality claim (arXiv:1811.11141 §IV, journal arXiv:1912.09268)
rests on feeding the merge solver *measured* per-layer backward times and a
*measured* (α, β) comm model, re-derived as conditions change.  The repo has
long had the parts — ``MeasuredCosts``, ``MeasuredComm``, the policy
registry, ``replan_if_drifted`` — but until this module the live train loop
only ever reran ONE policy on a uniformly rescaled cost vector.  The
``Tuner`` closes the loop:

  * ``Tuner.sweep`` runs EVERY registered policy against the current cost
    vector and (α, β) model, scores each candidate by its predicted
    ``t_iter`` (tie-broken toward fewer groups, then policy name — fully
    deterministic), optionally scores arena wire bytes per candidate from
    ``bucketing.group_arenas``, and returns the argmin ``Plan`` with a
    provenance record naming the policy, the cost/comm sources, and the
    predicted ``t_iter``;
  * ``Tuner.observe`` writes the measured iteration time back into the
    latest sweep record, so every plan carries predicted-vs-observed;
  * ``CommRefitter`` is the amortized comm-side drift monitor: a few timed
    psums per check (``SLIM_COMM_SWEEP``), exponentially weighted into the
    stored sweep (``MeasuredComm.update``), refit via
    ``core.comm_model.fit_affine``, re-plan when ``comm_drift`` crosses the
    threshold — the wire-side analogue of ``replan_if_drifted``;
  * tuner state (sweep history + comm observations) serializes to JSON and
    rides beside every checkpoint (``checkpoint.save(..., tuner=...)``), so
    a restart resumes the online loop instead of restarting it cold.

The launcher wires this in behind ``launch/train.py --autotune`` (per-unit
probes from ``runtime/timeline.py`` feed ``MeasuredCosts.from_segment_times``)
and ``--comm-refit-every``; ``benchmarks/run.py`` runs the same sweep as the
load-bearing search for its planning tables.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from ..core.bucketing import ParamLayout, group_arenas, layer_buckets_for_scan
from ..core.comm_model import AllReduceModel
from ..core.cost_model import Hardware, LayerCost
from .costs import (
    SLIM_COMM_SWEEP,
    MeasuredComm,
    comm_drift,
    replan_if_comm_drifted,
)
from .plan import Plan, build_plan
from .registry import available_policies, resolve_policy_name

TUNER_FORMAT = 1

#: Exhaustive 2^(L-1) enumeration is only admissible for small unit counts.
MAX_EXHAUSTIVE_LAYERS = 14


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One scored (policy, plan) cell of a tuner sweep."""

    policy: str
    n_groups: int
    predicted_t_iter: float
    t_comm_exposed: float
    arena_bytes: int | None = None  # total wire-buffer bytes (when scored)

    def to_json_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SweepRecord:
    """Provenance of one registry-wide sweep (predicted vs observed)."""

    trigger: str  # 'startup' | 'restart' | 'cost_drift' | 'comm_drift' | 'sweep'
    chosen: str
    predicted_t_iter: float
    cost_source: str
    comm_source: str
    candidates: list[Candidate]
    observed_t_iter: float | None = None

    def to_json_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["candidates"] = [c.to_json_dict() for c in self.candidates]
        return d

    @classmethod
    def from_json_dict(cls, d: dict[str, Any]) -> "SweepRecord":
        d = dict(d)
        d["candidates"] = [Candidate(**c) for c in d["candidates"]]
        return cls(**d)


def default_policies(num_layers: int) -> tuple[str, ...]:
    """Every registered policy the sweep can afford, sorted (deterministic).

    ``optimal`` (exhaustive 2^(L-1)) is only included when the layer count
    makes it cheap; it then serves as the in-sweep ground truth.
    """
    names = set(available_policies())
    if num_layers > MAX_EXHAUSTIVE_LAYERS:
        names.discard("optimal")
    return tuple(sorted(names))


@dataclasses.dataclass
class Tuner:
    """Registry-wide argmin-``t_iter`` plan search over one layout.

    Attributes:
      layout:        communication units the plans are built over.
      n_scan_stages: scan segmentation input (None for flat layouts).
      policies:      policy names to sweep (default: every registered
                     policy, minus ``optimal`` for large L), sorted.
      policy_opts:   per-policy extra options (e.g. ``{'fixed':
                     {'bucket_bytes': ...}}``).
      shapes:        parameter (shape) pytree for arena-byte scoring via
                     ``bucketing.group_arenas`` (None skips that column).
      wire_dtype:    dtype name the arena bytes are scored at.
      history:       one ``SweepRecord`` per sweep, newest last.
    """

    layout: ParamLayout
    n_scan_stages: int | None = None
    policies: tuple[str, ...] | None = None
    policy_opts: dict[str, dict[str, Any]] = dataclasses.field(default_factory=dict)
    shapes: Any = None
    wire_dtype: str = "float32"
    provenance: dict[str, str] = dataclasses.field(default_factory=dict)
    history: list[SweepRecord] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if self.policies is None:
            self.policies = default_policies(self.layout.num_layers)
        else:
            self.policies = tuple(
                sorted(resolve_policy_name(p) for p in self.policies)
            )

    def sweep(
        self,
        costs: list[LayerCost],
        ar_model: AllReduceModel,
        hw: Hardware,
        *,
        cost_source: str = "analytic",
        comm_source: str = "analytic",
        trigger: str = "sweep",
        mode: str = "overlap",
    ) -> Plan:
        """Run every policy, return the argmin predicted-``t_iter`` Plan.

        Candidate order and the argmin are deterministic: policies are
        swept in sorted-name order and ties break by (t_iter, n_groups,
        policy name).  The chosen plan's provenance records the trigger,
        the predicted t_iter, and how many candidates it beat; the full
        per-candidate table lands in ``self.history``.

        ``mode`` prices every candidate under an issue-order model
        (``core.timeline.MODES``): ``overlap`` (DAG step, comm hides
        behind backward — the default) or ``serialized`` (post-backward
        step).  Non-default modes ride each candidate's ``policy_opts``
        so the plan artifact records what it was optimized for.
        """
        candidates: list[tuple[tuple, Candidate, Plan]] = []
        for policy in self.policies:
            opts = dict(self.policy_opts.get(policy) or {})
            if mode != "overlap":
                opts["mode"] = mode
            plan = build_plan(
                self.layout,
                list(costs),
                ar_model,
                policy=policy,
                hw=hw,
                n_scan_stages=self.n_scan_stages,
                cost_source=cost_source,
                policy_opts=opts or None,
                provenance=dict(self.provenance),
            )
            r = plan.schedule.result
            arena_bytes = None
            if self.shapes is not None:
                arena_bytes = sum(
                    a.nbytes
                    for a in group_arenas(
                        self.layout, plan.schedule, self.shapes, self.wire_dtype
                    )
                )
            cand = Candidate(
                policy=policy,
                n_groups=len(plan.schedule.groups),
                predicted_t_iter=r.t_iter,
                t_comm_exposed=r.t_comm_exposed,
                arena_bytes=arena_bytes,
            )
            candidates.append(((r.t_iter, len(plan.schedule.groups), policy), cand, plan))

        candidates.sort(key=lambda t: t[0])
        _, best, best_plan = candidates[0]
        record = SweepRecord(
            trigger=trigger,
            chosen=best.policy,
            predicted_t_iter=best.predicted_t_iter,
            cost_source=cost_source,
            comm_source=comm_source,
            candidates=[c for _, c, _ in candidates],
        )
        self.history.append(record)
        prov = dict(best_plan.provenance)
        prov.update(
            {
                "tuner": trigger,
                "comm_source": comm_source,
                "predicted_t_iter": f"{best.predicted_t_iter:.6e}",
                "candidates": str(len(candidates)),
            }
        )
        return dataclasses.replace(best_plan, provenance=prov)

    def sweep_fabric(
        self,
        costs: list[LayerCost],
        fabric: Any,
        axis_sizes: dict[str, int],
        hw: Hardware,
        *,
        op: str = "all_reduce",
        cost_source: str = "analytic",
        trigger: str = "sweep",
    ) -> Plan:
        """``sweep`` with the (α, β) model priced by a registry fabric.

        ``fabric`` is a preset name or live ``Fabric`` instance
        (``fabric.get_fabric``); the fabric's name lands in the record's
        ``comm_source`` so sweeps across backends stay attributable.
        """
        from ..fabric import get_fabric

        fab = get_fabric(fabric)
        return self.sweep(
            costs,
            fab.cost(op, axis_sizes),
            hw,
            cost_source=cost_source,
            comm_source=fab.name,
            trigger=trigger,
        )

    def observe(self, observed_t_iter: float) -> SweepRecord:
        """Record the measured iteration time against the latest sweep —
        the predicted-vs-observed pair every provenance story needs."""
        if not self.history:
            raise ValueError("observe() before any sweep()")
        self.history[-1].observed_t_iter = float(observed_t_iter)
        return self.history[-1]

    @property
    def last_record(self) -> SweepRecord | None:
        return self.history[-1] if self.history else None

    # -- serialization (rides beside checkpoints) ---------------------------

    def state_dict(self) -> dict[str, Any]:
        """JSON-serializable tuner state: sweep history + settings.  The
        layout/shapes are NOT serialized (the plan artifact already carries
        the layout); restoring state onto a freshly built Tuner resumes
        the predicted-vs-observed history across restarts."""
        return {
            "format": TUNER_FORMAT,
            "policies": list(self.policies),
            "policy_opts": {k: dict(v) for k, v in self.policy_opts.items()},
            "wire_dtype": self.wire_dtype,
            "history": [r.to_json_dict() for r in self.history],
        }

    def load_state(self, d: dict[str, Any]) -> "Tuner":
        """Restore serialized state in place (returns self)."""
        if d.get("format") != TUNER_FORMAT:
            raise ValueError(f"unsupported tuner state format {d.get('format')!r}")
        self.policies = tuple(d["policies"])
        self.policy_opts = {k: dict(v) for k, v in d.get("policy_opts", {}).items()}
        self.wire_dtype = d.get("wire_dtype", "float32")
        self.history = [SweepRecord.from_json_dict(r) for r in d["history"]]
        return self


@dataclasses.dataclass
class CommRefitter:
    """Amortized online (α, β) drift monitor (journal Fig. 5(b), live).

    Holds the full startup ``MeasuredComm`` sweep; each ``check`` times
    only ``probe_sizes`` (a few psums), exponentially weights them into
    the stored observations, refits, and reports the drift of the fresh
    fit against the model the current plan was built with.

    ``time_fn(nbytes) -> seconds`` is injectable so tests (and the
    benchmark's congestion-injection cell) can model an α×10 event
    without real network noise; production passes
    ``psum_time_fn(mesh, axes)``.
    """

    base: MeasuredComm
    threshold: float = 0.25
    weight: float = 0.5
    probe_sizes: tuple[int, ...] = SLIM_COMM_SWEEP
    checks: int = 0
    refits: int = 0

    def __post_init__(self) -> None:
        self._reference = self.base.fit()

    @property
    def reference(self) -> AllReduceModel:
        """The fit the current plan is assumed to be built with."""
        return self._reference

    def check(self, time_fn: Callable[[int], float]) -> tuple[AllReduceModel, float, bool]:
        """One drift check: slim re-probe -> EWMA -> refit -> compare.

        Returns ``(fresh_fit, drift, drifted)``.  On ``drifted`` the fresh
        fit becomes the new reference — the caller is expected to re-plan
        (``replan_if_comm_drifted`` / ``Tuner.sweep``) with it.
        """
        self.checks += 1
        times = [float(time_fn(int(s))) for s in self.probe_sizes]
        self.base = self.base.update(self.probe_sizes, times, weight=self.weight)
        fit = self.base.fit()
        drift = comm_drift(self._reference, fit)
        drifted = drift > self.threshold
        if drifted:
            self.refits += 1
            self._reference = fit
        return fit, drift, drifted

    def replan(self, plan: Plan, fit: AllReduceModel, policy: str | None = None):
        """Convenience pass-through to ``replan_if_comm_drifted`` with this
        monitor's threshold (kept here so callers hold one knob)."""
        return replan_if_comm_drifted(plan, fit, threshold=self.threshold, policy=policy)

    # -- serialization ------------------------------------------------------

    def state_dict(self) -> dict[str, Any]:
        return {
            "sizes_bytes": list(self.base.sizes_bytes),
            "times_s": list(self.base.times_s),
            "axes": list(self.base.axes),
            "name": self.base.name,
            "threshold": self.threshold,
            "weight": self.weight,
            "probe_sizes": list(self.probe_sizes),
            "checks": self.checks,
            "refits": self.refits,
            "reference": {"a": self._reference.a, "b": self._reference.b,
                          "name": self._reference.name},
        }

    @classmethod
    def from_state_dict(cls, d: dict[str, Any]) -> "CommRefitter":
        out = cls(
            base=MeasuredComm(
                sizes_bytes=tuple(d["sizes_bytes"]),
                times_s=tuple(d["times_s"]),
                axes=tuple(d["axes"]),
                name=d.get("name", "measured_comm"),
            ),
            threshold=d["threshold"],
            weight=d["weight"],
            probe_sizes=tuple(d["probe_sizes"]),
            checks=d.get("checks", 0),
            refits=d.get("refits", 0),
        )
        ref = d.get("reference")
        if ref is not None:
            out._reference = AllReduceModel(a=ref["a"], b=ref["b"], name=ref["name"])
        return out


def psum_time_fn(mesh, axes: tuple[str, ...] = ("data",), dtype=None,
                 repeats: int = 2) -> Callable[[int], float]:
    """A ``time_fn`` for ``CommRefitter.check`` that times one real psum
    per call on ``mesh`` (min of ``repeats``, compile discarded).

    The jitted psum closure is built ONCE per probe size and reused for
    the lifetime of the returned callable — the periodic drift checks
    must stay compile-free, or the probe would cost more than the thing
    it measures.
    """
    import time as _time

    import jax
    import jax.numpy as jnp
    import numpy as _np

    from ..compat import shard_map

    dt = jnp.float32 if dtype is None else dtype
    axis_arg = axes if len(axes) > 1 else axes[0]
    P = jax.sharding.PartitionSpec
    compiled: dict[int, Any] = {}

    def get_fn(n: int):
        if n not in compiled:
            def body(v):
                return jax.lax.psum(v, axis_arg)

            compiled[n] = jax.jit(
                shard_map(
                    body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                    axis_names=set(axes), check_vma=False,
                )
            )
        return compiled[n]

    def time_one(nbytes: int) -> float:
        n = max(1, int(nbytes) // _np.dtype(dt).itemsize)
        f = get_fn(n)
        x = jnp.ones((n,), dt)
        jax.block_until_ready(f(x))  # compile on first use, warm after
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = _time.perf_counter()
            jax.block_until_ready(f(x))
            best = min(best, _time.perf_counter() - t0)
        return best

    return time_one
