"""Unified planning subsystem.

Lifecycle (see README):

    cost source  ──►  policy  ──►  Plan  ──►  sync buckets + scan segments
         ▲                          │
         └── measured profile ◄── replan_if_drifted (online re-planning)

  * ``registry``  — scheduler policies (`register_policy` / `get_policy`):
    ``wfbp``, ``synceasgd``, ``fixed``, ``mg_wfbp``, ``dp_optimal``,
    ``optimal`` + future ones, one extensible interface.
  * ``plan``      — the frozen, JSON-serializable ``Plan`` artifact.
  * ``serve``     — the lifecycle extended to decode: ``ServePlan``
    (KV all-gathers / expert all-to-alls merged by the same policies,
    priced by a ``repro.fabric`` preset) + ``make_group_collective``.
  * ``costs``     — ``AnalyticCosts`` (Eq. 18) and ``MeasuredCosts``
    (wall-clock / HLO segments), plus ``replan_if_drifted``; on the comm
    side ``MeasuredComm`` (timed-psum α–β fit, journal §V-A Fig. 5(b)).
"""

from .costs import (
    AnalyticCosts,
    CostSource,
    DEFAULT_COMM_SWEEP,
    MEASURED_HW,
    SLIM_COMM_SWEEP,
    MeasuredComm,
    MeasuredCosts,
    comm_drift,
    cost_drift,
    measure_comm_models,
    replan_if_comm_drifted,
    replan_if_drifted,
)
from .plan import PLAN_FORMAT, Plan, build_plan
from .serve import (
    SERVE_PLAN_FORMAT,
    ServePlan,
    build_serve_plan,
    decode_unit_costs,
    group_comparison_lines,
    make_group_collective,
    measure_serve_comm,
    rebuild_serve_plan,
    refit_serve_fit,
    serve_collective_time_fn,
    serve_fabric_fits,
    time_serve_groups,
)
from .registry import (
    available_policies,
    build_schedule,
    get_policy,
    register_policy,
    resolve_policy_name,
)
from .tuner import (
    Candidate,
    CommRefitter,
    SweepRecord,
    Tuner,
    default_policies,
    psum_time_fn,
)

__all__ = [
    "AnalyticCosts",
    "CostSource",
    "DEFAULT_COMM_SWEEP",
    "MEASURED_HW",
    "SLIM_COMM_SWEEP",
    "MeasuredComm",
    "MeasuredCosts",
    "comm_drift",
    "cost_drift",
    "measure_comm_models",
    "replan_if_comm_drifted",
    "replan_if_drifted",
    "PLAN_FORMAT",
    "Plan",
    "build_plan",
    "SERVE_PLAN_FORMAT",
    "ServePlan",
    "build_serve_plan",
    "decode_unit_costs",
    "group_comparison_lines",
    "make_group_collective",
    "measure_serve_comm",
    "rebuild_serve_plan",
    "refit_serve_fit",
    "serve_collective_time_fn",
    "serve_fabric_fits",
    "time_serve_groups",
    "available_policies",
    "build_schedule",
    "get_policy",
    "register_policy",
    "resolve_policy_name",
    "Candidate",
    "CommRefitter",
    "SweepRecord",
    "Tuner",
    "default_policies",
    "psum_time_fn",
]
