"""Scheduler-policy registry.

Every way of turning a per-layer cost vector into a gradient-merge
``Schedule`` — the paper's Algorithm 1, its baselines, the beyond-paper
exact DP, and whatever future PRs add — registers here under a name.
This replaces the two parallel string dispatches the repo used to have
(``core.trainer.build_schedule``'s if-chain and ``SyncConfig.strategy``):
a policy name is now the *single* selection mechanism end to end, and the
sync engine derives its structure from the schedule alone.

A policy is a callable::

    policy(costs: list[LayerCost], ar_model: AllReduceModel,
           hw: Hardware = TPU_V5E, t_f: float | None = None,
           **opts) -> Schedule

The registry guarantees the returned schedule carries an evaluated
``TimelineResult`` (re-evaluating when the policy did not).

Aliases map the historical ``SyncConfig.strategy`` vocabulary onto
policies: ``per_tensor`` -> ``wfbp``, ``single`` -> ``synceasgd``,
``bucketed`` -> ``mg_wfbp``.
"""

from __future__ import annotations

from typing import Callable, Protocol

from ..core.comm_model import AllReduceModel
from ..core.cost_model import Hardware, LayerCost, TPU_V5E
from ..core.schedule import (
    Schedule,
    dp_optimal_schedule,
    evaluate_schedule,
    fixed_bucket_schedule,
    mg_wfbp_schedule,
    optimal_schedule,
    synceasgd_schedule,
    wfbp_schedule,
)


class PolicyFn(Protocol):
    def __call__(
        self,
        costs: list[LayerCost],
        ar_model: AllReduceModel,
        hw: Hardware = ...,
        t_f: float | None = ...,
        **opts,
    ) -> Schedule: ...


_POLICIES: dict[str, PolicyFn] = {}
_ALIASES: dict[str, str] = {
    # historical SyncConfig.strategy names
    "per_tensor": "wfbp",
    "single": "synceasgd",
    "bucketed": "mg_wfbp",
}


def register_policy(
    name: str, *, aliases: tuple[str, ...] = (), overwrite: bool = False
) -> Callable[[PolicyFn], PolicyFn]:
    """Decorator registering ``fn`` as scheduler policy ``name``."""

    def deco(fn: PolicyFn) -> PolicyFn:
        if not overwrite:
            for key in (name, *aliases):
                if key in _POLICIES or key in _ALIASES:
                    raise ValueError(f"policy name {key!r} already registered")
        _POLICIES[name] = fn
        for a in aliases:
            _ALIASES[a] = name
        return fn

    return deco


def resolve_policy_name(name: str) -> str:
    """Canonical policy name (aliases resolved); raises on unknown."""
    name = _ALIASES.get(name, name)
    if name not in _POLICIES:
        known = ", ".join(sorted(set(_POLICIES) | set(_ALIASES)))
        raise KeyError(f"unknown scheduler policy {name!r}; known: {known}")
    return name


def get_policy(name: str) -> PolicyFn:
    """Look up a registered scheduler policy by (aliased) name.

    Example: ``get_policy("mg_wfbp")(costs, ar_model, hw=TPU_V5E)``."""
    return _POLICIES[resolve_policy_name(name)]


def available_policies() -> tuple[str, ...]:
    """Canonical policy names, sorted."""
    return tuple(sorted(_POLICIES))


def build_schedule(
    policy: str,
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    t_f: float | None = None,
    **opts,
) -> Schedule:
    """Run a registered policy and guarantee an evaluated result.

    ``opts`` are forwarded to the policy; every built-in accepts
    ``mode='overlap'|'serialized'`` (``core.timeline.MODES``) selecting the
    issue-order model the schedule is optimized and priced under.
    """
    schedule = get_policy(policy)(costs, ar_model, hw=hw, t_f=t_f, **opts)
    if schedule.result is None:
        schedule = evaluate_schedule(
            schedule, costs, ar_model, hw, t_f, mode=opts.get("mode", "overlap")
        )
    return schedule


# ---------------------------------------------------------------------------
# Built-in policies (paper Algorithm 1 + baselines + beyond-paper exact DP)
# ---------------------------------------------------------------------------


@register_policy("wfbp", aliases=())
def _wfbp(costs, ar_model, hw=TPU_V5E, t_f=None, *, mode: str = "overlap", **opts) -> Schedule:
    """WFBP [10,12]: one all-reduce per layer (𝕄 = ∅)."""
    return evaluate_schedule(wfbp_schedule(len(costs)), costs, ar_model, hw, t_f, mode=mode)


@register_policy("synceasgd")
def _synceasgd(costs, ar_model, hw=TPU_V5E, t_f=None, *, mode: str = "overlap", **opts) -> Schedule:
    """SyncEASGD [15]: single merged message after backward."""
    return evaluate_schedule(
        synceasgd_schedule(len(costs)), costs, ar_model, hw, t_f, mode=mode
    )


@register_policy("fixed")
def _fixed(costs, ar_model, hw=TPU_V5E, t_f=None, *, bucket_bytes: int = 25 * 2**20, mode: str = "overlap", **opts) -> Schedule:
    """DDP/Horovod-style size-threshold tensor fusion."""
    return evaluate_schedule(
        fixed_bucket_schedule(costs, bucket_bytes), costs, ar_model, hw, t_f, mode=mode
    )


@register_policy("mg_wfbp")
def _mg_wfbp(costs, ar_model, hw=TPU_V5E, t_f=None, *, mode: str = "overlap", **opts) -> Schedule:
    """Paper Algorithm 1 greedy merge (O(L²), run once)."""
    return mg_wfbp_schedule(costs, ar_model, hw, t_f, mode=mode)


@register_policy("dp_optimal")
def _dp_optimal(costs, ar_model, hw=TPU_V5E, t_f=None, *, mode: str = "overlap", **opts) -> Schedule:
    """Beyond-paper exact optimum via the O(L²) Bellman recursion."""
    return dp_optimal_schedule(costs, ar_model, hw, t_f, mode=mode)


@register_policy("optimal")
def _optimal(costs, ar_model, hw=TPU_V5E, t_f=None, *, max_layers: int = 22, mode: str = "overlap", **opts) -> Schedule:
    """Exhaustive 2^(L-1) enumeration — small L only (tests, validation)."""
    return optimal_schedule(costs, ar_model, hw, t_f, max_layers=max_layers, mode=mode)
