"""Serving: continuous batching (``engine``) + plan-driven sharded TP
decode (``sharded``) — the executable side of ``planning.ServePlan``."""

from .engine import Request, ServingEngine
from .sharded import (
    ServeTimer,
    make_sharded_decode_step,
    serving_cache_pspecs,
    serving_param_pspecs,
    shard_serving_state,
    sharded_decode_core,
    sharded_decode_fn,
    stack_fresh_rows,
    write_fresh_rows,
)

__all__ = [
    "Request",
    "ServeTimer",
    "ServingEngine",
    "make_sharded_decode_step",
    "sharded_decode_core",
    "serving_cache_pspecs",
    "serving_param_pspecs",
    "shard_serving_state",
    "sharded_decode_fn",
    "stack_fresh_rows",
    "write_fresh_rows",
]
