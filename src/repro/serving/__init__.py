"""Serving: continuous batching (``engine``) + plan-driven sharded TP
decode (``sharded``) — the executable side of ``planning.ServePlan`` —
plus the resilience layer (``resilience``): snapshot/restore, seeded
chaos injection, the restart serve loop, and degraded-fabric
replanning — and the fleet layer (``fleet``): N health-checked
replicas behind one SLO-aware router with in-flight failover and
plan-priced elastic scaling."""

from .engine import Request, ServingEngine
from .fleet import (
    FleetConfig,
    FleetController,
    FleetReport,
    FleetWatchdog,
    LoadGenerator,
    LoadSpec,
)
from .resilience import (
    ChaosConfig,
    ChaosError,
    ChaosInjector,
    EngineSnapshot,
    ServeLoopDriver,
    ServeReport,
    latest_snapshot,
    load_snapshot,
    resilient_serve_loop,
    restore_latest_snapshot,
    save_snapshot,
    snapshot_engine,
)
from .sharded import (
    ServeTimer,
    make_sharded_decode_step,
    serving_cache_pspecs,
    serving_param_pspecs,
    shard_serving_state,
    sharded_decode_core,
    sharded_decode_fn,
    stack_fresh_rows,
    write_fresh_rows,
)

__all__ = [
    "ChaosConfig",
    "ChaosError",
    "ChaosInjector",
    "EngineSnapshot",
    "FleetConfig",
    "FleetController",
    "FleetReport",
    "FleetWatchdog",
    "LoadGenerator",
    "LoadSpec",
    "Request",
    "ServeLoopDriver",
    "ServeReport",
    "ServeTimer",
    "ServingEngine",
    "latest_snapshot",
    "load_snapshot",
    "resilient_serve_loop",
    "restore_latest_snapshot",
    "save_snapshot",
    "snapshot_engine",
    "make_sharded_decode_step",
    "sharded_decode_core",
    "serving_cache_pspecs",
    "serving_param_pspecs",
    "shard_serving_state",
    "sharded_decode_fn",
    "stack_fresh_rows",
    "write_fresh_rows",
]
