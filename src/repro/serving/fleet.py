"""Fault-tolerant serving fleet: N replicas, one controller.

"Millions of users" (ROADMAP item 2) means N ``ServingEngine`` replicas
behind a router, not one engine in a loop.  This module is the layer
between the single resilient engine (``serving.resilience``) and
production traffic, shaped after the actor/learner/controller split of
distributed RL systems and priced end to end by the plan — the DAG cost
model of Shi et al. (arXiv 1805.03812): a supervisor that routes work by
priced cost, not by hope.  Four pieces:

* **LoadGenerator** — a seeded trace/Poisson arrival schedule.  Same
  seed, same arrivals, same prompts, same deadlines: every fleet run —
  chaos or not — replays exactly.
* **FleetController** — drives one ``ServeLoopDriver`` per replica
  (the cooperative ``tick()`` form of ``resilient_serve_loop``, so the
  fleet and the single engine share one failure semantics), with
  heartbeat health checks, per-replica seeded chaos fault domains
  (``ChaosConfig.for_replica``), and SLO-aware fleet admission: a
  request is routed to the healthy replica with the cheapest plan-priced
  ETA and shed fleet-wide when **no** replica's
  ``ServePlan.predicted_step_time()`` can meet its deadline.
* **in-flight failover** — a replica whose restart budget is spent (or
  whose heartbeat goes stale) is dead: its queued *and* active requests
  drain to healthy peers with provenance (``Request.replica_id`` /
  ``Request.retries``) and their partial output preserved — resume
  admission (``ServingEngine._admit``) re-prefills the prefix, so a
  failed-over request's final tokens are token-identical to its partial
  prefix and goodput is never double-charged.
* **FleetWatchdog** — the ``StragglerMonitor`` idea one level up:
  prices the fleet backlog against ``ServePlan.capacity_tok_per_s`` and
  emits scale-up/down decisions (applied elastically when the
  controller owns an engine factory); per-replica stragglers still
  trigger the degraded-fabric replan inside each driver.

See ``docs/fleet.md`` for the process topology, failover flow, and
admission math; ``benchmarks/run.py serve_fleet`` measures p50/p99
latency and goodput vs offered load, with and without kill chaos.
"""

from __future__ import annotations

import dataclasses
import logging
import pathlib
import time
from typing import Any, Callable

import numpy as np

from ..runtime.fault_tolerance import StragglerMonitor
from .engine import Request, ServingEngine
from .resilience import ChaosConfig, ChaosInjector, ServeLoopDriver, ServeReport

log = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# LoadGenerator: seeded trace/Poisson arrivals
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """One seeded offered-load schedule.

    ``kind='poisson'`` draws exponential inter-arrival gaps at
    ``rate_rps`` requests/second; ``kind='trace'`` replays the explicit
    ``trace_arrivals_s`` offsets (cycled if shorter than
    ``n_requests``).  ``deadline_s`` is each request's SLO *relative to
    its own arrival* (None = no deadline).  Everything — arrival times,
    prompt tokens — is a pure function of ``seed``, so a chaos run and
    its fault-free baseline see byte-identical traffic."""

    n_requests: int = 16
    prompt_len: int = 8
    max_new_tokens: int = 8
    kind: str = "poisson"
    rate_rps: float = 200.0
    trace_arrivals_s: tuple[float, ...] = ()
    deadline_s: float | None = None
    seed: int = 0
    vocab: int = 256


class LoadGenerator:
    """Materialized ``LoadSpec``: deterministic (arrival offset, Request)
    pairs, popped in arrival order by ``due(now)``.

    Offsets are relative to the fleet loop's start; the controller adds
    its clock origin and stamps each request's absolute ``deadline_s``
    at admission."""

    def __init__(self, spec: LoadSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        n = spec.n_requests
        if spec.kind == "trace":
            if not spec.trace_arrivals_s:
                raise ValueError("trace load needs trace_arrivals_s")
            tr = list(spec.trace_arrivals_s)
            span = tr[-1] - tr[0]
            period = max(span + span / max(1, len(tr) - 1), 1e-9)
            offsets = sorted(
                float(tr[i % len(tr)] + (i // len(tr)) * period)
                for i in range(n)
            )
        elif spec.kind == "poisson":
            gaps = rng.exponential(1.0 / max(spec.rate_rps, 1e-9), size=n)
            offsets = np.cumsum(gaps).tolist()
        else:
            raise ValueError(f"unknown load kind {spec.kind!r}")
        self._queue: list[tuple[float, Request]] = []
        for rid, off in enumerate(offsets):
            prompt = rng.integers(0, spec.vocab, size=spec.prompt_len,
                                  dtype=np.int32)
            self._queue.append((float(off), Request(
                rid=rid, prompt=prompt, max_new_tokens=spec.max_new_tokens,
            )))
        self._next = 0

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def exhausted(self) -> bool:
        return self._next >= len(self._queue)

    @property
    def next_arrival_s(self) -> float | None:
        """Offset of the next not-yet-due arrival (None when drained)."""
        if self.exhausted:
            return None
        return self._queue[self._next][0]

    def due(self, now_s: float) -> list[tuple[float, Request]]:
        """Pop every (arrival offset, request) with offset <= ``now_s``."""
        out = []
        while (not self.exhausted
               and self._queue[self._next][0] <= now_s):
            out.append(self._queue[self._next])
            self._next += 1
        return out


# ---------------------------------------------------------------------------
# FleetWatchdog: plan-priced scale decisions
# ---------------------------------------------------------------------------


class FleetWatchdog:
    """Backlog-vs-capacity monitor emitting priced scale decisions.

    The ``StragglerMonitor`` idea one level up: instead of one engine's
    step times it watches the whole fleet's backlog, priced by the plan
    — capacity per replica is ``ServePlan.capacity_tok_per_s(slots)``,
    so the predicted drain time of ``backlog_tokens`` over ``n_alive``
    replicas is an honest plan-derived quantity, and every decision
    records the before/after drain prediction that justified it.
    ``scale_up`` fires when the drain prediction exceeds
    ``scale_up_backlog_s``; ``scale_down`` after
    ``scale_down_idle_rounds`` consecutive empty-backlog rounds (0
    disables).  ``cooldown_rounds`` rounds must pass between decisions
    so one burst cannot thrash the fleet."""

    def __init__(
        self,
        *,
        scale_up_backlog_s: float = float("inf"),
        scale_down_idle_rounds: int = 0,
        cooldown_rounds: int = 4,
    ):
        self.scale_up_backlog_s = scale_up_backlog_s
        self.scale_down_idle_rounds = scale_down_idle_rounds
        self.cooldown_rounds = cooldown_rounds
        self.idle_rounds = 0
        self._cooldown = 0
        self.decisions: list[dict[str, Any]] = []

    def assess(
        self,
        *,
        round_idx: int,
        backlog_tokens: int,
        n_alive: int,
        plan: Any,
        slots: int,
    ) -> str | None:
        """One fleet heartbeat: returns ``'scale_up'``/``'scale_down'``/
        None and records the plan-priced justification."""
        if self._cooldown > 0:
            self._cooldown -= 1
        cap = plan.capacity_tok_per_s(slots) if plan is not None else None
        if not cap:
            return None
        drain_s = backlog_tokens / (cap * max(1, n_alive))
        self.idle_rounds = self.idle_rounds + 1 if backlog_tokens == 0 else 0
        action = None
        if self._cooldown == 0:
            if drain_s > self.scale_up_backlog_s:
                action = "scale_up"
            elif (
                self.scale_down_idle_rounds > 0
                and self.idle_rounds >= self.scale_down_idle_rounds
                and n_alive > 1
            ):
                action = "scale_down"
        if action is not None:
            delta = 1 if action == "scale_up" else -1
            self.decisions.append({
                "round": int(round_idx),
                "action": action,
                "backlog_tokens": int(backlog_tokens),
                "n_alive": int(n_alive),
                "capacity_tok_per_s_per_replica": float(cap),
                "drain_s_before": float(drain_s),
                "drain_s_after": float(
                    backlog_tokens / (cap * max(1, n_alive + delta))
                ),
            })
            self._cooldown = self.cooldown_rounds
            self.idle_rounds = 0
        return action


# ---------------------------------------------------------------------------
# FleetReport
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetReport:
    """What one ``FleetController.run`` did, fleet-wide.

    ``latencies_s`` maps rid -> arrival-to-completion seconds for every
    finished (non-shed) request; ``p50/p99`` summarize them.
    ``goodput_tokens`` counts tokens of completed requests that were
    neither shed nor expired — and because failover moves the one
    ``Request`` (tokens ride along, completions dedupe by rid), a
    re-routed request is counted exactly once.
    ``failover_token_mismatches`` is the hard invariant: completed
    failed-over requests whose final output does NOT start with the
    partial prefix they had at failover (must be 0, asserted by the
    ``serve-fleet-smoke`` CI job)."""

    completed: dict[int, Request] = dataclasses.field(default_factory=dict)
    latencies_s: dict[int, float] = dataclasses.field(default_factory=dict)
    rounds: int = 0
    offered: int = 0
    shed: int = 0
    expired: int = 0
    failovers: int = 0
    failover_token_mismatches: int = 0
    replica_deaths: int = 0
    restores: int = 0
    replans: int = 0
    snapshots: int = 0
    scale_ups: int = 0
    scale_downs: int = 0
    scale_decisions: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    recovery_times_s: list[float] = dataclasses.field(default_factory=list)
    replicas: list[dict[str, Any]] = dataclasses.field(default_factory=list)
    goodput_tokens: int = 0
    wall_s: float = 0.0

    @property
    def goodput_tok_per_s(self) -> float:
        """Deadline-meeting tokens per wall second over the whole run."""
        return self.goodput_tokens / max(self.wall_s, 1e-9)

    def latency_percentile(self, q: float) -> float:
        """q-th percentile of completed-request latency seconds (0 when
        nothing completed) — ``latency_percentile(50)``/``(99)`` are the
        p50/p99 the benchmark publishes."""
        vals = [t for rid, t in self.latencies_s.items()
                if not self.completed[rid].shed]
        return float(np.percentile(vals, q)) if vals else 0.0

    def summary(self) -> dict[str, Any]:
        """The JSON-ready roll-up one benchmark row / log line carries."""
        done = [r for r in self.completed.values() if not r.shed]
        return {
            "offered": self.offered,
            "completed": len(done),
            "shed": self.shed,
            "expired": self.expired,
            "failovers": self.failovers,
            "failover_token_mismatches": self.failover_token_mismatches,
            "replica_deaths": self.replica_deaths,
            "restores": self.restores,
            "replans": self.replans,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "goodput_tokens": self.goodput_tokens,
            "goodput_tok_per_s": self.goodput_tok_per_s,
            "p50_latency_s": self.latency_percentile(50),
            "p99_latency_s": self.latency_percentile(99),
            "wall_s": self.wall_s,
            "rounds": self.rounds,
        }


# ---------------------------------------------------------------------------
# FleetController
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs of one fleet run.

    ``max_restores`` budgets each replica's *in-place* recoveries
    (snapshot restore inside its ``ServeLoopDriver``); past it the
    replica is dead and its requests fail over.  ``heartbeat_timeout_s``
    declares a replica dead when its last successful tick is older than
    this on the fleet clock (None disables).  ``elastic`` lets watchdog
    decisions actually add/retire replicas (bounded by
    ``max_replicas``/``min_replicas``); otherwise decisions are
    recorded, not applied.  ``idle_sleep_s`` is slept when a round makes
    no progress and no arrival is due — the cooperative loop's polling
    backoff."""

    replicas: int = 4
    snapshot_every: int = 8
    max_restores: int = 1
    backoff_base_s: float = 0.0
    heartbeat_timeout_s: float | None = None
    max_rounds: int = 10_000
    elastic: bool = False
    min_replicas: int = 1
    max_replicas: int = 8
    scale_up_backlog_s: float = float("inf")
    scale_down_idle_rounds: int = 0
    idle_sleep_s: float = 5e-4


@dataclasses.dataclass
class _Replica:
    """Controller-side handle: engine + driver + health bookkeeping."""

    rid: int
    engine: ServingEngine
    driver: ServeLoopDriver
    alive: bool = True
    retired: bool = False  # scale-down, not death
    last_beat_s: float = 0.0
    failed_over: int = 0
    report: ServeReport | None = None


class FleetController:
    """Route, tick, health-check, fail over, and (optionally) scale N
    serving replicas — the supervisor of the fleet.

    ``engine_factory(replica_id)`` builds one ready ``ServingEngine``
    (with its ``ServePlan`` installed); the controller spawns
    ``config.replicas`` up front and more on elastic scale-up.  Each
    replica runs behind its own ``ServeLoopDriver`` — the same guarded
    tick ``resilient_serve_loop`` uses — with its own snapshot directory
    under ``snapshot_root`` and, when ``chaos`` is given, its own
    deterministic fault domain (``chaos.for_replica(rid)``, restricted
    to ``chaos_replicas`` when set).

    Example::

        fleet = FleetController(
            engine_factory=make_engine,
            config=FleetConfig(replicas=4, max_restores=0),
            snapshot_root=tmpdir,
            chaos=ChaosConfig(kill_at=(3,), max_kills=1),
            chaos_replicas=(0,),
        )
        report = fleet.run(LoadGenerator(LoadSpec(n_requests=16)))
        assert report.failover_token_mismatches == 0
    """

    def __init__(
        self,
        *,
        engine_factory: Callable[[int], ServingEngine],
        config: FleetConfig = FleetConfig(),
        snapshot_root: str,
        clock: Callable[[], float] = time.monotonic,
        sleep_fn: Callable[[float], None] = time.sleep,
        chaos: ChaosConfig | None = None,
        chaos_replicas: tuple[int, ...] | None = None,
        straggler_factory: Callable[[], StragglerMonitor] | None = None,
        refit_time_fn: Callable[[int], float] | None = None,
    ):
        self.engine_factory = engine_factory
        self.config = config
        self.snapshot_root = pathlib.Path(snapshot_root)
        self.clock = clock
        self.sleep_fn = sleep_fn
        self.chaos = chaos
        self.chaos_replicas = chaos_replicas
        self.straggler_factory = straggler_factory
        self.refit_time_fn = refit_time_fn
        self.watchdog = FleetWatchdog(
            scale_up_backlog_s=config.scale_up_backlog_s,
            scale_down_idle_rounds=config.scale_down_idle_rounds,
        )
        self.report = FleetReport()
        self.replicas: list[_Replica] = []
        self.pending: list[Request] = []  # unroutable (no healthy replica)
        self._arrival_abs: dict[int, float] = {}
        self._failover_prefix: dict[int, tuple[int, ...]] = {}
        self._t0: float | None = None
        for _ in range(config.replicas):
            self._spawn_replica()

    # -- replica lifecycle --------------------------------------------------

    def _spawn_replica(self) -> _Replica:
        rid = len(self.replicas)
        engine = self.engine_factory(rid)
        injector = None
        if self.chaos is not None and (
            self.chaos_replicas is None or rid in self.chaos_replicas
        ):
            injector = ChaosInjector(self.chaos.for_replica(rid))
        driver = ServeLoopDriver(
            engine,
            snapshot_dir=str(self.snapshot_root / f"replica_{rid}"),
            snapshot_every=self.config.snapshot_every,
            max_restarts=self.config.max_restores,
            backoff_base_s=self.config.backoff_base_s,
            sleep_fn=self.sleep_fn,
            clock=self.clock,
            chaos=injector,
            straggler=(self.straggler_factory()
                       if self.straggler_factory is not None else None),
            refit_time_fn=self.refit_time_fn,
        )
        rep = _Replica(rid=rid, engine=engine, driver=driver,
                       last_beat_s=self.clock())
        self.replicas.append(rep)
        return rep

    def alive_replicas(self) -> list[_Replica]:
        return [r for r in self.replicas if r.alive]

    def _close_replica(self, rep: _Replica) -> None:
        """Final accounting for a replica leaving the fleet (death or
        scale-down): harvest finished requests, freeze its driver
        report."""
        self._drain_completed(rep)
        rep.report = rep.driver.finalize()

    # -- admission / routing ------------------------------------------------

    def _eta_s(self, rep: _Replica, req: Request) -> float:
        """Plan-priced completion ETA of ``req`` on ``rep``: predicted
        queue wait (tokens ahead of it spread over the replica's slots,
        plus the shortest active row when no slot is free) plus its own
        remaining decode steps — all multiples of
        ``ServePlan.predicted_step_time()``."""
        plan = rep.engine.plan
        step = plan.predicted_step_time() if plan is not None else None
        if not step:
            return 0.0  # unpriced engines admit everything
        queued = sum(r.remaining_tokens for r in rep.engine.waiting)
        free = rep.engine.slots - len(rep.engine.active)
        gate = 0
        if free <= 0 and rep.engine.active:
            gate = min(r.remaining_tokens for r in rep.engine.active.values())
        wait_steps = gate + queued / max(1, rep.engine.slots)
        return step * (wait_steps + req.remaining_tokens)

    def route(self, req: Request, now: float) -> bool:
        """SLO-aware fleet admission: place ``req`` on the healthy
        replica with the cheapest plan-priced ETA; shed it fleet-wide
        when even the best replica's ETA misses the deadline (the
        request costs zero decode steps).  Returns False when shed or
        deferred (no healthy replica)."""
        alive = self.alive_replicas()
        if not alive:
            self.pending.append(req)
            return False
        best = min(alive, key=lambda r: self._eta_s(r, req))
        eta = self._eta_s(best, req)
        if req.deadline_s is not None and now + eta > req.deadline_s:
            req.shed = True
            req.done = True
            self._complete(req, now)
            return False
        req.replica_id = best.rid
        best.engine.submit(req)
        return True

    # -- failure handling ---------------------------------------------------

    def _fail_over(self, rep: _Replica, reason: str) -> None:
        """Replica death: drain its queued and in-flight requests and
        re-route them — provenance-tracked (``retries`` bumped, the
        partial prefix recorded so completion can verify token identity),
        partial output preserved via resume admission on the peer."""
        rep.alive = False
        self.report.replica_deaths += 1
        reqs = rep.engine.drain_requests()
        self._close_replica(rep)
        log.warning(
            "fleet: replica %d dead (%s); failing over %d request(s)",
            rep.rid, reason, len(reqs),
        )
        now = self.clock()
        for req in reqs:
            self._failover_prefix.setdefault(req.rid, tuple(req.generated))
            req.retries += 1
            rep.failed_over += 1
            self.report.failovers += 1
            self.route(req, now)

    def health_check(self) -> None:
        """Heartbeat sweep: any live replica whose last successful tick
        is older than ``heartbeat_timeout_s`` on the fleet clock is
        declared dead and failed over — the liveness check that catches
        a hung replica, not just a raising one."""
        timeout = self.config.heartbeat_timeout_s
        if timeout is None:
            return
        now = self.clock()
        for rep in self.alive_replicas():
            if now - rep.last_beat_s > timeout:
                self._fail_over(rep, reason="stale heartbeat")

    # -- completion bookkeeping --------------------------------------------

    def _complete(self, req: Request, now: float) -> None:
        if req.rid in self.report.completed:
            return  # dedupe by rid: goodput is never double-charged
        self.report.completed[req.rid] = req
        arr = self._arrival_abs.get(req.rid)
        if arr is not None:
            self.report.latencies_s[req.rid] = now - arr
        prefix = self._failover_prefix.get(req.rid)
        if prefix is not None and not req.shed:
            if tuple(req.generated[: len(prefix)]) != prefix:
                self.report.failover_token_mismatches += 1
                log.error(
                    "fleet: request %d lost its partial prefix across "
                    "failover", req.rid,
                )

    def _drain_completed(self, rep: _Replica) -> None:
        now = self.clock()
        for req in rep.engine.completed:
            self._complete(req, now)
        rep.engine.completed.clear()

    # -- elastic scaling ----------------------------------------------------

    def _apply_scale(self, action: str) -> None:
        if action == "scale_up":
            if len(self.alive_replicas()) >= self.config.max_replicas:
                return
            self._spawn_replica()
            self.report.scale_ups += 1
            # rebalance queued (never-admitted) requests through the
            # router so the new capacity actually absorbs the backlog;
            # in-flight rows stay put — moving them is failover's job
            moved: list[Request] = []
            for rep in self.alive_replicas():
                moved.extend(rep.engine.waiting)
                rep.engine.waiting.clear()
            now = self.clock()
            for req in moved:
                self.route(req, now)
        elif action == "scale_down":
            if len(self.alive_replicas()) <= self.config.min_replicas:
                return
            idle = [r for r in self.alive_replicas()
                    if not r.engine.active and not r.engine.waiting]
            if not idle:
                return  # never retire a busy replica
            rep = idle[-1]
            rep.alive = False
            rep.retired = True
            self._close_replica(rep)
            self.report.scale_downs += 1

    # -- the fleet loop ------------------------------------------------------

    def backlog_tokens(self) -> int:
        """Tokens still owed across every live replica's queues — what
        the watchdog prices against plan capacity."""
        total = 0
        for rep in self.alive_replicas():
            total += sum(r.remaining_tokens for r in rep.engine.active.values())
            total += sum(r.remaining_tokens for r in rep.engine.waiting)
        return total

    def _tick_replica(self, rep: _Replica) -> bool:
        """One guarded driver tick; a tick that raises past its restore
        budget kills the replica and fails its work over."""
        try:
            progressed = rep.driver.tick()
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            self._fail_over(rep, reason=repr(e))
            return False
        rep.last_beat_s = self.clock()
        return progressed

    def run(self, load: LoadGenerator) -> FleetReport:
        """Serve the whole offered-load schedule to completion (or
        ``max_rounds``): admit due arrivals, tick every live replica one
        step, sweep heartbeats, harvest completions, and let the
        watchdog scale.  Returns the finalized ``FleetReport``."""
        cfg = self.config
        self._t0 = t0 = self.clock()
        rounds = 0
        while rounds < cfg.max_rounds:
            now = self.clock()
            # 1. fleet admission: due arrivals + deferred requests
            for off, req in load.due(now - t0):
                self.report.offered += 1
                self._arrival_abs[req.rid] = t0 + off
                if load.spec.deadline_s is not None:
                    req.deadline_s = t0 + off + load.spec.deadline_s
                self.route(req, now)
            if self.pending and self.alive_replicas():
                retry, self.pending = self.pending, []
                for req in retry:
                    self.route(req, now)
            # 2. one cooperative step per live replica
            progressed = False
            for rep in list(self.alive_replicas()):
                progressed |= self._tick_replica(rep)
            # 3. liveness + harvest + scaling
            self.health_check()
            for rep in list(self.alive_replicas()):
                self._drain_completed(rep)
            action = self.watchdog.assess(
                round_idx=rounds,
                backlog_tokens=self.backlog_tokens(),
                n_alive=len(self.alive_replicas()),
                plan=next(
                    (r.engine.plan for r in self.alive_replicas()
                     if r.engine.plan is not None), None,
                ),
                slots=max(
                    (r.engine.slots for r in self.alive_replicas()), default=1
                ),
            )
            if action is not None and cfg.elastic:
                self._apply_scale(action)
            rounds += 1
            if (load.exhausted and not self.pending
                    and all(r.driver.idle for r in self.alive_replicas())):
                break
            if not progressed and cfg.idle_sleep_s > 0:
                self.sleep_fn(cfg.idle_sleep_s)
        return self._finalize(rounds)

    def _finalize(self, rounds: int) -> FleetReport:
        rep_out = self.report
        rep_out.rounds = rounds
        for rep in self.replicas:
            if rep.alive:
                self._close_replica(rep)
                rep.alive = False
                rep.retired = True
        for rep in self.replicas:
            r = rep.report
            if r is None:
                continue
            # successful in-place restores only: r.restarts counts
            # attempts, including the final budget-exhausted one that
            # killed the replica
            rep_out.restores += len(r.recovery_times_s)
            rep_out.replans += r.replans
            rep_out.snapshots += r.snapshots
            rep_out.recovery_times_s.extend(r.recovery_times_s)
            rep_out.replicas.append({
                "rid": rep.rid,
                "retired": rep.retired,
                "steps": r.steps,
                "restarts": r.restarts,
                "replans": r.replans,
                "failed_over": rep.failed_over,
            })
        rep_out.scale_decisions = list(self.watchdog.decisions)
        done = [r for r in rep_out.completed.values()]
        rep_out.shed = sum(1 for r in done if r.shed)
        rep_out.expired = sum(1 for r in done if r.expired)
        rep_out.goodput_tokens = sum(
            len(r.generated) for r in done if not r.shed and not r.expired
        )
        rep_out.wall_s = self.clock() - (self._t0 or 0.0)
        return rep_out
