"""Batch-slot serving engine: continuous batching over the decode step.

The engine owns a fixed batch of decode slots.  Requests join free slots
as they arrive (prefill runs per-join at the request's length, then its
KV rows are spliced into the slot), every occupied slot decodes one token
per engine step, and finished rows free their slots immediately — no
head-of-line blocking on long generations.

Positions are tracked *per row*: the decode step's scalar ``pos`` is the
engine's global clock, and each layer's ring-buffer cache masks by
absolute stored positions (models/layers.py), so rows at different
progress coexist in one batch.  For simplicity rows joining mid-flight
re-prefill into a fresh slot-batch of size 1 and are copied in; a paged
KV allocator is the production refinement and slots behind this API.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.steps import make_decode_step, make_prefill_step
from ..models.common import ArchConfig
from ..models.transformer import init_caches

if TYPE_CHECKING:
    from ..planning.serve import ServePlan
    from .sharded import ServeTimer


@dataclasses.dataclass
class Request:
    """One generation request: a prompt, a token budget, and the tokens
    decoded so far.  ``submit`` it to a ``ServingEngine``; the engine
    appends to ``generated`` every step and sets ``done`` when the budget
    (or the engine's ``max_seq``) is reached."""

    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Synchronous-step continuous batching over fixed decode slots.

    ``plan`` is the frozen decode-side ``planning.ServePlan`` the engine
    runs under; its evaluated timeline is the engine's predicted per-step
    cost (``predicted_step_time``).  With ``mesh=`` the engine *executes*
    the plan: the decode step runs under ``shard_map`` over ``tp_axis``
    and issues exactly one fused collective per scheduled serve group
    (``serving.sharded`` — KV all-gathers for dense archs, expert
    all-to-alls for MoE), token-for-token identical to the unsharded
    path.  A ``ServeTimer`` passed as ``timer=`` records per-step wall
    clock, closing the predicted-vs-observed loop
    (``observed_step_time``).

    Token models feed prompts directly; ``input_mode == 'embeds'`` archs
    (audio/VLM stub frontends) route token ids through the model's
    embedding table — the same one-engine code path either way.

    Example::

        plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 8}, batch_rows=4)
        eng = ServingEngine(cfg, params, slots=4, plan=plan, mesh=mesh)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=16))
        done = eng.run_to_completion()
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        slots: int = 4,
        max_seq: int = 512,
        sample: Callable[[jax.Array], jax.Array] | None = None,
        plan: "ServePlan | None" = None,
        mesh=None,
        tp_axis: str = "model",
        timer: "ServeTimer | None" = None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.plan = plan
        self.mesh = mesh
        self.tp_axis = tp_axis
        self.timer = timer
        self.sample = sample or (lambda logits: jnp.argmax(logits, axis=-1))
        self._prefill = jax.jit(make_prefill_step(cfg, None, max_seq=max_seq))
        if mesh is not None:
            if plan is None:
                raise ValueError("sharded serving (mesh=) requires a ServePlan")
            from .sharded import sharded_decode_fn

            self._decode = sharded_decode_fn(cfg, plan, mesh, tp_axis=tp_axis)
        else:
            self._decode = jax.jit(make_decode_step(cfg, None))
        self.caches = init_caches(cfg, batch=slots, max_seq=max_seq, dtype=jnp.float32)
        self.active: dict[int, Request] = {}  # slot -> request
        self.row_pos = np.zeros((slots,), np.int32)  # per-row next position
        self.next_token = np.zeros((slots,), np.int32)
        self.waiting: list[Request] = []
        self.completed: list[Request] = []

    # -- inputs ------------------------------------------------------------

    def _embed_rows(self, ids: jax.Array) -> jax.Array:
        """Stub frontend for ``input_mode == 'embeds'`` archs: token ids ->
        embedding-table rows (what ``launch/serve.py`` historically did)."""
        return self.params["embed"][ids].astype(jnp.float32)

    def _prefill_input(self, prompt: np.ndarray) -> dict:
        ids = jnp.asarray(prompt[None, :])
        if self.cfg.input_mode == "embeds":
            return {"embeds": self._embed_rows(ids)}
        return {"tokens": ids}

    def _decode_input(self, tokens: jax.Array) -> dict:
        if self.cfg.input_mode == "embeds":
            return {"embeds": self._embed_rows(tokens)}
        return {"tokens": tokens}

    def predicted_step_time(self) -> float | None:
        """Modeled decode-step seconds from the plan's evaluated timeline."""
        if self.plan is None or self.plan.schedule.result is None:
            return None
        return self.plan.schedule.result.t_iter

    def observed_step_time(self) -> float | None:
        """Median measured decode-step seconds from the attached
        ``ServeTimer`` (None without a timer or before any clean sample)
        — the measured counterpart of ``predicted_step_time``."""
        return self.timer.median() if self.timer is not None else None

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.waiting:
            slot = free.pop(0)
            req = self.waiting.pop(0)
            logits, fresh = self._prefill(
                self.params, self._prefill_input(req.prompt)
            )
            # splice the single-row prefill caches into this slot
            self.caches = self._splice(fresh, slot)
            tok = int(np.asarray(self.sample(logits))[0])
            req.generated.append(tok)
            self.active[slot] = req
            self.row_pos[slot] = len(req.prompt)
            self.next_token[slot] = tok

    def _splice(self, fresh, slot: int):
        """Copy a 1-row cache pytree into row ``slot`` of the engine cache."""
        if self.mesh is not None:
            # sharded decode leaves the caches replicated over the mesh;
            # bring the single-device prefill rows (and, before the first
            # decode, the freshly initialized caches) onto the same layout
            # so the eager splice never mixes committed placements.  The
            # whole-tree put runs only while the caches are still off-mesh.
            sh = jax.NamedSharding(self.mesh, jax.sharding.PartitionSpec())
            fresh = jax.tree.map(lambda x: jax.device_put(x, sh), fresh)
            if jax.tree.leaves(self.caches)[0].sharding != sh:
                self.caches = jax.tree.map(lambda x: jax.device_put(x, sh), self.caches)

        def put(c, f):
            if c.ndim >= 2 and c.shape[0] == self.cfg.n_stages:
                # stacked stage caches: (n_stages, B, ...) vs fresh (n_stages, 1, ...)
                if c.ndim >= 3 and c.shape[1] == self.slots:
                    return jax.lax.dynamic_update_slice_in_dim(c, f.astype(c.dtype), slot, axis=1)
            if c.ndim >= 1 and c.shape[0] == self.slots:
                return jax.lax.dynamic_update_slice_in_dim(c, f.astype(c.dtype), slot, axis=0)
            return c  # shared (kpos) leaves — identical across rows at same clock

        return jax.tree.map(put, self.caches, fresh)

    # -- stepping ----------------------------------------------------------

    def step(self) -> int:
        """Admit, decode one token for every active row; returns #active."""
        self._admit()
        if not self.active:
            return 0
        # All rows share one engine clock; rows keep their own logical pos.
        # (The demo keeps rows aligned by admitting at matching lengths; a
        # per-row position vector is the next refinement.)
        pos = int(max(self.row_pos[s] for s in self.active))
        tokens = jnp.asarray(self.next_token[:, None])
        t0 = time.perf_counter() if self.timer is not None else 0.0
        out = self._decode(
            self.params, self.caches, self._decode_input(tokens),
            jnp.asarray(pos, jnp.int32),
        )
        if self.mesh is not None:
            logits, self.caches, _wire = out
        else:
            logits, self.caches = out
        if self.timer is not None:
            jax.block_until_ready((logits, self.caches))
            self.timer.observe(time.perf_counter() - t0)
        sampled = np.asarray(self.sample(logits))
        for slot, req in list(self.active.items()):
            tok = int(sampled[slot])
            req.generated.append(tok)
            self.row_pos[slot] += 1
            self.next_token[slot] = tok
            if len(req.generated) >= req.max_new_tokens or self.row_pos[slot] + 1 >= self.max_seq:
                req.done = True
                self.completed.append(req)
                del self.active[slot]
        return len(self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.active and not self.waiting:
                break
            self.step()
        return self.completed
