"""Batch-slot serving engine: dispatch-free continuous batching.

The engine owns a fixed batch of decode slots and keeps **all** per-step
state — the KV cache arena, per-row positions, next tokens, the
active-slot mask, the per-row token budget, and the sampling key — in a
single fixed-shape device pytree (the ``DecodeState``).  The decode hot
loop is exactly one jitted, buffer-donating call per step
(``jax.jit(step, donate_argnums=...)``): no per-step ``device_put``, no
host-side position bookkeeping feeding the trace, and no retrace when a
sequence joins or leaves — joins and retirements are *data* (masked
device writes), never *shape*.

Admission is bucketed: requests admitted in the same step are spliced
into their slots by one jitted masked-write call selected from a small
set of static batch buckets (powers of two up to ``slots``), so a churny
request stream compiles at most ``log2(slots)+1`` admission executables
ever, and the steady-state decode loop compiles exactly one
(``compile_stats`` exposes the executable counts; the test suite pins
them).  Prefill still runs per-join at the request's prompt length and
its KV rows ride the bucketed splice; a paged KV allocator is the
production refinement and slots behind this API.

Rows at different progress coexist in one batch: the decode step's
scalar ``pos`` is the max active row position (the engine's global
clock), and each layer's ring-buffer cache masks by absolute stored
positions (``models/layers.py``).  For simplicity rows joining
mid-flight re-prefill into a fresh slot-batch of size 1 and are copied
in by the bucketed splice.
"""

from __future__ import annotations

import dataclasses
import inspect
import time
from typing import TYPE_CHECKING, Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.steps import make_decode_step, make_prefill_step
from ..models.common import ArchConfig
from ..models.transformer import init_caches

if TYPE_CHECKING:
    from ..planning.serve import ServePlan
    from .sharded import ServeTimer

Pytree = Any


@dataclasses.dataclass
class Request:
    """One generation request: a prompt, a token budget, and the tokens
    decoded so far.  ``submit`` it to a ``ServingEngine``; the engine
    appends to ``generated`` every step and sets ``done`` when the budget
    (or the engine's ``max_seq``) is reached.

    ``deadline_s`` is an absolute per-request SLO on the serving loop's
    clock (``serving.resilience.resilient_serve_loop``): an *active*
    request past its deadline retires gracefully with the tokens decoded
    so far (``expired=True``, partial ``generated``); a *waiting* request
    whose predicted completion (``ServePlan.predicted_step_time()`` ×
    remaining budget) misses the deadline is never admitted
    (``shed=True``, empty ``generated``) — load shedding at admission
    instead of wasted decode steps.

    ``replica_id``/``retries`` are fleet provenance
    (``serving.fleet.FleetController``): which replica currently owns
    the request and how many times it was failed over.  A request
    submitted with a non-empty ``generated`` list *resumes*: admission
    re-prefills ``prompt + generated[:-1]`` and continues decoding from
    ``generated[-1]``, so a re-routed request keeps every token it
    already produced (its final output is token-identical to its
    partial prefix, and goodput is never double-charged — the tokens
    live on one ``Request``, counted once)."""

    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    deadline_s: float | None = None
    expired: bool = False
    shed: bool = False
    replica_id: int | None = None
    retries: int = 0

    @property
    def remaining_tokens(self) -> int:
        """Decode steps still owed to this request — what fleet admission
        prices against ``ServePlan.predicted_step_time()``."""
        return max(0, self.max_new_tokens - len(self.generated))


def _cache_size(fn) -> int:
    """Number of compiled executables behind a jitted callable (0 before
    the first call) — the compile-count hook the engine tests pin."""
    get = getattr(fn, "_cache_size", None)
    return int(get()) if callable(get) else -1


class ServingEngine:
    """Synchronous-step continuous batching over fixed decode slots.

    ``plan`` is the frozen decode-side ``planning.ServePlan`` the engine
    runs under; ``predicted_step_time`` is the plan's wire timeline plus
    its measured per-step fixed (dispatch+compute) term — see
    ``measure_step_fixed``/``calibrate_plan``.  With ``mesh=`` the
    engine *executes* the plan: the decode step runs under ``shard_map``
    over ``tp_axis`` and issues exactly one fused collective per
    scheduled serve group (``serving.sharded`` — KV all-gathers for
    dense archs, expert all-to-alls for MoE), token-for-token identical
    to the unsharded path.  Either way the whole step — decode,
    sampling, position/budget/mask updates — is ONE jitted call whose
    ``DecodeState`` argument is donated, so the cache arena is updated
    in place and the steady-state loop never retraces.

    ``sample`` may take ``(logits)`` (pure, e.g. the default argmax) or
    ``(logits, key)`` (seeded stochastic sampling; the PRNG key lives in
    the donated state and is split inside the step).  A ``ServeTimer``
    passed as ``timer=`` records per-step wall clock, closing the
    predicted-vs-observed loop (``observed_step_time``); call
    ``warmup()`` before any timing loop so compilation never pollutes
    the samples.

    Token models feed prompts directly; ``input_mode == 'embeds'`` archs
    (audio/VLM stub frontends) route token ids through the model's
    embedding table — the same one-engine code path either way.

    Example::

        plan = build_serve_plan(cfg, param_specs(cfg), "tpu_v5e",
                                {"model": 8}, batch_rows=4)
        eng = ServingEngine(cfg, params, slots=4, plan=plan, mesh=mesh)
        eng.warmup()
        plan = eng.calibrate_plan()     # measured t_step_fixed folded in
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=16))
        done = eng.run_to_completion()
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        slots: int = 4,
        max_seq: int = 512,
        sample: Callable | None = None,
        sample_seed: int = 0,
        plan: "ServePlan | None" = None,
        mesh=None,
        tp_axis: str = "model",
        timer: "ServeTimer | None" = None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.plan = plan
        self.mesh = mesh
        self.tp_axis = tp_axis
        self.timer = timer
        self.sample = sample or (lambda logits: jnp.argmax(logits, axis=-1))
        self._keyed_sample = _takes_key(self.sample)
        self._prefill = jax.jit(make_prefill_step(cfg, None, max_seq=max_seq))
        if mesh is not None:
            if plan is None:
                raise ValueError("sharded serving (mesh=) requires a ServePlan")
            from .sharded import sharded_decode_core

            core = sharded_decode_core(cfg, plan, mesh, tp_axis=tp_axis)
        else:
            base = make_decode_step(cfg, None)

            def core(params, caches, batch, pos):
                logits, caches = base(params, caches, batch, pos)
                return logits, caches, ()

        self._step_fn = jax.jit(self._make_step(core), donate_argnums=(1,))
        self._admit_fns: dict[int, Callable] = {}
        caches = init_caches(cfg, batch=slots, max_seq=max_seq, dtype=jnp.float32)
        self._state: Pytree = {
            "caches": caches,
            "row_pos": jnp.zeros((slots,), jnp.int32),
            "next_token": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "budget": jnp.zeros((slots,), jnp.int32),
            "key": jax.random.PRNGKey(sample_seed),
        }
        if mesh is not None:
            # the step runs mirror-compute over the mesh: all state rides
            # replicated, committed ONCE here — never again per step
            sh = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
            self._state = jax.tree.map(lambda x: jax.device_put(x, sh), self._state)
        self._admit_key = jax.random.PRNGKey(sample_seed + 1)
        self.active: dict[int, Request] = {}  # slot -> request
        self.row_pos = np.zeros((slots,), np.int32)  # host mirror (bookkeeping)
        self.next_token = np.zeros((slots,), np.int32)
        self.waiting: list[Request] = []
        self.completed: list[Request] = []

    # -- the one jitted step ----------------------------------------------

    def _make_step(self, core):
        """Build the whole-step body: decode + sample + masked state
        updates, traced once per (shape, mesh) — the donated hot path."""
        cfg, max_seq = self.cfg, self.max_seq
        sample, keyed = self.sample, self._keyed_sample

        def step_fn(params, state):
            active = state["active"]
            pos = jnp.max(jnp.where(active, state["row_pos"], 0)).astype(jnp.int32)
            tokens = state["next_token"][:, None]
            if cfg.input_mode == "embeds":
                batch = {"embeds": params["embed"][tokens].astype(jnp.float32)}
            else:
                batch = {"tokens": tokens}
            logits, caches, wire = core(params, state["caches"], batch, pos)
            if keyed:
                key, sub = jax.random.split(state["key"])
                sampled = sample(logits, sub)
            else:
                key = state["key"]
                sampled = sample(logits)
            sampled = sampled.astype(jnp.int32)
            row_pos = jnp.where(active, state["row_pos"] + 1, state["row_pos"])
            budget = jnp.where(active, state["budget"] - 1, state["budget"])
            # retirement is a masked device write: a row leaves the batch
            # by flipping its mask bit, never by changing a shape
            still = active & (budget > 0) & (row_pos + 1 < max_seq)
            new_state = {
                "caches": caches,
                "row_pos": row_pos,
                "next_token": jnp.where(active, sampled, state["next_token"]),
                "active": still,
                "budget": budget,
                "key": key,
            }
            return new_state, sampled, wire

        return step_fn

    # -- inputs ------------------------------------------------------------

    def _embed_rows(self, ids: jax.Array) -> jax.Array:
        """Stub frontend for ``input_mode == 'embeds'`` archs: token ids ->
        embedding-table rows (what ``launch/serve.py`` historically did)."""
        return self.params["embed"][ids].astype(jnp.float32)

    def _prefill_input(self, prompt: np.ndarray) -> dict:
        ids = jnp.asarray(prompt[None, :])
        if self.cfg.input_mode == "embeds":
            return {"embeds": self._embed_rows(ids)}
        return {"tokens": ids}

    # -- predicted vs observed --------------------------------------------

    def predicted_step_time(self) -> float | None:
        """Modeled decode-step seconds: the plan's wire timeline plus its
        ``t_step_fixed`` (dispatch+compute) term."""
        return self.plan.predicted_step_time() if self.plan is not None else None

    def observed_step_time(self) -> float | None:
        """Median measured decode-step seconds from the attached
        ``ServeTimer`` (None without a timer or before any clean sample)
        — the measured counterpart of ``predicted_step_time``."""
        return self.timer.median() if self.timer is not None else None

    def warmup(self) -> None:
        """Compile + warm the decode executable on a throwaway state copy
        (all slots marked active) so the first timed step never includes
        compilation.  Run this before any timing loop; the engine's real
        state and submitted requests are untouched."""
        state = _copy_state(self._state)
        state["active"] = jnp.ones_like(state["active"])
        out_state, sampled, _ = self._step_fn(self.params, state)
        jax.block_until_ready((out_state, sampled))

    def probe_step_time(self, repeats: int = 5) -> float:
        """Min-of-``repeats`` wall seconds of the compiled engine step on
        a throwaway state chain (every slot active) — the whole-step
        measurement ``measure_step_fixed`` decomposes.  Compilation is
        warmed first and never timed; samples run through the shared
        outlier-retrying ``planning.costs.min_of_k`` so one GC pause or
        noisy neighbor cannot skew the ``t_step_fixed`` calibration."""
        from ..planning.costs import min_of_k

        state = _copy_state(self._state)
        state["active"] = jnp.ones_like(state["active"])
        state, s, _ = self._step_fn(self.params, state)  # warm
        jax.block_until_ready(s)
        chain = [state]

        def sample() -> float:
            t0 = time.perf_counter()
            new_state, tok, _ = self._step_fn(self.params, chain[0])
            jax.block_until_ready((new_state, tok))
            chain[0] = new_state
            return time.perf_counter() - t0

        return min_of_k(sample, max(1, repeats))

    def measure_step_fixed(self, repeats: int = 5) -> float:
        """The measured per-step *fixed* (dispatch+compute) seconds: the
        probed whole-step time minus the plan's wire timeline — the
        ``a_step`` analogue of the paper's startup term, one level up.
        Probed once (``StepTimer``-style warm-compiled min-of-repeats)
        and folded into ``ServePlan.t_step_fixed`` by
        ``calibrate_plan``; without a plan the whole probe is fixed."""
        probe = self.probe_step_time(repeats=repeats)
        wire = 0.0
        if self.plan is not None and self.plan.schedule.result is not None:
            wire = self.plan.schedule.result.t_iter
        return max(0.0, probe - wire)

    def calibrate_plan(self, repeats: int = 5) -> "ServePlan":
        """Probe the fixed term and install (and return) the calibrated
        plan: ``predicted_step_time`` now reports wire + fixed — the
        honest compute+dispatch serve cost model."""
        if self.plan is None:
            raise ValueError("calibrate_plan requires a ServePlan")
        self.plan = self.plan.with_step_fixed(self.measure_step_fixed(repeats))
        return self.plan

    def install_plan(self, plan: "ServePlan") -> None:
        """Swap in a (re)built ``ServePlan`` — the degraded-fabric replan
        hook.  On an unsharded engine the plan is advisory (predictions,
        shedding); on a sharded engine the decode step *executes* the
        plan's merge schedule, so the step function is rebuilt and
        recompiles on the next step — acceptable for a rare replan, and
        the only way the wire actually changes shape."""
        self.plan = plan
        if self.mesh is not None:
            from .sharded import sharded_decode_core

            core = sharded_decode_core(self.cfg, plan, self.mesh,
                                       tp_axis=self.tp_axis)
            self._step_fn = jax.jit(self._make_step(core), donate_argnums=(1,))

    def retire(self, slot: int, *, expired: bool = False,
               requeue: bool = False) -> Request:
        """Retire an active row before its budget is spent: the request
        keeps its partial ``generated`` output, the slot's device mask
        bit flips off (a masked write, never a reshape), and the slot
        frees for the next admission.

        With ``requeue=True`` the request is *evicted*, not finished: it
        is returned not-done and joins no queue — the fleet failover
        path re-submits it elsewhere and resume admission continues it
        from its partial prefix.  Otherwise it lands in ``completed``
        (``expired=`` marks a deadline expiry)."""
        req = self.active.pop(slot)
        state = dict(self._state)
        state["active"] = state["active"].at[slot].set(False)
        self._state = state
        if requeue:
            return req
        req.done = True
        req.expired = expired
        self.completed.append(req)
        return req

    def drain_requests(self) -> list[Request]:
        """Evict every in-flight and waiting request (active rows first,
        in slot order) — what the fleet controller calls on a dead
        replica to fail its work over to healthy peers.  Each request
        keeps its partial ``generated`` output; the engine is left
        empty."""
        out = [self.retire(slot, requeue=True)
               for slot in sorted(self.active)]
        out.extend(self.waiting)
        self.waiting.clear()
        return out

    # -- snapshot / restore -------------------------------------------------

    def snapshot(self, step: int = 0) -> "Any":
        """Host-side ``EngineSnapshot`` of the full decode state — the
        cache arena, row positions, next tokens, masks, budgets, both
        PRNG keys — plus the pending/in-flight/completed request queues
        (``serving.resilience.snapshot_engine``).  Save it with
        ``serving.resilience.save_snapshot`` (the checkpoint subsystem's
        atomic-rename machinery) and resume with ``restore_snapshot``:
        decoding continues token-for-token identical to an uninterrupted
        run — the serve-side analogue of ``RunState.checkpoint_tree()``."""
        from .resilience import snapshot_engine

        return snapshot_engine(self, step)

    def restore_snapshot(self, snap: "Any") -> None:
        """Install an ``EngineSnapshot``: device state re-placed (under
        the engine's mesh sharding when sharded), request queues and host
        mirrors rebuilt.  The engine must have been constructed with the
        same config/slots/max_seq the snapshot was taken under (the
        snapshot carries them for validation).  After a restore the next
        ``step()`` continues exactly where the snapshot left off."""
        snap.validate_against(self)
        state = jax.tree.map(jnp.asarray, snap.state)
        if self.mesh is not None:
            sh = jax.NamedSharding(self.mesh, jax.sharding.PartitionSpec())
            state = jax.tree.map(lambda x: jax.device_put(x, sh), state)
        self._state = state
        self._admit_key = jnp.asarray(snap.admit_key)
        from .resilience import requests_from_snapshot

        self.active, self.waiting, self.completed = requests_from_snapshot(snap)
        self.row_pos = np.asarray(snap.row_pos, np.int32).copy()
        self.next_token = np.asarray(snap.next_token, np.int32).copy()

    def compile_stats(self) -> dict[str, Any]:
        """Executable counts per engine entry point: ``decode`` (the one
        donated step), ``admit`` (one per batch bucket used), ``prefill``
        (one per distinct prompt length).  The steady-state invariant the
        tests pin is ``decode == 1`` across joins, leaves, and slot
        reuse."""
        return {
            "decode": _cache_size(self._step_fn),
            "admit": {m: _cache_size(f) for m, f in self._admit_fns.items()},
            "prefill": _cache_size(self._prefill),
        }

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _bucket(self, k: int) -> int:
        """Static admission bucket: next power of two ≥ k, ≤ slots."""
        m = 1 << max(0, k - 1).bit_length()
        return min(m, self.slots)

    def _admit_fn(self, m: int) -> Callable:
        if m not in self._admit_fns:
            self._admit_fns[m] = jax.jit(
                self._make_admit(m), donate_argnums=(0,)
            )
        return self._admit_fns[m]

    def _make_admit(self, m: int):
        """Bucketed splice: write ``m`` stacked 1-row prefill cache trees
        into their slots as masked device writes (invalid lanes rewrite
        the slot's own row — a no-op), plus the per-row scalar state.
        One executable per bucket size, reused forever."""
        cfg, slots = self.cfg, self.slots

        def admit(state, fresh, slot_idx, valid, tok0, pos0, budget0):
            def put(c, f):
                # same leaf-dispatch rule as the historical eager splice:
                # stacked stage caches splice axis 1, slot-batched leaves
                # axis 0, shared (kpos) leaves keep the engine's copy
                for i in range(m):
                    s = slot_idx[i]
                    if c.ndim >= 2 and c.shape[0] == cfg.n_stages:
                        if c.ndim >= 3 and c.shape[1] == slots:
                            cur = jax.lax.dynamic_slice_in_dim(c, s, 1, axis=1)
                            row = jnp.where(valid[i], f[i].astype(c.dtype), cur)
                            c = jax.lax.dynamic_update_slice_in_dim(c, row, s, axis=1)
                            continue
                    if c.ndim >= 1 and c.shape[0] == slots:
                        cur = jax.lax.dynamic_slice_in_dim(c, s, 1, axis=0)
                        row = jnp.where(valid[i], f[i].astype(c.dtype), cur)
                        c = jax.lax.dynamic_update_slice_in_dim(c, row, s, axis=0)
                return c

            caches = jax.tree.map(put, state["caches"], fresh)
            row_pos, next_token = state["row_pos"], state["next_token"]
            active, budget = state["active"], state["budget"]
            for i in range(m):
                s = slot_idx[i]
                row_pos = row_pos.at[s].set(
                    jnp.where(valid[i], pos0[i], row_pos[s]))
                next_token = next_token.at[s].set(
                    jnp.where(valid[i], tok0[i], next_token[s]))
                budget = budget.at[s].set(
                    jnp.where(valid[i], budget0[i], budget[s]))
                active = active.at[s].set(valid[i] | active[s])
            return {
                **state, "caches": caches, "row_pos": row_pos,
                "next_token": next_token, "active": active, "budget": budget,
            }

        return admit

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self.active]
        entries: list[tuple[int, Pytree, int, int, int]] = []
        while free and self.waiting:
            slot = free.pop(0)
            req = self.waiting.pop(0)
            if req.generated:
                # resume (fleet failover re-route): re-prefill everything
                # up to the last already-sampled token, then decode that
                # token next — the request continues from its partial
                # prefix, no admission sample, no token double-charged
                if req.remaining_tokens == 0:
                    req.done = True
                    self.completed.append(req)
                    free.insert(0, slot)
                    continue
                ids = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(req.generated[:-1], np.int32)]
                )
                _, fresh = self._prefill(self.params, self._prefill_input(ids))
                tok = int(req.generated[-1])
                pos0 = len(req.prompt) + len(req.generated) - 1
            else:
                logits, fresh = self._prefill(
                    self.params, self._prefill_input(req.prompt)
                )
                if self._keyed_sample:
                    self._admit_key, sub = jax.random.split(self._admit_key)
                    tok = int(np.asarray(self.sample(logits, sub))[0])
                else:
                    tok = int(np.asarray(self.sample(logits))[0])
                req.generated.append(tok)
                pos0 = len(req.prompt)
            if self.mesh is not None:
                sh = jax.NamedSharding(self.mesh, jax.sharding.PartitionSpec())
                fresh = jax.tree.map(lambda x: jax.device_put(x, sh), fresh)
            self.active[slot] = req
            self.row_pos[slot] = pos0
            self.next_token[slot] = tok
            entries.append((slot, fresh, tok, pos0,
                            req.max_new_tokens - len(req.generated)))
        if not entries:
            return
        n_real = len(entries)
        m = self._bucket(n_real)
        while len(entries) < m:  # pad the bucket with masked-off lanes
            entries.append(entries[0])
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[e[1] for e in entries])
        self._state = self._admit_fn(m)(
            self._state,
            stacked,
            jnp.asarray([e[0] for e in entries], jnp.int32),
            jnp.asarray([i < n_real for i in range(m)], bool),
            jnp.asarray([e[2] for e in entries], jnp.int32),
            jnp.asarray([e[3] for e in entries], jnp.int32),
            jnp.asarray([e[4] for e in entries], jnp.int32),
        )

    # -- stepping ----------------------------------------------------------

    def step(self) -> int:
        """Admit, decode one token for every active row; returns #active.

        With no active rows this is a guaranteed no-op: no compile, no
        dispatch, no collective (the empty-bucket invariant the tests
        pin)."""
        self._admit()
        if not self.active:
            return 0
        t0 = time.perf_counter() if self.timer is not None else 0.0
        new_state, sampled, _wire = self._step_fn(self.params, self._state)
        self._state = new_state
        if self.timer is not None:
            jax.block_until_ready((new_state, sampled))
            self.timer.observe(time.perf_counter() - t0)
        sampled_np = np.asarray(sampled)  # the step's one device->host read
        for slot, req in list(self.active.items()):
            tok = int(sampled_np[slot])
            req.generated.append(tok)
            self.row_pos[slot] += 1
            self.next_token[slot] = tok
            if len(req.generated) >= req.max_new_tokens or self.row_pos[slot] + 1 >= self.max_seq:
                req.done = True
                self.completed.append(req)
                del self.active[slot]
        return len(self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.active and not self.waiting:
                break
            self.step()
        return self.completed


def _takes_key(sample: Callable) -> bool:
    """Whether ``sample`` is the keyed two-arg form ``(logits, key)``."""
    try:
        n = len(inspect.signature(sample).parameters)
    except (TypeError, ValueError):
        return False
    return n >= 2


def _copy_state(state: Pytree) -> Pytree:
    """Deep-copy a ``DecodeState`` into fresh buffers (same shardings) so
    a donated probe/warmup call can never consume the engine's state."""
    return jax.tree.map(jnp.copy, state)
