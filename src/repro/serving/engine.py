"""Batch-slot serving engine: continuous batching over the decode step.

The engine owns a fixed batch of decode slots.  Requests join free slots
as they arrive (prefill runs per-join at the request's length, then its
KV rows are spliced into the slot), every occupied slot decodes one token
per engine step, and finished rows free their slots immediately — no
head-of-line blocking on long generations.

Positions are tracked *per row*: the decode step's scalar ``pos`` is the
engine's global clock, and each layer's ring-buffer cache masks by
absolute stored positions (models/layers.py), so rows at different
progress coexist in one batch.  For simplicity rows joining mid-flight
re-prefill into a fresh slot-batch of size 1 and are copied in; a paged
KV allocator is the production refinement and slots behind this API.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.steps import make_decode_step, make_prefill_step
from ..models.common import ArchConfig
from ..models.transformer import init_caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Synchronous-step continuous batching over fixed decode slots."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        slots: int = 4,
        max_seq: int = 512,
        sample: Callable[[jax.Array], jax.Array] | None = None,
    ):
        assert cfg.input_mode == "tokens", "engine demo supports token models"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.sample = sample or (lambda logits: jnp.argmax(logits, axis=-1))
        self._prefill = jax.jit(make_prefill_step(cfg, None, max_seq=max_seq))
        self._decode = jax.jit(make_decode_step(cfg, None))
        self.caches = init_caches(cfg, batch=slots, max_seq=max_seq, dtype=jnp.float32)
        self.active: dict[int, Request] = {}  # slot -> request
        self.row_pos = np.zeros((slots,), np.int32)  # per-row next position
        self.next_token = np.zeros((slots,), np.int32)
        self.waiting: list[Request] = []
        self.completed: list[Request] = []

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.waiting:
            slot = free.pop(0)
            req = self.waiting.pop(0)
            logits, fresh = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None, :])}
            )
            # splice the single-row prefill caches into this slot
            self.caches = self._splice(fresh, slot)
            tok = int(np.asarray(self.sample(logits))[0])
            req.generated.append(tok)
            self.active[slot] = req
            self.row_pos[slot] = len(req.prompt)
            self.next_token[slot] = tok

    def _splice(self, fresh, slot: int):
        """Copy a 1-row cache pytree into row ``slot`` of the engine cache."""

        def put(c, f):
            if c.ndim >= 2 and c.shape[0] == self.cfg.n_stages:
                # stacked stage caches: (n_stages, B, ...) vs fresh (n_stages, 1, ...)
                if c.ndim >= 3 and c.shape[1] == self.slots:
                    return jax.lax.dynamic_update_slice_in_dim(c, f.astype(c.dtype), slot, axis=1)
            if c.ndim >= 1 and c.shape[0] == self.slots:
                return jax.lax.dynamic_update_slice_in_dim(c, f.astype(c.dtype), slot, axis=0)
            return c  # shared (kpos) leaves — identical across rows at same clock

        return jax.tree.map(put, self.caches, fresh)

    # -- stepping ----------------------------------------------------------

    def step(self) -> int:
        """Admit, decode one token for every active row; returns #active."""
        self._admit()
        if not self.active:
            return 0
        # All rows share one engine clock; rows keep their own logical pos.
        # (The demo keeps rows aligned by admitting at matching lengths; a
        # per-row position vector is the next refinement.)
        pos = int(max(self.row_pos[s] for s in self.active))
        tokens = jnp.asarray(self.next_token[:, None])
        logits, self.caches = self._decode(
            self.params, self.caches, {"tokens": tokens}, jnp.asarray(pos, jnp.int32)
        )
        sampled = np.asarray(self.sample(logits))
        for slot, req in list(self.active.items()):
            tok = int(sampled[slot])
            req.generated.append(tok)
            self.row_pos[slot] += 1
            self.next_token[slot] = tok
            if len(req.generated) >= req.max_new_tokens or self.row_pos[slot] + 1 >= self.max_seq:
                req.done = True
                self.completed.append(req)
                del self.active[slot]
        return len(self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.active and not self.waiting:
                break
            self.step()
        return self.completed
