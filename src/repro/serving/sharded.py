"""Sharded TP decode: the ``ServePlan`` executed inside the engine step.

``planning.serve`` prices the decode-side collectives — the fresh KV rows
every attention sublayer must publish across the tensor-parallel group,
the expert all-to-all of MoE archs — and merges them with the paper's
Eq. 9/10 math.  This module is the executable counterpart: a decode step
that runs under ``shard_map`` on a TP mesh and issues **exactly one fused
collective per scheduled serve group** (``make_group_collective``), the
decode analogue of ``core.sync``'s one-all-reduce-per-group invariant.

Execution model: mirror-compute / sliced-wire
---------------------------------------------
Decode is latency-bound and sequentially dependent: stage ``i+1``'s input
is stage ``i``'s full output, so a collective whose result feeds the next
stage (the Megatron output-combine psum) can never be deferred, let alone
merged across stages.  The collectives MG-WFBP *can* merge are the ones
whose results are only needed by **future** steps — exactly the KV-cache
coherence traffic ``ServePlan`` prices: the fresh rows written at step
``t`` are not read again until step ``t+1``.

The step therefore runs **mirror-compute / sliced-wire** TP:

  * every rank computes the full decode locally (the current token's
    self-attention reads the fresh row from registers — no blocking
    collective on the critical path);
  * each rank *owns* a ``1/N`` feature slice of every stage's fresh KV
    row; the cache receives the other ``N-1`` slices **only off the
    wire** — one fused all-gather per scheduled group, so in the lowered
    HLO the written cache rows genuinely flow through the collectives;
  * MoE archs issue the plan's expert all-to-all per group instead (the
    dispatch traffic the plan priced); its outputs ride along as live
    step outputs.

The wire traffic — op type, op count, group membership, payload bytes,
issue order — is exactly what the plan scheduled and what a production
TP serving mesh ships for KV coherence; the mirrored dense compute is
the virtual-mesh stand-in for sharded projections (whose blocking
combines are out of merge scope by the argument above).  Numerics are
bit-identical to the unsharded engine: the gathered slices are the same
deterministic values every rank computed, reassembled in rank order.

``serving_param_pspecs`` / ``serving_cache_pspecs`` give the matching
at-rest GSPMD layout (Megatron column/row shards for attention + MLP
weights, head-dim shards for the KV caches) used to report per-device
memory; ``ServeTimer`` owns the step wall-clock and per-group measured
comm samples that close the predicted-vs-observed loop.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Callable

import jax
import jax.numpy as jnp

from ..models.transformer import _window_for
from ..runtime.timeline import StepTimer

if TYPE_CHECKING:
    from ..planning.serve import ServePlan

Pytree = Any

#: Param-leaf names sharded on their LAST axis (Megatron column shards:
#: q/k/v projections and the MLP up/gate matrices) vs their FIRST axis
#: (row shards: the output projections whose contraction dim is sharded).
_COL_SHARD_KEYS = ("wq", "wk", "wv", "w_gate", "w_up")
_ROW_SHARD_KEYS = ("wo", "w_down")


def _attn_sublayers(cfg) -> tuple[str, ...]:
    """Cache keys of the pattern's attention-bearing sublayers, in order."""
    return tuple(
        f"{kind}_{i}"
        for i, kind in enumerate(cfg.pattern)
        if kind not in ("rwkv", "rec")
    )


def _write_index(cfg, kind: str, cache_len: int, pos):
    """Ring-buffer write index for this sublayer's cache at ``pos`` —
    the same rule ``models.layers.attention_block`` applies on decode."""
    return pos % cache_len if _window_for(cfg, kind) else pos


def stack_fresh_rows(cfg, caches: Pytree, pos) -> jax.Array | None:
    """The step's wire payload: ``(n_stages, F)`` fresh K/V rows.

    Reads the rows the decode step just wrote at ``pos`` out of every
    attention-bearing sublayer's stacked stage cache (K then V, pattern
    order) and flattens them per stage — the exact per-stage payload
    ``planning.serve.decode_unit_costs`` prices.  Returns ``None`` for
    recurrent-only archs (nothing on the serve wire).
    """
    parts = []
    for i, kind in enumerate(cfg.pattern):
        if kind in ("rwkv", "rec"):
            continue
        k, v, _ = caches["stages"][f"{kind}_{i}"]
        idx = _write_index(cfg, kind, k.shape[2], pos)
        for arr in (k, v):
            row = jax.lax.dynamic_index_in_dim(arr, idx, axis=2, keepdims=False)
            parts.append(row.reshape(row.shape[0], -1))
    if not parts:
        return None
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def write_fresh_rows(cfg, caches: Pytree, stacked: jax.Array, pos) -> Pytree:
    """Inverse of ``stack_fresh_rows``: splice ``(n_stages, F)`` rows back
    into the stage caches at ``pos``.

    On the sharded path ``stacked`` is the reassembled all-gather output,
    so the written rows flow through the plan's collectives in the
    lowered HLO — the wire is load-bearing, not decorative.
    """
    new_stages = dict(caches["stages"])
    off = 0
    for i, kind in enumerate(cfg.pattern):
        if kind in ("rwkv", "rec"):
            continue
        key = f"{kind}_{i}"
        k, v, kpos = new_stages[key]
        idx = _write_index(cfg, kind, k.shape[2], pos)
        written = []
        for arr in (k, v):
            n_stages, b, _, h, hd = arr.shape
            width = b * h * hd
            row = stacked[:, off : off + width]
            row = row.reshape(n_stages, b, 1, h, hd).astype(arr.dtype)
            written.append(
                jax.lax.dynamic_update_slice_in_dim(arr, row, idx, axis=2)
            )
            off += width
        new_stages[key] = (written[0], written[1], kpos)
    return {**caches, "stages": new_stages}


def make_sharded_decode_step(cfg, plan: "ServePlan", *, tp_axis: str = "model"):
    """Build the per-rank body of the plan-driven sharded decode step.

    Returns ``step(params, caches, batch, pos) -> (logits, caches, wire)``
    meant to run inside ``shard_map`` over ``tp_axis`` (see
    ``sharded_decode_fn`` for the jitted wrapper).  The body runs the
    ordinary decode (``launch.steps.make_decode_step``), cuts this rank's
    owned ``1/N`` slice out of the stacked fresh-row payload, and drives
    ``planning.serve.make_group_collective`` — one fused collective per
    scheduled serve group.  For the plan's ``all_gather`` op the gathered
    full rows are written back into the caches (``wire`` is empty); for
    ``all_to_all`` (MoE) the shuffled dispatch buffers are returned as
    live outputs and the locally written rows stand.
    """
    from ..launch.steps import make_decode_step
    from ..planning.serve import make_group_collective

    base = make_decode_step(cfg, None)
    wire = make_group_collective(plan, tp_axis)
    groups = plan.schedule.groups
    is_gather = plan.op == "all_gather"

    def step(params, caches, batch, pos):
        logits, caches = base(params, caches, batch, pos)
        stacked = stack_fresh_rows(cfg, caches, pos)
        if stacked is None:  # recurrent-only arch: nothing to cohere
            return logits, caches, ()
        from ..compat import axis_size

        n = axis_size(tp_axis)
        r = jax.lax.axis_index(tp_axis)
        n_stages, full = stacked.shape
        width = -(-full // n)  # ceil: every rank ships an equal slice
        pad = width * n - full
        padded = jnp.pad(stacked, ((0, 0), (0, pad))) if pad else stacked
        local = jax.lax.dynamic_slice_in_dim(padded, r * width, width, axis=1)
        outs = wire(local)
        if not is_gather:
            return logits, caches, tuple(outs)
        rows = []
        for (lo, hi), out in zip(groups, outs):
            g = hi - lo + 1
            # (n, g·width) gather -> rank-major slices back to (g, n·width)
            rows.append(out.reshape(n, g, width).transpose(1, 0, 2).reshape(g, n * width))
        gathered = jnp.concatenate(rows, axis=0)[:, :full]
        caches = write_fresh_rows(cfg, caches, gathered, pos)
        return logits, caches, ()

    return step


def sharded_decode_core(cfg, plan: "ServePlan", mesh, *, tp_axis: str = "model"):
    """Unjitted ``shard_map``-ped plan-driven decode step on a TP ``mesh``.

    ``fn(params, caches, batch, pos) -> (logits, caches, wire)`` — the
    collective-issuing core ``ServingEngine`` embeds inside its ONE
    jitted, buffer-donating step (so sampling and the masked state
    updates trace into the same executable as the plan's collectives).
    Engine state rides in replicated (the mirrored compute needs full
    values per rank; see the module docstring), and the lowered HLO of
    any step containing this core has exactly
    ``len(plan.schedule.groups)`` collective ops — pinned by the engine
    lowering test.
    """
    from ..compat import shard_map

    P = jax.sharding.PartitionSpec
    step = make_sharded_decode_step(cfg, plan, tp_axis=tp_axis)
    n_wire = 0 if plan.op == "all_gather" else len(plan.schedule.groups)
    if not _attn_sublayers(cfg):
        n_wire = 0
    out_specs = (P(), P(), tuple(P(tp_axis) for _ in range(n_wire)))
    return shard_map(
        step, mesh=mesh, in_specs=(P(), P(), P(), P()),
        out_specs=out_specs, axis_names={tp_axis}, check_vma=False,
    )


def sharded_decode_fn(cfg, plan: "ServePlan", mesh, *, tp_axis: str = "model"):
    """``jax.jit(sharded_decode_core(...))`` — the standalone jitted
    sharded decode step, for callers that want the plan-driven step
    outside a ``ServingEngine`` (the engine itself jits the core inside
    its donated whole-step function instead)."""
    return jax.jit(sharded_decode_core(cfg, plan, mesh, tp_axis=tp_axis))


# ---------------------------------------------------------------------------
# At-rest GSPMD layout (what a production engine holds its state in)
# ---------------------------------------------------------------------------


def serving_param_pspecs(params: Pytree, *, tp_axis: str = "model") -> Pytree:
    """Megatron at-rest ``PartitionSpec`` tree for the decode weights.

    q/k/v and MLP up/gate projections shard their output (last) axis over
    ``tp_axis``; the output projections (``wo``/``w_down``) shard their
    contraction (first non-stage) axis; everything else (norms, embed,
    head) stays replicated.  Stacked stage leaves keep the leading stage
    axis unsharded.  Pair with ``shard_serving_state`` to place, or with
    ``jax.sharding.NamedSharding.shard_shape`` to report the per-device
    memory of a sharded deployment.
    """
    P = jax.sharding.PartitionSpec

    def spec_for(path, leaf) -> jax.sharding.PartitionSpec:
        names = [str(getattr(p, "key", "")) for p in path]
        ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        if any(n in _COL_SHARD_KEYS for n in names) and ndim >= 2:
            return P(*([None] * (ndim - 1) + [tp_axis]))
        if any(n in _ROW_SHARD_KEYS for n in names) and ndim >= 2:
            # stacked stage leaves: (n_stages, in, out) -> shard 'in'
            row_axis = ndim - 2
            spec = [None] * ndim
            spec[row_axis] = tp_axis
            return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params)


def serving_cache_pspecs(cfg, caches: Pytree, *, tp_axis: str = "model") -> Pytree:
    """At-rest ``PartitionSpec`` tree sharding every K/V cache leaf's
    head_dim (last) axis over ``tp_axis`` — the decode-side memory win
    (the KV cache is the serving bottleneck); recurrent state and the
    ``kpos`` ring indices stay replicated."""
    P = jax.sharding.PartitionSpec

    def spec_for(path, leaf) -> jax.sharding.PartitionSpec:
        ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        # K/V leaves: (..., B, T, n_kv_heads, head_dim) float arrays
        if ndim >= 4 and jnp.issubdtype(getattr(leaf, "dtype", jnp.int32), jnp.floating):
            names = [str(getattr(p, "key", "")) for p in path]
            if any("_" in n and n.split("_")[0] not in ("rwkv", "rec") for n in names):
                return P(*([None] * (ndim - 1) + [tp_axis]))
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, caches)


def shard_serving_state(
    params: Pytree, caches: Pytree, cfg, mesh, *, tp_axis: str = "model"
) -> tuple[Pytree, Pytree]:
    """``device_put`` the engine state into the at-rest TP layout.

    Leaves whose shard axis does not divide by the ``tp_axis`` size fall
    back to replicated (small reduced configs).  The mirror-compute step
    consumes replicated values, so use this for at-rest storage /
    memory reporting, not as the step's input sharding.
    """
    size = dict(zip(mesh.axis_names, mesh.devices.shape))[tp_axis]

    def place(specs, tree):
        def put(spec, leaf):
            for ax, name in enumerate(tuple(spec)):
                if name is not None and leaf.shape[ax] % size != 0:
                    spec = jax.sharding.PartitionSpec()
                    break
            return jax.device_put(leaf, jax.NamedSharding(mesh, spec))

        return jax.tree.map(
            put, specs, tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )

    return (
        place(serving_param_pspecs(params, tp_axis=tp_axis), params),
        place(serving_cache_pspecs(cfg, caches, tp_axis=tp_axis), caches),
    )


class ServeTimer(StepTimer):
    """Decode-step wall-clock window + per-group measured comm seconds.

    The serving analogue of ``runtime.timeline.StepTimer``: the engine
    feeds ``observe(dt)`` per decode step (first samples skipped — they
    include compilation), ``median()`` is the observed step time that
    ``ServePlan.predicted`` (``schedule.result.t_iter``) is compared
    against, and ``group_times`` holds the per-scheduled-group measured
    collective seconds filled by ``planning.serve.time_serve_groups``.
    """

    def __init__(self, window: int = 200, skip_first: int = 2):
        super().__init__(window=window, skip_first=skip_first)
        self.group_times: tuple[float, ...] = ()
