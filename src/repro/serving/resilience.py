"""Serving resilience: snapshot/restore, chaos injection, the restart
loop, and degraded-fabric replanning.

MG-WFBP's merged buckets make collectives fewer and *larger*, so one
slow or dead participant stalls the whole decode step — the serving
fleet's version of the straggler problem the training side already
handles with ``resilient_loop`` + ``StragglerMonitor`` + ``CommRefitter``
(journal arXiv 1912.09268: re-fit the (α, β) comm model online when the
network changes).  This module is the serve-side counterpart, built from
four pieces wired through the whole stack:

* **EngineSnapshot** — the full ``DecodeState`` (KV cache arena, row
  positions, next tokens, active mask, budgets, sampling PRNG key) plus
  the admission key and every request queue (active/waiting/completed),
  serialized through the checkpoint subsystem's atomic-rename machinery.
  ``ServingEngine.restore_snapshot`` resumes **token-for-token
  identical** decoding — the serve analogue of
  ``RunState.checkpoint_tree()``.
* **ChaosInjector** — deterministic, seeded fault injection at the
  engine's existing seams: step-raise kills (the
  ``fault_injector(step)`` contract of ``runtime.fault_tolerance``),
  collective slowdown via a wrapped ``time_fn`` (the ``CommRefitter``
  probe seam), snapshot corruption, and a mid-write kill that leaves a
  ``.tmp`` directory behind.  Every failure mode is unit-testable on a
  CPU container.
* **resilient_serve_loop** — restart-with-backoff around
  ``engine.step()``: restores the newest *loadable* snapshot (corrupt
  ones fall back to older complete ones), re-warms the jitted step,
  re-admits interrupted requests at their saved positions, and enforces
  per-request deadlines — expired requests retire gracefully with
  partial output, and admission sheds load when
  ``ServePlan.predicted_step_time()`` says the SLO cannot be met.
* **degraded-fabric replan** — a ``StragglerMonitor`` over observed step
  times; on sustained degradation the serve-side (α, β) is re-fit
  (``planning.refit_serve_fit``) and the plan rebuilt at the degraded
  constants (``planning.rebuild_serve_plan``) — the merge decision
  changes when the wire slows down.

See ``docs/resilience.md`` for the failure model and snapshot schema.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import numpy as np

from ..checkpoint import available_steps, latest_step, restore, save
from ..runtime.fault_tolerance import StragglerMonitor
from .engine import Request, ServingEngine

Pytree = Any

log = logging.getLogger(__name__)

SNAPSHOT_FORMAT = 1


# ---------------------------------------------------------------------------
# EngineSnapshot: the full serving state, host-side
# ---------------------------------------------------------------------------


def _req_to_dict(r: Request) -> dict[str, Any]:
    return {
        "rid": int(r.rid),
        "prompt": [int(t) for t in np.asarray(r.prompt).tolist()],
        "max_new_tokens": int(r.max_new_tokens),
        "generated": [int(t) for t in r.generated],
        "done": bool(r.done),
        "deadline_s": None if r.deadline_s is None else float(r.deadline_s),
        "expired": bool(r.expired),
        "shed": bool(r.shed),
        "replica_id": None if r.replica_id is None else int(r.replica_id),
        "retries": int(r.retries),
    }


def _req_from_dict(d: dict[str, Any]) -> Request:
    return Request(
        rid=int(d["rid"]),
        prompt=np.asarray(d["prompt"], np.int32),
        max_new_tokens=int(d["max_new_tokens"]),
        generated=[int(t) for t in d["generated"]],
        done=bool(d["done"]),
        deadline_s=d.get("deadline_s"),
        expired=bool(d.get("expired", False)),
        shed=bool(d.get("shed", False)),
        replica_id=d.get("replica_id"),
        retries=int(d.get("retries", 0)),
    )


@dataclasses.dataclass
class EngineSnapshot:
    """One resumable serving checkpoint, entirely host-side.

    ``state`` is the engine's ``DecodeState`` pytree copied to numpy (the
    cache arena, ``row_pos``/``next_token``/``active``/``budget``
    vectors, and the sampling PRNG key); ``admit_key`` is the prefill
    sampling key; the three request collections are JSON dicts (see
    ``_req_to_dict``); ``row_pos``/``next_token`` are the engine's host
    bookkeeping mirrors; ``meta`` pins the engine geometry
    (``arch``/``slots``/``max_seq``) so a restore into a mismatched
    engine fails loudly instead of decoding garbage."""

    step: int
    state: Pytree
    admit_key: np.ndarray
    active: dict[int, dict]
    waiting: list[dict]
    completed: list[dict]
    row_pos: np.ndarray
    next_token: np.ndarray
    meta: dict[str, Any]

    def validate_against(self, engine: ServingEngine) -> None:
        """Raise unless ``engine`` has the geometry this snapshot was
        taken under (same arch, slots, and max_seq)."""
        want = _engine_meta(engine)
        got = {k: self.meta.get(k) for k in want}
        if got != want:
            raise ValueError(
                f"snapshot geometry {got} does not match engine {want}"
            )


def _engine_meta(engine: ServingEngine) -> dict[str, Any]:
    return {
        "arch": engine.cfg.name,
        "slots": int(engine.slots),
        "max_seq": int(engine.max_seq),
    }


def snapshot_engine(engine: ServingEngine, step: int = 0) -> EngineSnapshot:
    """Copy the engine's full decode state and request queues to host
    memory — safe to take between any two steps (the donated device state
    is valid there) and cheap relative to a decode step at serve scale."""
    return EngineSnapshot(
        step=int(step),
        state=_tree_to_host(engine._state),
        admit_key=np.asarray(engine._admit_key),
        active={int(s): _req_to_dict(r) for s, r in engine.active.items()},
        waiting=[_req_to_dict(r) for r in engine.waiting],
        completed=[_req_to_dict(r) for r in engine.completed],
        row_pos=np.asarray(engine.row_pos, np.int32).copy(),
        next_token=np.asarray(engine.next_token, np.int32).copy(),
        meta={"serve_snapshot_format": SNAPSHOT_FORMAT, **_engine_meta(engine)},
    )


def requests_from_snapshot(
    snap: EngineSnapshot,
) -> tuple[dict[int, Request], list[Request], list[Request]]:
    """Rebuild the three request collections from a snapshot (fresh
    ``Request`` objects — restored runs never alias the caller's)."""
    active = {int(s): _req_from_dict(d) for s, d in snap.active.items()}
    waiting = [_req_from_dict(d) for d in snap.waiting]
    completed = [_req_from_dict(d) for d in snap.completed]
    return active, waiting, completed


def _tree_to_host(tree: Pytree) -> Pytree:
    import jax

    return jax.tree.map(lambda x: np.asarray(x), tree)


def save_snapshot(
    engine: ServingEngine, directory: str, step: int
) -> EngineSnapshot:
    """Snapshot the engine and persist it under ``directory/step_<k>/``
    via ``checkpoint.save`` — the same atomic-rename machinery training
    checkpoints use, so a crash mid-write always leaves a complete older
    snapshot behind (``latest_snapshot`` never sees a partial one)."""
    snap = snapshot_engine(engine, step)
    save(
        directory,
        step,
        {"state": snap.state, "admit_key": snap.admit_key},
        extra={
            "meta": snap.meta,
            "step": snap.step,
            "active": {str(s): d for s, d in snap.active.items()},
            "waiting": snap.waiting,
            "completed": snap.completed,
            "row_pos": snap.row_pos.tolist(),
            "next_token": snap.next_token.tolist(),
        },
    )
    return snap


def load_snapshot(
    directory: str, step: int, engine: ServingEngine
) -> EngineSnapshot:
    """Read one on-disk snapshot back into an ``EngineSnapshot``.

    ``engine`` supplies the pytree structure (a restore target must be
    built with the snapshot's geometry anyway); raises on a corrupt or
    geometry-mismatched snapshot — ``restore_latest_snapshot`` catches
    and falls back."""
    like = {
        "state": _tree_to_host(engine._state),
        "admit_key": np.asarray(engine._admit_key),
    }
    tree, extra = restore(directory, step, like)
    meta = extra.get("meta", {})
    if meta.get("serve_snapshot_format") != SNAPSHOT_FORMAT:
        raise ValueError(
            f"unsupported serve snapshot format {meta.get('serve_snapshot_format')!r}"
        )
    return EngineSnapshot(
        step=int(extra["step"]),
        state=tree["state"],
        admit_key=tree["admit_key"],
        active={int(s): d for s, d in extra["active"].items()},
        waiting=list(extra["waiting"]),
        completed=list(extra["completed"]),
        row_pos=np.asarray(extra["row_pos"], np.int32),
        next_token=np.asarray(extra["next_token"], np.int32),
        meta=meta,
    )


def latest_snapshot(directory: str) -> int | None:
    """Step of the newest complete on-disk snapshot (None when empty)."""
    return latest_step(directory)


def restore_latest_snapshot(
    engine: ServingEngine, directory: str
) -> tuple[int, int]:
    """Restore the newest *loadable* snapshot into ``engine``.

    Walks complete snapshots newest-first; a corrupt one (chaos-injected
    or a real bad disk — ``np.load`` CRC failures, geometry mismatches)
    is logged and skipped, falling back to the next older complete
    snapshot.  Returns ``(restored_step, skipped)``; raises
    ``RuntimeError`` when no snapshot loads at all."""
    skipped = 0
    for step in reversed(available_steps(directory)):
        try:
            snap = load_snapshot(directory, step, engine)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            log.exception("snapshot step %d unloadable; falling back", step)
            skipped += 1
            continue
        engine.restore_snapshot(snap)
        return step, skipped
    raise RuntimeError(
        f"no loadable serve snapshot in {directory!r} ({skipped} corrupt)"
    )


# ---------------------------------------------------------------------------
# ChaosInjector: deterministic, seeded fault injection
# ---------------------------------------------------------------------------


class ChaosError(RuntimeError):
    """An injected failure — what the chaos step-raise seam throws."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault schedule for one chaos run.

    ``kill_every``/``kill_at`` raise deterministically (each step kills
    at most once, so a restored run replaying the same step makes
    progress); ``kill_prob`` draws a seeded Bernoulli per attempted step
    (bounded by ``max_kills``).  ``slow_factor``/``slow_after`` model a
    degraded fabric: observed step times and probed collective times are
    multiplied once the loop passes ``slow_after`` — the injectable
    ``time_fn`` seam ``CommRefitter`` established.  ``corrupt_snapshot_at``
    flips bytes in the newest snapshot's leaf file after the first
    snapshot at/after that step; ``partial_write_at`` drops a
    manifest-less ``step_<k>.tmp`` directory (a write killed mid-flight)
    — both exercise the fallback-to-older-snapshot path."""

    seed: int = 0
    kill_every: int = 0
    kill_at: tuple[int, ...] = ()
    kill_prob: float = 0.0
    max_kills: int | None = None
    slow_factor: float = 1.0
    slow_after: int | None = None
    corrupt_snapshot_at: int | None = None
    partial_write_at: int | None = None

    def for_replica(self, replica_id: int) -> "ChaosConfig":
        """Derive replica ``replica_id``'s fault domain from this fleet
        config: the schedule fields are shared, the seed is drawn
        deterministically from ``(seed, replica_id)`` via
        ``np.random.SeedSequence``, so every replica's Bernoulli kill
        stream and corruption bytes are independent of its peers' yet
        the whole multi-replica chaos run replays exactly from the one
        fleet seed."""
        derived = int(
            np.random.SeedSequence([int(self.seed), int(replica_id)])
            .generate_state(1)[0]
        )
        return dataclasses.replace(self, seed=derived)


class ChaosInjector:
    """Deterministic executor of a ``ChaosConfig``.

    Mirrors the ``fault_injector(step)`` contract of
    ``runtime.fault_tolerance.resilient_loop`` so the serve loop's chaos
    seam is the same shape as training's, and adds the serve-specific
    seams: step-time scaling, collective-probe wrapping, snapshot
    corruption, and the mid-write kill."""

    def __init__(self, config: ChaosConfig):
        self.config = config
        self.rng = np.random.default_rng(config.seed)
        self.kills = 0
        self._killed_steps: set[int] = set()
        self._corrupted = False
        self._partial = False

    # -- step-raise seam ----------------------------------------------------

    def fault_injector(self, step: int) -> None:
        """Raise ``ChaosError`` when the schedule kills this step (each
        step index kills at most once — a restored run replaying the same
        step must make progress)."""
        c = self.config
        if self.kills_exhausted():
            return
        due = (step in c.kill_at) or (
            c.kill_every > 0 and step > 0 and step % c.kill_every == 0
        )
        if not due and c.kill_prob > 0:
            due = bool(self.rng.random() < c.kill_prob)
        if due and step not in self._killed_steps:
            self._killed_steps.add(step)
            self.kills += 1
            raise ChaosError(f"injected kill at serve step {step}")

    def kills_exhausted(self) -> bool:
        c = self.config
        return c.max_kills is not None and self.kills >= c.max_kills

    # -- degraded-fabric seams ----------------------------------------------

    def degraded(self, step: int) -> bool:
        c = self.config
        return (
            c.slow_factor != 1.0
            and c.slow_after is not None
            and step >= c.slow_after
        )

    def scale_step_time(self, dt: float, step: int) -> float:
        """Observed step seconds under chaos: multiplied by
        ``slow_factor`` once the fabric is degraded — what the
        ``StragglerMonitor`` sees."""
        return dt * self.config.slow_factor if self.degraded(step) else dt

    def wrap_time_fn(
        self, time_fn: Callable[[int], float], step_fn: Callable[[], int]
    ) -> Callable[[int], float]:
        """Wrap a ``time_fn(nbytes) -> seconds`` collective probe so it
        reports degraded times once the fabric is slow — the injectable
        seam ``refit_serve_fit`` probes through, making the degraded
        replan unit-testable without real network noise."""

        def wrapped(nbytes: int) -> float:
            t = float(time_fn(nbytes))
            return (
                t * self.config.slow_factor if self.degraded(step_fn()) else t
            )

        return wrapped

    # -- snapshot seams -----------------------------------------------------

    def post_snapshot(self, directory: str, step: int) -> None:
        """Apply the snapshot-targeting faults once their step arrives
        (called by the loop right after each snapshot lands)."""
        c = self.config
        if (
            c.corrupt_snapshot_at is not None
            and step >= c.corrupt_snapshot_at
            and not self._corrupted
        ):
            self._corrupted = True
            self.corrupt_snapshot(directory)
        if (
            c.partial_write_at is not None
            and step >= c.partial_write_at
            and not self._partial
        ):
            self._partial = True
            self.partial_write(directory, step + 1)

    def corrupt_snapshot(self, directory: str, step: int | None = None) -> None:
        """Overwrite a seeded byte range in the middle of the newest (or
        given) snapshot's leaf file — a simulated bad disk.  The zip CRC
        check makes the next load raise, which the restore path must
        survive by falling back to an older complete snapshot."""
        import pathlib

        step = latest_step(directory) if step is None else step
        if step is None:
            return
        path = pathlib.Path(directory) / f"step_{step:08d}" / "leaves.npz"
        raw = bytearray(path.read_bytes())
        if len(raw) < 128:
            return
        mid = len(raw) // 2
        raw[mid : mid + 64] = bytes(self.rng.integers(0, 256, 64, np.uint8))
        path.write_bytes(bytes(raw))
        log.warning("chaos: corrupted snapshot step %d (%s)", step, path)

    def partial_write(self, directory: str, step: int) -> None:
        """Leave a manifest-less ``step_<k>.tmp`` directory behind — what
        a process killed mid-snapshot-write looks like.  The atomic
        rename contract means no reader may ever treat it as a
        snapshot."""
        import pathlib

        tmp = pathlib.Path(directory) / f"step_{step:08d}.tmp"
        tmp.mkdir(parents=True, exist_ok=True)
        (tmp / "leaves.npz").write_bytes(
            bytes(self.rng.integers(0, 256, 256, np.uint8))
        )
        log.warning("chaos: left partial snapshot write %s", tmp)


# ---------------------------------------------------------------------------
# resilient_serve_loop: restart-with-backoff around engine.step()
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeReport:
    """What one ``resilient_serve_loop`` run did.

    ``shed``/``expired`` are final request states (counted once each, no
    matter how many restores replayed the decision); ``recovery_times_s``
    is one entry per restart — backoff + snapshot restore + step re-warm,
    the serve-side MTTR.  ``goodput_tokens`` counts tokens of completed
    requests that met their deadline (shed and expired requests
    contribute nothing)."""

    completed: list[Request] = dataclasses.field(default_factory=list)
    steps: int = 0
    restarts: int = 0
    replans: int = 0
    snapshots: int = 0
    snapshot_fallbacks: int = 0
    shed: int = 0
    expired: int = 0
    recovery_times_s: list[float] = dataclasses.field(default_factory=list)
    interrupted: bool = False
    goodput_tokens: int = 0
    wall_s: float = 0.0

    @property
    def goodput_tok_per_s(self) -> float:
        """Deadline-meeting tokens per wall second over the whole run."""
        return self.goodput_tokens / max(self.wall_s, 1e-9)


def _expire_and_shed(
    engine: ServingEngine, now: float, report: ServeReport
) -> None:
    """Deadline enforcement, both ends: active rows past their deadline
    retire with partial output; waiting requests whose predicted
    completion misses their deadline are shed before they cost a step."""
    pred = engine.predicted_step_time() or 0.0
    for slot, req in list(engine.active.items()):
        if req.deadline_s is not None and now >= req.deadline_s:
            engine.retire(slot, expired=True)
    kept = []
    for req in engine.waiting:
        if req.deadline_s is not None:
            eta = now + pred * (req.max_new_tokens + 1)
            if now >= req.deadline_s or eta > req.deadline_s:
                req.shed = True
                req.done = True
                engine.completed.append(req)
                continue
        kept.append(req)
    engine.waiting[:] = kept


def _degraded_replan(
    engine: ServingEngine,
    baseline_model: Any,
    chaos: ChaosInjector | None,
    refit_time_fn: Callable[[int], float] | None,
    refit_sizes: tuple[int, ...] | None,
    step: int,
    on_replan: Callable[[Any], None] | None,
) -> None:
    """Re-fit the serve-side (α, β) and rebuild the plan at the degraded
    constants — the ``CommRefitter`` pattern through the serve wire."""
    from ..planning.serve import rebuild_serve_plan, refit_serve_fit

    plan = engine.plan
    if plan is None:
        return
    # default probe: the loop-entry plan's pricing — under chaos slowdown
    # this *is* the degraded wire (the unit-test seam; probing the
    # baseline, never the previous fit, keeps repeated replans from
    # compounding); production passes
    # planning.serve_collective_time_fn(mesh, plan.op) for live probes
    time_fn = refit_time_fn or (lambda nb: float(baseline_model(nb)))
    if chaos is not None:
        time_fn = chaos.wrap_time_fn(time_fn, lambda: step)
    fit = refit_serve_fit(
        time_fn, probe_sizes=refit_sizes,
        name=f"degraded:{plan.model.name or plan.fabric}",
    )
    new_plan = rebuild_serve_plan(plan, fit)
    engine.install_plan(new_plan)
    log.warning(
        "degraded-fabric replan at step %d: (a=%.3e, b=%.3e) -> "
        "(a=%.3e, b=%.3e), %d -> %d groups",
        step, plan.model.a, plan.model.b, fit.a, fit.b,
        len(plan.schedule.groups), len(new_plan.schedule.groups),
    )
    if on_replan is not None:
        on_replan(new_plan)


class ServeLoopDriver:
    """The resilient serve loop, one guarded step at a time.

    Owns everything ``resilient_serve_loop`` used to keep in locals —
    the step counter, restart budget, snapshot cadence, chaos and
    straggler hooks, and the accumulating ``ServeReport`` — behind a
    cooperative ``tick()``: advance one step, surviving a failure by
    backoff + snapshot restore + step re-warm.  ``resilient_serve_loop``
    is the single-engine while-loop over one driver;
    ``serving.fleet.FleetController`` drives N of them round-robin (one
    tick per replica per round), so both layers share exactly one
    failure semantics.  A tick that exhausts ``max_restarts`` (or finds
    no loadable snapshot) re-raises — the fleet layer's cue to fail the
    replica's in-flight requests over to healthy peers."""

    def __init__(
        self,
        engine: ServingEngine,
        *,
        snapshot_dir: str,
        snapshot_every: int = 8,
        max_restarts: int = 5,
        backoff_base_s: float = 0.05,
        sleep_fn: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.monotonic,
        chaos: ChaosInjector | None = None,
        straggler: StragglerMonitor | None = None,
        refit_time_fn: Callable[[int], float] | None = None,
        refit_sizes: tuple[int, ...] | None = None,
        on_replan: Callable[[Any], None] | None = None,
    ):
        self.engine = engine
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.sleep_fn = sleep_fn
        self.clock = clock
        self.chaos = chaos
        self.straggler = straggler
        self.refit_time_fn = refit_time_fn
        self.refit_sizes = refit_sizes
        self.on_replan = on_replan
        self.report = ServeReport()
        self.step = 0
        self.restarts = 0
        self._baseline_model = (
            engine.plan.model if engine.plan is not None else None
        )
        self._t_start = clock()
        # one snapshot before the first step: a kill at any point has
        # something to restore
        self.snapshot_now()

    @property
    def idle(self) -> bool:
        """No active rows and no waiting requests — nothing to tick."""
        return not self.engine.active and not self.engine.waiting

    def snapshot_now(self) -> None:
        """Persist the engine at the current step (counted)."""
        save_snapshot(self.engine, self.snapshot_dir, self.step)
        self.report.snapshots += 1

    def tick(self) -> bool:
        """Advance one guarded serve step; returns False once no work
        remains.  Failures inside the step recover in place (backoff,
        restore, re-warm) unless the restart budget is exhausted, in
        which case the failure propagates to the caller."""
        if self.idle:
            return False
        try:
            _expire_and_shed(self.engine, self.clock(), self.report)
            if self.idle:
                return False
            if self.chaos is not None:
                self.chaos.fault_injector(self.step)
            t0 = self.clock()
            self.engine.step()
            dt = self.clock() - t0
            self.step += 1
            self.report.steps += 1
            if self.chaos is not None:
                dt = self.chaos.scale_step_time(dt, self.step)
            if self.straggler is not None and self.straggler.observe(dt):
                _degraded_replan(
                    self.engine, self._baseline_model, self.chaos,
                    self.refit_time_fn, self.refit_sizes, self.step,
                    self.on_replan,
                )
                self.report.replans += 1
            if self.step % max(1, self.snapshot_every) == 0:
                self.snapshot_now()
                if self.chaos is not None:
                    self.chaos.post_snapshot(self.snapshot_dir, self.step)
        except (KeyboardInterrupt, SystemExit):
            save_snapshot(self.engine, self.snapshot_dir, self.step)
            raise  # operator interrupts stop the loop, never restart it
        except Exception:
            self._recover()
        return True

    def _recover(self) -> None:
        """Restart-with-backoff from the newest loadable snapshot (runs
        inside the failed tick's ``except`` block; re-raises the original
        failure once ``max_restarts`` is spent)."""
        log.exception(
            "serve step %d failed; restart %d/%d from latest snapshot",
            self.step, self.restarts + 1, self.max_restarts,
        )
        self.restarts += 1
        self.report.restarts = self.restarts
        if self.restarts > self.max_restarts:
            raise
        t_fail = self.clock()
        if self.backoff_base_s > 0:
            self.sleep_fn(self.backoff_base_s * 2 ** (self.restarts - 1))
        restored, skipped = restore_latest_snapshot(self.engine, self.snapshot_dir)
        self.report.snapshot_fallbacks += skipped
        self.engine.warmup()  # re-warm the jitted step off the clock path
        self.step = restored
        self.report.recovery_times_s.append(self.clock() - t_fail)

    def finalize(self) -> ServeReport:
        """Close out the report: wall time, completed requests, and the
        shed/expired/goodput tallies."""
        report = self.report
        report.wall_s = self.clock() - self._t_start
        report.completed = list(self.engine.completed)
        report.shed = sum(1 for r in report.completed if r.shed)
        report.expired = sum(1 for r in report.completed if r.expired)
        report.goodput_tokens = sum(
            len(r.generated)
            for r in report.completed
            if not r.shed and not r.expired
        )
        return report


def resilient_serve_loop(
    engine: ServingEngine,
    *,
    snapshot_dir: str,
    snapshot_every: int = 8,
    max_restarts: int = 5,
    max_steps: int = 10_000,
    backoff_base_s: float = 0.05,
    sleep_fn: Callable[[float], None] = time.sleep,
    clock: Callable[[], float] = time.monotonic,
    chaos: ChaosInjector | None = None,
    straggler: StragglerMonitor | None = None,
    refit_time_fn: Callable[[int], float] | None = None,
    refit_sizes: tuple[int, ...] | None = None,
    on_replan: Callable[[Any], None] | None = None,
    stop_flag: Callable[[], bool] | None = None,
) -> ServeReport:
    """Run ``engine`` to completion, surviving failures — the serve-side
    ``resilient_loop``.

    Snapshot cadence: one snapshot before the first step (so a kill at
    any point has something to restore) and every ``snapshot_every``
    steps after.  On a step failure the loop logs the traceback, backs
    off exponentially (``backoff_base_s * 2**(restarts-1)``, ``sleep_fn``
    injectable), restores the newest loadable snapshot (corrupt ones fall
    back to older complete ones — ``snapshot_fallbacks`` counts them),
    re-warms the jitted step, and resumes; in-flight requests continue at
    their saved positions, so the completed tokens are bit-identical to
    an uninterrupted run (pinned by the chaos tests and the
    ``serve_resilience`` benchmark).  ``KeyboardInterrupt``/``SystemExit``
    snapshot best-effort and re-raise — an operator interrupt stops the
    loop, it never restarts it (``launch/serve.py`` turns SIGINT into
    ``stop_flag`` for the fully graceful version).

    Deadlines: before every step, active rows past their
    ``Request.deadline_s`` retire gracefully with partial output, and
    waiting requests whose predicted completion (admission now +
    ``engine.predicted_step_time()`` × remaining budget) misses their
    deadline are shed unadmitted.  All times are on ``clock`` —
    injectable, so deadline behavior is deterministic under test.

    Degradation: when ``straggler`` flags sustained slow steps (observed
    step seconds, chaos-scaled under injection), the serve (α, β) is
    re-fit through ``refit_time_fn`` and the plan rebuilt at the degraded
    constants (``planning.rebuild_serve_plan``) — the merge schedule
    changes when the wire slows down, and a sharded engine recompiles its
    step to execute the new schedule.

    This is the single-engine while-loop over a ``ServeLoopDriver``;
    ``serving.fleet.FleetController`` drives N drivers through the same
    ``tick()`` for the fleet version.
    """
    driver = ServeLoopDriver(
        engine,
        snapshot_dir=snapshot_dir,
        snapshot_every=snapshot_every,
        max_restarts=max_restarts,
        backoff_base_s=backoff_base_s,
        sleep_fn=sleep_fn,
        clock=clock,
        chaos=chaos,
        straggler=straggler,
        refit_time_fn=refit_time_fn,
        refit_sizes=refit_sizes,
        on_replan=on_replan,
    )
    while driver.step < max_steps:
        if stop_flag is not None and stop_flag():
            driver.snapshot_now()
            driver.report.interrupted = True
            break
        if not driver.tick():
            break
    return driver.finalize()
