"""Per-unit step instrumentation: the live-loop side of measured costs.

The paper seeds Algorithm 1 with per-layer backward times "benchmarked in
the first several iterations"; the journal version re-derives them online.
Until this module the train loop's only live signal was whole-step wall
time — a uniform rescale of the analytic vector that can never move the
*relative* unit costs the merge decision actually depends on.

Two measurement paths, mirroring ``core/profiler.py``'s split:

  * where compiled-HLO segment profiles exist (the dry-run pipeline),
    ``profiler.time_segment`` wall-clocks those same compiled segments —
    measured seconds over the exact segment decomposition;
  * in the live loop, ``make_unit_probes`` builds one *jitted probe* per
    distinct CommUnit kind (embed / one scan stage / tail / head) running
    that unit's real forward+backward at the training shape, and
    ``probe_unit_times`` times them (warmup discarded, min of repeats).
    Structurally identical scan stages share one probe, so a probe pass
    costs ~3–4 small jitted calls regardless of depth — cheap enough to
    amortize into the drift-check cadence.

``probe_unit_times`` feeds ``MeasuredCosts.from_segment_times`` directly:
per-unit backward seconds under ``MEASURED_HW``, with genuinely
non-uniform drift across units (embed's gather backward and the head's
vocab matmul move very differently from a transformer stage when batch,
sequence, or sharding change).

The comm side rides the same cadence: ``time_group_comm`` times one real
psum per schedule group's wire payload (``sync.group_wire_bytes``), and
``StepTimer`` owns the whole-step samples (compile-step skipping included)
that predicted-vs-observed provenance compares against.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Any, Callable

from ..core.profiler import time_segment

Pytree = Any

#: Probes time forward+backward together (``jax.grad`` runs both); the
#: backward share of a train segment is 2/3 under the paper's 2:4
#: fwd:bwd flops ratio (Eq. 17/18) — the same split the dry-run uses.
BWD_FRACTION = 2.0 / 3.0


@dataclasses.dataclass
class UnitProfile:
    """One probe pass: measured per-unit backward seconds (+ comm)."""

    unit_seconds: dict[str, float]  # unit name -> backward seconds
    group_seconds: tuple[float, ...] = ()  # per schedule group comm seconds
    source: str = "probe"

    def ratios(self, base_costs, hw) -> dict[str, float]:
        """measured / analytic backward-time ratio per unit — the drift
        signature.  A uniform whole-step rescale produces identical
        ratios; real segment timing does not."""
        out = {}
        for c in base_costs:
            if c.name in self.unit_seconds:
                out[c.name] = self.unit_seconds[c.name] / max(c.t_b(hw), 1e-12)
        return out

    def nonuniformity(self, base_costs, hw) -> float:
        """max/min of the per-unit ratios (1.0 == a pure uniform rescale)."""
        r = list(self.ratios(base_costs, hw).values())
        if not r:
            return 1.0
        return max(r) / max(min(r), 1e-12)


def make_unit_probes(
    cfg, params: Pytree, batch: dict, *,
    positions=None,
) -> dict[str, tuple[Callable, tuple]]:
    """One jitted fwd+bwd probe per distinct unit kind.

    Returns ``{kind: (jitted_fn, args)}`` with kinds ``embed``, ``stage``,
    ``tail`` (when the arch has one) and ``head`` — the timed-shard-map
    fallback for when no compiled-HLO segment profile exists.  Probes run
    the unit's real computation (``models.transformer`` apply fns) on the
    live batch shapes, so their wall times move with exactly the things
    Eq. 18's analytic constants cannot see.
    """
    import jax
    import jax.numpy as jnp

    from ..models.layers import apply_norm, softcap_logits
    from ..models.transformer import apply_stage

    targets = batch["targets"]
    B, S = targets.shape
    x = jnp.ones((B, S, cfg.d_model), cfg.param_dtype)
    if positions is None:
        if cfg.attention and cfg.attention.rope == "mrope":
            positions = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        else:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    probes: dict[str, tuple[Callable, tuple]] = {}

    if cfg.input_mode == "embeds":
        # no lookup backward in this mode; the unit's cost is the input cast
        def embed_loss(e):
            return jnp.sum(e.astype(jnp.float32))

        probes["embed"] = (jax.jit(jax.grad(embed_loss)), (batch["embeds"],))
    else:
        tokens = batch["tokens"]

        def embed_loss(e):
            return jnp.sum(e[tokens].astype(jnp.float32))

        probes["embed"] = (jax.jit(jax.grad(embed_loss)), (params["embed"],))

    def stage_probe(pattern):
        def loss(sp, xx):
            y, _, aux = apply_stage(sp, xx, cfg, pattern, positions=positions)
            return jnp.sum(y.astype(jnp.float32)) + aux

        return jax.jit(jax.grad(loss, argnums=(0, 1)))

    stage_p = jax.tree.map(lambda a: a[0], params["stages"])
    probes["stage"] = (stage_probe(cfg.pattern), (stage_p, x))
    if cfg.tail_pattern and "tail" in params:
        probes["tail"] = (stage_probe(cfg.tail_pattern), (params["tail"], x))

    head_mat = params["embed"].T if cfg.tie_embeddings else params["head"]

    def head_loss(norm_p, hm, xx):
        y = apply_norm(cfg, norm_p, xx)
        logits = (y @ hm.astype(cfg.param_dtype)).astype(jnp.float32)
        logits = softcap_logits(logits, cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    probes["head"] = (
        jax.jit(jax.grad(head_loss, argnums=(0, 1))),
        (params["final_norm"], head_mat, x),
    )
    return probes


def probe_unit_times(
    cfg, params: Pytree, batch: dict, layout, *,
    probes: dict[str, tuple[Callable, tuple]] | None = None,
    repeats: int = 2, warmup: int = 1, bwd_fraction: float = BWD_FRACTION,
) -> UnitProfile:
    """Time the unit probes and expand to a per-CommUnit seconds map.

    ``layout`` is the plan's ``ParamLayout``; every ``stage_i`` unit gets
    the (single) stage probe's time — the stages are structurally
    identical, so one measurement covers all of them while the embed /
    tail / head units carry their own.  Ready to feed
    ``MeasuredCosts.from_segment_times``.

    Pass a prebuilt ``probes`` dict (``make_unit_probes``) when probing
    repeatedly — the jit caches live on the probe callables, so reusing
    them keeps every re-probe compile-free.
    """
    if probes is None:
        probes = make_unit_probes(cfg, params, batch)
    kind_seconds = {
        kind: bwd_fraction * time_segment(fn, *args, warmup=warmup, repeats=repeats)
        for kind, (fn, args) in probes.items()
    }
    unit_seconds: dict[str, float] = {}
    for u in layout.units:
        kind = "stage" if u.name.startswith("stage_") else u.name
        if kind in kind_seconds:
            unit_seconds[u.name] = kind_seconds[kind]
    return UnitProfile(unit_seconds=unit_seconds, source="probe")


def time_group_comm(
    mesh, dp_axes: tuple[str, ...], group_nbytes, dtype=None, repeats: int = 2,
) -> tuple[float, ...]:
    """Seconds per schedule group's all-reduce: one timed psum per group
    wire payload (``sync.group_wire_bytes``, backward issue order)."""
    from ..planning.costs import MeasuredComm

    sizes = tuple(max(1, int(n)) for n in group_nbytes)
    mc = MeasuredComm.time_psums(
        mesh, tuple(dp_axes), sizes_bytes=sizes, dtype=dtype,
        repeats=repeats, name="group_comm",
    )
    return mc.times_s


class StepTimer:
    """Whole-step wall-time window with compile-step skipping.

    The train loop calls ``skip(n)`` after anything that recompiles (a
    re-plan, a restart) and ``observe(dt)`` per step; ``median()`` is the
    observed t_iter that predicted-vs-observed provenance compares
    against (``Tuner.observe``).

    ``clock`` is injectable (the FakeClock pattern the resilience tests
    use) and drives the ``start()``/``stop()`` convenience pair, so
    timing tests never sleep or race real wall clocks."""

    def __init__(self, window: int = 50, skip_first: int = 2, clock: Callable[[], float] | None = None):
        import time as _time

        self.window = window
        self.clock = clock or _time.monotonic
        self._samples: list[float] = []
        self._skip = max(0, skip_first)
        self._t0: float | None = None

    def start(self) -> None:
        """Arm the injected clock for one step (pair with ``stop``)."""
        self._t0 = self.clock()

    def stop(self) -> float:
        """Observe and return the step seconds since ``start()``."""
        if self._t0 is None:
            raise ValueError("StepTimer.stop() before start()")
        dt = self.clock() - self._t0
        self._t0 = None
        self.observe(dt)
        return dt

    def skip(self, n: int = 2) -> None:
        """Discard the next ``n`` samples (recompile ahead)."""
        self._skip = max(self._skip, n)

    def observe(self, dt: float) -> None:
        if self._skip > 0:
            self._skip -= 1
            return
        self._samples.append(float(dt))
        if len(self._samples) > self.window:
            del self._samples[: -self.window]

    def reset(self, skip_first: int = 2) -> None:
        self._samples.clear()
        self._skip = max(0, skip_first)

    def __len__(self) -> int:
        return len(self._samples)

    def median(self) -> float | None:
        """Median observed step seconds (None before any clean sample)."""
        if not self._samples:
            return None
        return statistics.median(self._samples)
