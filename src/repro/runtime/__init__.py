from .fault_tolerance import RunState, StragglerMonitor, resilient_loop
from .compression import (
    ErrorFeedbackState,
    bf16_ef_decode,
    bf16_ef_encode,
    compressed_psum_rs_ag,
    ef_init,
)
from .timeline import (
    StepTimer,
    UnitProfile,
    make_unit_probes,
    probe_unit_times,
    time_group_comm,
)

__all__ = [
    "RunState",
    "StragglerMonitor",
    "resilient_loop",
    "ErrorFeedbackState",
    "bf16_ef_decode",
    "bf16_ef_encode",
    "compressed_psum_rs_ag",
    "ef_init",
    "StepTimer",
    "UnitProfile",
    "make_unit_probes",
    "probe_unit_times",
    "time_group_comm",
]
