from .fault_tolerance import RunState, StragglerMonitor, resilient_loop
from .compression import ErrorFeedbackState, compressed_psum_rs_ag, ef_init

__all__ = [
    "RunState",
    "StragglerMonitor",
    "resilient_loop",
    "ErrorFeedbackState",
    "compressed_psum_rs_ag",
    "ef_init",
]
