"""Fault tolerance: restart loop, straggler mitigation, elasticity hooks.

On a real multi-pod deployment each of these hooks binds to the cluster
manager (GKE/Borg preemption signals, ICI health counters).  The logic —
what to do when — lives here and is deterministic and unit-tested; the
signal sources are injectable callables so the tests (and this CPU
container) simulate failures exactly.

* ``resilient_loop`` — run train steps; on failure restore the latest
  complete checkpoint and continue.  Tolerates the checkpointed step
  being mid-write (atomic rename guarantees a complete older one).
* ``StragglerMonitor`` — deadline-based detection over per-step
  durations: a step slower than ``factor`` x rolling median flags a
  straggler; after ``patience`` consecutive flags it requests remediation
  (re-shard / hot-spare swap at the cluster layer).  This implements the
  synchronous-SGD-side mitigation MG-WFBP needs: merged buckets make
  all-reduces fewer and larger, so one slow participant stalls the whole
  step — detection must be cheap and fast.
* elasticity — on restart with a different device count the MG-WFBP
  plan is recomputed (checkpoint layout is schedule-agnostic; see
  checkpoint.restore_rebucketed).  ``resilient_loop`` exposes this as
  the ``on_restart`` hook: the launcher re-runs the planning pipeline
  (``planning.replan_if_drifted`` or a fresh policy run at the new N)
  and swaps in the new train step before the loop resumes.
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from typing import Any, Callable

from ..checkpoint import AsyncCheckpointer, latest_step, restore

Pytree = Any

log = logging.getLogger(__name__)


@dataclasses.dataclass
class RunState:
    step: int
    params: Pytree
    opt_state: Pytree
    restarts: int = 0
    #: error-feedback residual pytree (compression='bf16_ef'); None for
    #: stateless runs.  Checkpointed beside params/opt_state so EF
    #: compression survives restarts.
    residual: Pytree | None = None

    def checkpoint_tree(self) -> dict:
        tree = {"params": self.params, "opt_state": self.opt_state}
        if self.residual is not None:
            tree["residual"] = self.residual
        return tree


class StragglerMonitor:
    """Deadline-based straggler detection on per-step wall times."""

    def __init__(self, factor: float = 2.0, patience: int = 3, window: int = 32):
        self.factor = factor
        self.patience = patience
        self.window = window
        self.durations: list[float] = []
        self.consecutive_slow = 0
        self.remediations = 0

    def observe(self, duration_s: float) -> bool:
        """Record one step; returns True when remediation should trigger."""
        if len(self.durations) >= 8:
            med = statistics.median(self.durations[-self.window :])
            if duration_s > self.factor * med:
                self.consecutive_slow += 1
            else:
                self.consecutive_slow = 0
        self.durations.append(duration_s)
        if self.consecutive_slow >= self.patience:
            self.consecutive_slow = 0
            self.remediations += 1
            return True
        return False


def resilient_loop(
    *,
    num_steps: int,
    init_state: Callable[[], RunState],
    train_step: Callable[[RunState, int], RunState],
    checkpoint_dir: str,
    checkpoint_every: int = 50,
    max_restarts: int = 5,
    backoff_base_s: float = 0.05,
    sleep_fn: Callable[[float], None] = time.sleep,
    fault_injector: Callable[[int], None] | None = None,
    straggler: StragglerMonitor | None = None,
    on_straggler: Callable[[RunState], RunState] | None = None,
    on_restart: Callable[[RunState], RunState] | None = None,
    plan_provider: Callable[[], Any] | None = None,
    tuner_provider: Callable[[], Any] | None = None,
) -> RunState:
    """Checkpoint/restart training loop.

    ``fault_injector(step)`` may raise to simulate a node failure;
    the loop restores the latest complete checkpoint and resumes.  The
    data pipeline needs no state file — batches are pure functions of the
    step (data/pipeline.py), so restored step ⇒ restored stream.

    ``on_restart(state)`` runs after every restore (including restarts
    from scratch) — the elasticity hook where the launcher re-plans the
    gradient-merge schedule for the post-failure cluster shape.

    Failure handling: every failure logs the full traceback with the
    failing step before the restore; ``KeyboardInterrupt``/``SystemExit``
    are never swallowed (an operator Ctrl-C must stop the run, not
    restart it); restarts back off exponentially
    (``backoff_base_s * 2**(restarts-1)``, ``sleep_fn`` injectable so
    tests pin the schedule without sleeping); and the ``restarts``
    counter saved in every checkpoint's ``extra`` dict is folded back in
    on restore, so the count — and the ``max_restarts`` budget — survive
    process death instead of resetting with each new process.

    ``plan_provider()`` returns the *currently active* ``planning.Plan``
    (or None); it is called at every checkpoint so the plan JSON lands
    beside the weights (``checkpoint.load_plan`` reads it back) — a
    callable rather than a value because online re-planning swaps the
    plan mid-run.  ``tuner_provider()`` is the same contract for the
    auto-tuner's state (``checkpoint.load_tuner_state`` /
    ``planning.Tuner.load_state``): sweep history and comm observations
    resume across restarts instead of restarting the online loop cold.
    """
    ckpt = AsyncCheckpointer(checkpoint_dir)
    state = init_state()
    restarts = 0

    while state.step < num_steps:
        try:
            t0 = time.monotonic()
            if fault_injector is not None:
                fault_injector(state.step)
            state = train_step(state, state.step)
            state.step += 1
            dt = time.monotonic() - t0
            if straggler is not None and straggler.observe(dt):
                if on_straggler is not None:
                    state = on_straggler(state)
            if state.step % checkpoint_every == 0:
                ckpt.save(
                    state.step,
                    state.checkpoint_tree(),
                    extra={"restarts": restarts},
                    plan=plan_provider() if plan_provider is not None else None,
                    tuner=tuner_provider() if tuner_provider is not None else None,
                )
        except (KeyboardInterrupt, SystemExit):
            raise  # operator interrupts stop the run, never restart it
        except Exception:
            log.exception(
                "train step %d failed; restart %d/%d from latest checkpoint",
                state.step, restarts + 1, max_restarts,
            )
            restarts += 1
            if restarts > max_restarts:
                raise
            if backoff_base_s > 0:
                sleep_fn(backoff_base_s * 2 ** (restarts - 1))
            ckpt.wait()
            step = latest_step(checkpoint_dir)
            if step is None:
                state = init_state()
                state.restarts = restarts
                if on_restart is not None:
                    state = on_restart(state)
                continue
            fresh = init_state()
            tree, extra = restore(checkpoint_dir, step, fresh.checkpoint_tree())
            # restart counts survive process death: the checkpoint's saved
            # counter (+1 for the failure just handled) floors this
            # session's count, so max_restarts budgets the run, not the
            # process
            restarts = max(restarts, int(extra.get("restarts", 0)) + 1)
            state = RunState(
                step=step,
                params=tree["params"],
                opt_state=tree["opt_state"],
                restarts=restarts,
                residual=tree.get("residual"),
            )
            if on_restart is not None:
                state = on_restart(state)
    ckpt.wait()
    state.restarts = restarts
    return state
