"""Gradient compression with error feedback.

Two honest wire formats (DESIGN.md §2 — a TPU psum cannot carry sub-16-bit
payloads, so int8 uses a reduce-scatter + quantized all-gather split):

* bf16 psum     — grads cast to bf16 on the wire (2x vs fp32); handled by
  ``core.sync.SyncConfig(compression='bf16')``.  ``bf16_ef_encode`` is
  the error-feedback variant: the rounding error of the cast stays in a
  local f32 residual and is re-added next step, so the *expected* update
  is unbiased.  ``core.sync``'s arena wire path
  (``SyncConfig(fuse='arena', compression='bf16_ef')``) fuses exactly
  this encode into the ``kernels/comm_pack`` pack kernel — these
  functions are its semantics oracle.
* int8 RS+AG    — ``compressed_psum_rs_ag``: reduce-scatter the fp grads
  (each device owns a 1/N shard of the sum), quantize the shard to int8
  with a per-shard fp32 scale, all-gather the int8 payload (4x smaller
  than an fp32 all-gather half), dequantize.  Quantization error stays
  local in an error-feedback accumulator and is re-added next step —
  the EF-SGD convergence trick [Karimireddy et al., 2019; paper's ref
  class [5][6][7]].

Total wire bytes per element: RS 4B/N·(N-1)≈4B + AG 1B·(N-1)/N ≈ 5B vs
plain fp32 all-reduce ≈ 8B — a 1.6x cut, or 3.2x against the bf16 path's
4B when combined (bf16 RS + int8 AG).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..compat import axis_size as _axis_size

Pytree = Any


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Pytree  # local quantization error, fp32


def ef_init(grads_like: Pytree) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def bf16_ef_encode(
    g: jax.Array, residual: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback bf16 wire encode: ``(wire, new_residual)``.

    ``wire = bf16(g + residual)`` and the new residual is what the cast
    dropped — the EF-SGD accumulate/quantize/carry step at fp32/bf16
    granularity.  Reference semantics for the fused arena pack.
    """
    acc = g.astype(jnp.float32) + residual.astype(jnp.float32)
    wire = acc.astype(jnp.bfloat16)
    return wire, acc - wire.astype(jnp.float32)


def bf16_ef_decode(wire: jax.Array, dtype: Any, scale=1.0) -> jax.Array:
    """Inverse of the wire encode with the DP averaging scale fused."""
    return (wire.astype(jnp.float32) * scale).astype(dtype)


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_rs_ag(
    g: jax.Array,
    axis: str | tuple[str, ...],
    residual: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """int8-wire gradient sum over a *manual* (shard_map) mesh axis.

    Returns (summed gradient replicated over ``axis``, new residual).
    Must be called inside shard_map with ``axis`` manual.  The reduce-
    scatter half runs at full precision (sums must not saturate); only
    the broadcast half is quantized, which is where the (N-1)/N of the
    volume lives.
    """
    orig_shape = g.shape
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual

    axis_size = _axis_size(axis)
    pad = (-gf.size) % axis_size
    flat = jnp.pad(gf.reshape(-1), (0, pad))
    # reduce-scatter: each rank owns shard i of the full sum
    shard = jax.lax.psum_scatter(
        flat.reshape(axis_size, -1), axis, scatter_dimension=0, tiled=False
    )
    q, scale = _quantize_int8(shard)
    deq_local = q.astype(jnp.float32) * scale  # what the others will see
    # all-gather the int8 payload + scales
    q_all = jax.lax.all_gather(q, axis, axis=0)
    s_all = jax.lax.all_gather(scale, axis, axis=0)
    full = (q_all.astype(jnp.float32) * s_all[:, None]).reshape(-1)[: gf.size]
    full = full.reshape(orig_shape)

    # error feedback: the part of MY shard the quantizer dropped
    my_err = (shard - deq_local).reshape(-1)
    # scatter back into the flat layout: residual only covers our shard;
    # keep it in shard layout broadcast to full size for simplicity
    idx = jax.lax.axis_index(axis)
    err_full = jnp.zeros_like(flat).reshape(axis_size, -1).at[idx].set(my_err)
    new_residual = err_full.reshape(-1)[: gf.size].reshape(orig_shape)
    return full, new_residual
