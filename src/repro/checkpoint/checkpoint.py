"""Checkpointing: atomic, sharded, async, schedule-agnostic.

Layout: ``<dir>/step_<k>/`` holding one ``.npz`` per pytree leaf group
plus a JSON manifest (tree structure, shapes, dtypes, step, and the mesh
it was written under).  Writes go to ``step_<k>.tmp`` and are renamed
atomically, so a crash mid-write never corrupts the latest checkpoint —
the restart loop (runtime/fault_tolerance.py) always finds a complete one.

Elasticity: checkpoints store the *full* (unsharded per-leaf) arrays in
the canonical stacked-layer layout.  A restart on a different cluster
size re-shards on load (jax.device_put against the new mesh) and
recomputes the MG-WFBP schedule for the new N — ``restore_rebucketed``
is the one-call path for that.

Plan-aware: ``save(..., plan=...)`` drops the active ``planning.Plan``
JSON beside the weights (``plan.json`` inside the step directory, same
atomic rename), and ``load_plan`` returns it — so a same-shape restart
resumes under the *exact* schedule it crashed with instead of re-running
Algorithm 1, while an elastic restart (different N) reads the old plan's
provenance and re-plans.  The weights stay schedule-agnostic either way.

The async writer snapshots device arrays to host (blocking only on the
transfer), then serializes on a background thread — the paper's
overlap-communication-with-compute philosophy applied to I/O.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

Pytree = Any

_MANIFEST = "manifest.json"
_PLAN = "plan.json"
_TUNER = "tuner.json"


def _flatten(tree: Pytree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    named = [(f"leaf_{i:05d}", np.asarray(x)) for i, x in enumerate(leaves)]
    return named, treedef


def _plan_text(plan: Any) -> str | None:
    """Serialize a plan argument: a ``planning.Plan``, a pre-serialized
    JSON string, or a JSON dict (duck-typed — checkpointing must not
    depend on the planning package)."""
    if plan is None:
        return None
    if isinstance(plan, str):
        return plan
    if hasattr(plan, "to_json"):
        return plan.to_json()
    return json.dumps(plan, indent=1)


def _tuner_text(tuner: Any) -> str | None:
    """Serialize tuner state: a ``planning.Tuner`` (via ``state_dict``),
    a pre-serialized JSON string, or a JSON dict — duck-typed like the
    plan so checkpointing stays planning-agnostic."""
    if tuner is None:
        return None
    if isinstance(tuner, str):
        return tuner
    if hasattr(tuner, "state_dict"):
        tuner = tuner.state_dict()
    return json.dumps(tuner, indent=1)


def save(
    directory: str | pathlib.Path,
    step: int,
    tree: Pytree,
    extra: dict | None = None,
    plan: Any | None = None,
    tuner: Any | None = None,
) -> pathlib.Path:
    """Atomic synchronous save; returns the final path.

    ``plan`` (a ``planning.Plan``, its JSON dict, or its JSON text) is
    written as ``plan.json`` inside the step directory under the same
    atomic rename — a checkpoint is complete with the schedule it was
    trained under.  ``tuner`` (a ``planning.Tuner``, its ``state_dict``,
    or JSON text) lands beside it as ``tuner.json`` so the auto-tuner's
    sweep history and comm observations survive restarts too."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    named, treedef = _flatten(tree)
    np.savez(tmp / "leaves.npz", **dict(named))
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(named),
        "extra": extra or {},
    }
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
    plan_text = _plan_text(plan)
    if plan_text is not None:
        (tmp / _PLAN).write_text(plan_text)
    tuner_text = _tuner_text(tuner)
    if tuner_text is not None:
        (tmp / _TUNER).write_text(tuner_text)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def load_plan(directory: str | pathlib.Path, step: int):
    """The ``planning.Plan`` stored beside checkpoint ``step`` (None when
    the checkpoint predates plan-aware saving)."""
    path = pathlib.Path(directory) / f"step_{step:08d}" / _PLAN
    if not path.exists():
        return None
    from ..planning import Plan

    return Plan.from_json(path.read_text())


def load_tuner_state(directory: str | pathlib.Path, step: int) -> dict | None:
    """The tuner state dict stored beside checkpoint ``step`` (None when
    the run was not auto-tuned); feed it to ``planning.Tuner.load_state``."""
    path = pathlib.Path(directory) / f"step_{step:08d}" / _TUNER
    if not path.exists():
        return None
    return json.loads(path.read_text())


def available_steps(directory: str | pathlib.Path) -> list[int]:
    """All *complete* checkpoint steps in ``directory``, ascending.

    A checkpoint is complete when its final (renamed) directory holds a
    manifest — ``.tmp`` directories from a write killed mid-flight are
    ignored.  The serve-side restore path walks this list newest-first so
    a corrupted latest snapshot falls back to an older complete one."""
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
            if (p / _MANIFEST).exists():  # complete checkpoints only
                steps.append(int(p.name.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str | pathlib.Path) -> int | None:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str | pathlib.Path, step: int, like: Pytree, shardings: Pytree | None = None) -> tuple[Pytree, dict]:
    """Restore into the structure of ``like`` (re-sharding via device_put
    when ``shardings`` is given — the elastic path)."""
    directory = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((directory / _MANIFEST).read_text())
    data = np.load(directory / "leaves.npz")
    leaves = [data[f"leaf_{i:05d}"] for i in range(manifest["num_leaves"])]
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    like_leaves = jax.tree.leaves(like)
    for got, want in zip(leaves, like_leaves):
        if tuple(got.shape) != tuple(want.shape):
            raise ValueError(f"checkpoint shape {got.shape} != expected {want.shape}")
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["extra"]


def restore_rebucketed(
    directory: str | pathlib.Path,
    step: int,
    like: Pytree,
    shardings: Pytree | None,
    schedule_fn,
) -> tuple[Pytree, Any, dict]:
    """Elastic restart: restore and recompute the MG-WFBP schedule for the
    *current* cluster (the checkpoint's stacked layout is schedule-free,
    so only the schedule object changes — paper Algorithm 1 reruns with
    the new N's α–β model)."""
    tree, extra = restore(directory, step, like, shardings)
    schedule = schedule_fn()
    return tree, schedule, extra


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight."""

    def __init__(self, directory: str | pathlib.Path):
        self.directory = pathlib.Path(directory)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(
        self,
        step: int,
        tree: Pytree,
        extra: dict | None = None,
        plan: Any | None = None,
        tuner: Any | None = None,
    ) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap vs serialization);
        # the plan and tuner state are serialized now too, so a re-plan or
        # a new sweep after this call cannot race the background write
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        plan_text = _plan_text(plan)
        tuner_text = _tuner_text(tuner)

        def work():
            try:
                save(self.directory, step, host_tree, extra,
                     plan=plan_text, tuner=tuner_text)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
