from .checkpoint import (
    AsyncCheckpointer,
    latest_step,
    load_plan,
    load_tuner_state,
    restore,
    restore_rebucketed,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "latest_step",
    "load_plan",
    "load_tuner_state",
    "restore",
    "restore_rebucketed",
    "save",
]
