from .checkpoint import (
    AsyncCheckpointer,
    available_steps,
    latest_step,
    load_plan,
    load_tuner_state,
    restore,
    restore_rebucketed,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "available_steps",
    "latest_step",
    "load_plan",
    "load_tuner_state",
    "restore",
    "restore_rebucketed",
    "save",
]
