from .checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore,
    restore_rebucketed,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "latest_step",
    "restore",
    "restore_rebucketed",
    "save",
]
