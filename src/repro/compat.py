"""Version shims for the jax APIs this repo uses.

The codebase targets the modern jax surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``).  Older
jaxlib builds (0.4.x, like the one baked into the CI container) expose
the same functionality under ``jax.experimental.shard_map`` / the mesh
context manager / ``jax.make_mesh`` without axis types.  Everything in
the repo goes through this module so the delta lives in one place.
"""

from __future__ import annotations

import contextlib
import functools
from typing import Any

import jax

__all__ = [
    "ensure_virtual_devices",
    "shard_map",
    "set_mesh",
    "make_mesh",
    "axis_size",
    "tpu_compiler_params",
]


def ensure_virtual_devices(n: int = 8) -> None:
    """Force ``n`` virtual CPU devices if no device count is set yet.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    unless one is already present.  Must run before jax initializes its
    backend (importing jax is fine — the flag is read on first device
    use).  The one bootstrap shared by ``launch/serve.py``,
    ``benchmarks/run.py``, and the sharded-decode examples.
    """
    import os

    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}"
    ).strip()


@functools.cache
def variadic_psum_is_single_op() -> bool:
    """Whether ``psum`` over a tuple lowers to ONE variadic all-reduce op.

    Gated on the jax version first: 0.4.x (no ``jax.shard_map``) is known
    to emit one all-reduce per operand and rely on XLA's combiner pass —
    no need to lower anything to find that out.  On modern jax the answer
    is confirmed by actually lowering a two-operand tuple psum once and
    counting the all-reduce ops; the probe (and this wrapper) are cached,
    so the cost is one tiny lowering per process, not one per plan/sync
    build as before.
    """
    if not hasattr(jax, "shard_map"):
        return False
    return _probe_variadic_psum()


@functools.cache
def _probe_variadic_psum() -> bool:
    """Lower ``psum((a, b), axis)`` on a 1-device mesh and count ops."""
    mesh = make_mesh((1,), ("_probe",))
    P = jax.sharding.PartitionSpec

    def body(x, y):
        return jax.lax.psum((x, y), "_probe")

    f = shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        axis_names={"_probe"}, check_vma=False,
    )
    import jax.numpy as jnp

    text = jax.jit(f).lower(jnp.zeros((8,)), jnp.zeros((4,))).as_text()
    return text.count("all_reduce") + text.count("all-reduce") <= 1


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (modern) / ``pltpu.TPUCompilerParams``
    (0.4.x) — same fields, renamed class."""
    import jax.experimental.pallas.tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def axis_size(axis_name: str):
    """Size of a manual mesh axis from inside shard_map.

    Modern jax: ``jax.lax.axis_size``.  Legacy: ``psum(1, axis)``, which
    constant-folds to the same static integer.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    axis_names: set[str] | None = None,
    check_vma: bool = False,
):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``.

    ``axis_names``/``check_vma`` follow the modern signature; the legacy
    path maps ``check_vma`` onto ``check_rep`` and treats every mesh axis
    as manual (``axis_names`` ignored).  Partial-manual (``auto=``) on
    0.4.x trips an XLA-CPU SpmdPartitioner abort on scanned bodies; for
    this repo's usage fully-manual is numerically identical because the
    non-DP axes carry no explicit collectives inside the body — they just
    lose GSPMD sharding, i.e. replicate model-axis compute.
    """
    if hasattr(jax, "shard_map"):
        kwargs: dict[str, Any] = {}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def set_mesh(mesh):
    """Context manager selecting ``mesh`` as the ambient mesh.

    Modern jax: ``jax.set_mesh``.  Legacy jax has no sharding-typed
    ambient mesh; entering the ``Mesh`` object itself provides the
    closest equivalent (and is a no-op for fully-explicit jit calls,
    which is how every call site in this repo passes shardings).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext()


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with all axes Auto-typed when supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)
