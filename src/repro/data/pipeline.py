"""Deterministic, resumable, sharded synthetic data pipeline.

Design mirrors a production token pipeline:

  * every batch is a pure function of ``(seed, step)`` — restart at step k
    reproduces the exact stream with no state files (the checkpoint only
    stores the step counter);
  * per-host sharding: each data-parallel rank draws only its rows
    (``host_batch_slice``), so no host materializes the global batch;
  * background prefetch with a bounded queue overlaps host data generation
    with device compute (double-buffering);
  * the synthetic distribution is a mixture of Zipfian unigrams and
    repeated n-grams so the LM loss actually decreases during the examples
    (pure-uniform tokens would pin the loss at log V).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_repeat: int = 8  # period of the repeated motif (learnable signal)
    input_mode: str = "tokens"  # 'tokens' | 'embeds'
    d_model: int = 0  # for embeds mode


class SyntheticLMStream:
    """Stateless-per-step synthetic LM batches."""

    def __init__(self, cfg: DataConfig, host_rank: int = 0, host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_rank = host_rank
        self.host_count = host_count
        self.host_batch = cfg.global_batch // host_count
        # fixed Zipf unigram table (shared across hosts)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._motif = rng.integers(0, cfg.vocab, size=cfg.ngram_repeat)

    def batch_at(self, step: int) -> dict:
        """The batch for ``step`` — pure function of (seed, step, rank)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.host_rank
        )
        B, S = self.host_batch, cfg.seq_len
        base = rng.choice(cfg.vocab, size=(B, S + 1), p=self._probs)
        # overlay the repeated motif on a random half of rows: predictable
        # structure the model can learn within a few hundred steps
        motif_rows = rng.random(B) < 0.5
        reps = int(np.ceil((S + 1) / cfg.ngram_repeat))
        motif = np.tile(self._motif, reps)[: S + 1]
        base[motif_rows] = motif
        tokens = base[:, :-1].astype(np.int32)
        targets = base[:, 1:].astype(np.int32)
        out = {"targets": targets}
        if cfg.input_mode == "embeds":
            emb_rng = np.random.default_rng(cfg.seed + 7)
            table = emb_rng.standard_normal((cfg.vocab, cfg.d_model)).astype(np.float32) * 0.02
            out["embeds"] = table[tokens]
        else:
            out["tokens"] = tokens
        return out

    def iterate(self, start_step: int = 0, prefetch: int = 2) -> Iterator[dict]:
        """Background-prefetched iterator resuming from ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.1)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_stream(cfg: DataConfig, host_rank: int = 0, host_count: int = 1) -> SyntheticLMStream:
    return SyntheticLMStream(cfg, host_rank, host_count)
