"""The ``measured`` fabric: live timed-collective fits behind the same
registry surface as the analytic presets.

``MeasuredFabric`` wraps per-axis all-reduce fits — typically
``planning.MeasuredComm.time_psums(...).fit()`` per mesh axis (journal
§V-A Fig. 5(b), online) — and serves them through ``cost(op,
axis_sizes)``.  Ops other than all-reduce are derived from the measured
all-reduce by the ring decomposition (all-reduce = reduce-scatter ∘
all-gather, each one phase: half the startup, half the slope) — honest
for ring backends, and exactly the approximation the analytic algebra
makes in reverse.  A direct fit for a specific op can be stored under
``'<op>@<axes>'`` to override the derivation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from ..core.comm_model import AllReduceModel
from .model import Collective


def _axes_key(axis_sizes: dict[str, int]) -> str:
    """Canonical lookup key: '+'-joined axis names, sorted.

    Axis *names* (not sizes) select the fit — a sweep timed over the
    ``data`` axis is the ``data`` model whatever the virtual world size,
    because the fit already bakes in the real topology it ran on.
    """
    axes = sorted(axis_sizes)
    return "+".join(axes) if axes else "*"


@dataclasses.dataclass(frozen=True)
class MeasuredFabric:
    """Fitted (α, β) constants served through the ``Fabric`` protocol.

    ``models`` maps an axes key (``'data'``, ``'data+pod'``, or ``'*'``
    as a catch-all) to that axis set's measured *all-reduce* fit; op-
    specific overrides use ``'all_gather@data'``-style keys.
    """

    models: dict[str, AllReduceModel]
    name: str = "measured"

    @classmethod
    def from_comm(cls, *comms: Any, name: str = "measured") -> "MeasuredFabric":
        """Build from ``MeasuredComm``-like records (anything with
        ``.axes`` and ``.fit() -> AllReduceModel``)."""
        models = {"+".join(sorted(c.axes)): c.fit() for c in comms}
        return cls(models=models, name=name)

    def with_fits(self, fits: dict[str, AllReduceModel]) -> "MeasuredFabric":
        """New fabric with ``fits`` merged in — op-specific keys
        (``'all_gather@model'``, e.g. from
        ``planning.serve_fabric_fits``) override the ring derivation for
        that op; axes keys replace the base all-reduce fit."""
        return dataclasses.replace(self, models={**self.models, **fits})

    def cost(self, op: Collective | str, axis_sizes: dict[str, int]) -> AllReduceModel:
        op = Collective(op)
        key = _axes_key(axis_sizes)
        fit = self.models.get(f"{op.value}@{key}")
        if fit is not None:
            return dataclasses.replace(fit, name=f"{self.name}:{op.value}")
        fit = self.models.get(key, self.models.get("*"))
        if fit is None:
            known = ", ".join(sorted(self.models))
            raise KeyError(f"no measured fit for axes {key!r}; have: {known}")
        if op is not Collective.ALL_REDUCE:
            # one ring phase of the measured two-phase all-reduce
            fit = AllReduceModel(a=fit.a / 2, b=fit.b / 2, name=fit.name)
        return AllReduceModel(a=fit.a, b=fit.b, name=f"{self.name}:{op.value}")
