"""Backend-preset registry: one name -> one ``Fabric``.

Mirrors ``planning.registry`` (the scheduler-policy registry): presets
register under a name, consumers select with a ``--fabric`` flag, and
``get_fabric`` also passes live ``Fabric`` instances straight through so
a freshly fitted ``MeasuredFabric`` slots into the same call sites as a
named analytic preset.
"""

from __future__ import annotations

from typing import Callable, TypeVar

from .model import Fabric

F = TypeVar("F", bound=Fabric)

_FABRICS: dict[str, Fabric] = {}


def register_fabric(
    name: str, fabric: Fabric | None = None, *, overwrite: bool = False
) -> Fabric | Callable[[F], F]:
    """Register ``fabric`` under ``name``.

    Usable directly (``register_fabric("measured", my_fabric)``) or as a
    decorator on a zero-arg factory/class whose instance becomes the
    preset.  Duplicate names raise unless ``overwrite=True`` (re-fitting
    a measured fabric overwrites deliberately).
    """
    if fabric is not None:
        if name in _FABRICS and not overwrite:
            raise ValueError(f"fabric {name!r} already registered")
        _FABRICS[name] = fabric
        return fabric

    def deco(obj: F) -> F:
        register_fabric(name, obj() if isinstance(obj, type) else obj, overwrite=overwrite)
        return obj

    return deco


def get_fabric(name: str | Fabric) -> Fabric:
    """Resolve a preset name (or pass a live instance through).

    Example::

        >>> get_fabric("gpu_nccl").cost("all_reduce", {"data": 8}).a > 0
        True
    """
    if not isinstance(name, str):
        if not hasattr(name, "cost"):
            raise TypeError(f"not a Fabric (no .cost): {type(name).__name__}")
        return name
    if name not in _FABRICS:
        known = ", ".join(sorted(_FABRICS))
        raise KeyError(f"unknown fabric {name!r}; known: {known}")
    return _FABRICS[name]


def available_fabrics() -> list[str]:
    """Registered preset names as a sorted list — directly usable as
    argparse ``choices`` and always in stable display order."""
    return sorted(_FABRICS)
