"""Hierarchical tree/pipeline reduction fabrics (Wang & Vuduc).

Wang & Vuduc (arXiv 1611.04255, PAPERS.md) price large-fleet reductions
with per-tier *algorithm* choices: a latency-bound tier wants a
binary-tree reduction (startup ``O(log N)`` instead of the ring's
``O(N)``), a bandwidth-bound tier with large messages wants a
*pipelined* tree (segment the message into k chunks so tree hops
overlap, buying back the tree's ``log N`` bandwidth penalty).  This
module adds exactly that degree of freedom to the existing two-tier
composition:

``HierarchicalFabric`` is a ``RingInterconnect`` whose all-reduce
algorithm is selectable per tier (``ici_algo`` / ``dcn_algo`` in
{'ring', 'tree', 'pipeline'}); every other collective and the tier
composition itself (later tiers price a shrunken shard) are inherited
unchanged, so the presets slot into every ``--fabric`` call site.

Algorithm models (paper Table II + the pipelined tree):

  ring      : a = 2(n-1)α              b = (2(n-1)/n)β + ((n-1)/n)γ
  tree      : a = 2α·lg n              b = (2β + γ)·lg n
  pipeline  : affine fit of min_k 2(k + ⌈lg n⌉ - 1)(α + (M/k)(β + γ/2))
              over the standard probe sweep — startup stays O(lg n)
              while the bandwidth term approaches 2β + γ, independent
              of n (the Wang & Vuduc large-message asymptote).

Crossover intuition the simulator exploits: at 10GbE constants, the
tree beats the ring on startup for any fleet over a few nodes (45 µs x
2(N-1) vs x 2 lg N), while its bandwidth term loses at large messages;
the pipelined tree keeps the tree's startup *and* ring-class bandwidth
— which is why it wins the 512-host what-if cells in BENCH_sim.json.
"""

from __future__ import annotations

import dataclasses
import math

from ..core.comm_model import AllReduceModel, binary_tree, fit_affine, ring
from .model import RingInterconnect
from .registry import register_fabric

#: Per-tier all-reduce algorithm choices a HierarchicalFabric accepts.
TIER_ALGOS = ("ring", "tree", "pipeline")

#: Message sizes the pipelined-tree model is affine-fitted over — the
#: same 4 KiB..128 MiB sweep ``planning.costs.DEFAULT_COMM_SWEEP`` probes
#: (duplicated here: the fabric layer cannot import planning).
DEFAULT_PIPELINE_FIT_SWEEP = tuple(4 * 1024 * 8**i for i in range(6))


def pipeline_tree(
    n: int,
    alpha: float,
    beta: float,
    gamma: float,
    fit_sizes: tuple[int, ...] = DEFAULT_PIPELINE_FIT_SWEEP,
) -> AllReduceModel:
    """Pipelined binary-tree all-reduce as an affine (a, b) model.

    The exact cost of reducing ``M`` bytes up a depth-⌈lg n⌉ tree and
    broadcasting back down, with the message segmented into ``k`` chunks
    so hops overlap, is ``T(M, k) = 2 (k + c)(α + (M/k)(β + γ/2))`` with
    ``c = ⌈lg n⌉ - 1``; the optimal segment count is ``k* = sqrt(M c (β
    + γ/2) / α)`` (clamped to >= 1).  ``T(M, k*)`` is concave in ``M``
    (a sqrt term), so it is least-squares fitted over the standard probe
    sweep into the affine currency every policy consumes — the same
    ``fit_affine`` treatment a measured fabric gets."""
    if n <= 1:
        return AllReduceModel(a=0.0, b=0.0, name="noop")
    c = max(0, math.ceil(math.log2(n)) - 1)
    s = beta + gamma / 2.0

    def exact(m: float) -> float:
        k = max(1.0, math.sqrt(m * c * s / alpha)) if alpha > 0 and c > 0 else 1.0
        return 2.0 * (k + c) * (alpha + (m / k) * s)

    model = fit_affine(
        fit_sizes, [exact(m) for m in fit_sizes], name="pipeline_tree"
    )
    # tiny-sweep degeneracy guard: the schedule algebra needs a, b > 0
    a = model.a if model.a > 0 else 2.0 * (1 + c) * alpha
    return AllReduceModel(a=a, b=max(model.b, 2.0 * s), name="pipeline_tree")


@dataclasses.dataclass(frozen=True)
class HierarchicalFabric(RingInterconnect):
    """Two-tier fabric with per-tier all-reduce algorithm selection.

    Inherits every ``RingInterconnect`` constant and its hierarchical
    composition (fast axes first, the ``'pod'`` tier pricing a
    ``1/ici_size`` shard); only the per-tier all-reduce model is swapped
    per ``ici_algo``/``dcn_algo`` ('ring' | 'tree' | 'pipeline').
    Single-phase collectives (reduce-scatter / all-gather / all-to-all)
    ride the inherited ring algebra — tree variants of those are not in
    the Wang & Vuduc treatment and no plan schedules them on these
    presets."""

    ici_algo: str = "tree"
    dcn_algo: str = "tree"
    name: str = "hierarchical"

    def __post_init__(self) -> None:
        for algo in (self.ici_algo, self.dcn_algo):
            if algo not in TIER_ALGOS:
                raise ValueError(
                    f"unknown tier algorithm {algo!r}; known: {TIER_ALGOS}"
                )

    def _tier_allreduce(self, algo: str, n: int, pod: bool) -> AllReduceModel:
        if n <= 1:
            return AllReduceModel(a=0.0, b=0.0, name="noop")
        alpha, beta = self._tier(pod)
        if algo == "ring":
            m = ring(n, alpha, beta, self.gamma)
        elif algo == "tree":
            m = binary_tree(n, alpha, beta, self.gamma)
        else:
            m = pipeline_tree(n, alpha, beta, self.gamma)
        return AllReduceModel(
            a=m.a + self.fixed_overhead, b=m.b, name=f"{'dcn' if pod else 'ici'}_{algo}"
        )

    def ring_axis(self, n: int) -> AllReduceModel:
        """Fast-tier all-reduce phase priced by ``ici_algo``."""
        return self._tier_allreduce(self.ici_algo, n, pod=False)

    def dcn_allreduce(self, n_pods: int) -> AllReduceModel:
        """Cross-pod all-reduce phase priced by ``dcn_algo``."""
        return self._tier_allreduce(self.dcn_algo, n_pods, pod=True)


def _paper_constants() -> dict[str, float]:
    from ..core.comm_model import PAPER_10GBE_ALPHA, PAPER_10GBE_BETA, PAPER_GAMMA

    return dict(
        ici_link_bw=1.0 / PAPER_10GBE_BETA,
        ici_alpha=PAPER_10GBE_ALPHA,
        n_rings=1,
        dcn_bw=1.0 / PAPER_10GBE_BETA,
        dcn_alpha=PAPER_10GBE_ALPHA,
        fixed_overhead=0.0,
        gamma=PAPER_GAMMA,
    )


#: Paper's 10GbE constants with binary-tree reduction on the flat tier:
#: startup O(lg N) instead of the ring's O(N) — the latency-bound regime.
TREE_10GBE = HierarchicalFabric(
    **_paper_constants(), ici_algo="tree", dcn_algo="tree", name="tree_10gbe"
)
#: Paper's 10GbE constants with the pipelined tree: O(lg N) startup AND
#: ring-class bandwidth — Wang & Vuduc's large-fleet workhorse.
PIPELINE_10GBE = HierarchicalFabric(
    **_paper_constants(), ici_algo="pipeline", dcn_algo="pipeline",
    name="pipeline_10gbe",
)
#: TPU v5e ICI rings (a torus is a ring fabric) + a pipelined-tree DCN
#: tier: the two-tier shape a 512-host multi-pod what-if prices.
TPU_V5E_TREE_DCN = HierarchicalFabric(
    ici_algo="ring", dcn_algo="pipeline", name="tpu_v5e_tree_dcn"
)

register_fabric("tree_10gbe", TREE_10GBE)
register_fabric("pipeline_10gbe", PIPELINE_10GBE)
register_fabric("tpu_v5e_tree_dcn", TPU_V5E_TREE_DCN)

__all__ = [
    "HierarchicalFabric",
    "PIPELINE_10GBE",
    "TIER_ALGOS",
    "TREE_10GBE",
    "TPU_V5E_TREE_DCN",
    "pipeline_tree",
]
