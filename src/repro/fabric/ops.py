"""Typed collective issuing: the one place a ``Collective`` becomes a
``jax.lax`` primitive.

Both wire paths route through here — the training sync
(``core/sync.py``: every gradient psum) and the serve-side group
collectives (``planning/serve.py``: KV all-gathers, expert all-to-alls)
— so the op vocabulary the planner schedules is the op vocabulary the
compiler sees, with no ad-hoc ``jax.lax.*`` calls scattered per caller.
"""

from __future__ import annotations

from typing import Any

import jax

from .model import Collective


def issue(op: Collective | str, value: Any, axis: str | tuple[str, ...], **kwargs: Any):
    """Issue one collective inside a ``shard_map`` manual region.

    ``value`` may be a pytree for ``all_reduce`` (variadic psum); the
    gather/scatter/all-to-all ops take a single array.  ``kwargs`` pass
    through to the underlying primitive (``tiled``, ``split_axis``, ...).
    """
    op = Collective(op)
    if op is Collective.ALL_REDUCE:
        return jax.lax.psum(value, axis, **kwargs)
    if op is Collective.ALL_GATHER:
        return jax.lax.all_gather(value, axis, **kwargs)
    if op is Collective.REDUCE_SCATTER:
        return jax.lax.psum_scatter(value, axis, **kwargs)
    kwargs.setdefault("split_axis", 0)
    kwargs.setdefault("concat_axis", 0)
    return jax.lax.all_to_all(value, axis, **kwargs)
