"""Analytic backend presets: one registry serves TPU/GPU/CPU clusters.

Constants are per-preset beliefs, not measurements — the ``measured``
path (``MeasuredFabric``) replaces any of them with live timed-collective
fits through the exact same registry surface.

  tpu_v5e     — TPU v5e ICI (2-D torus, 50 GB/s/link, ~1 µs/hop) + DCN
                cross-pod tier: the historical ``TpuInterconnect``
                constants, absorbed (``core.comm_model`` re-exports this
                preset under the old names).
  gpu_nccl    — NVLink-class intra-node tier (~200 GB/s effective ring
                bandwidth, NCCL kernel-launch overhead) + 400 Gb/s-class
                IB/RoCE 'pod' tier: the DGX-pod shape NCCL rings assume.
  dcn_only    — no fast tier at all: every axis rides 100 GbE-class
                datacenter ethernet (CPU clusters, spot fleets).
  paper_10gbe — the paper's own measured environment (§V-A): 8-node K80
                cluster on 10GbE MPI — the Das et al. synchronous-SGD
                setting; ``cost('all_reduce', {'data': N})`` reproduces
                ``comm_model.paper_cluster_model(N)`` exactly.
"""

from __future__ import annotations

from ..core.comm_model import (
    PAPER_10GBE_ALPHA,
    PAPER_10GBE_BETA,
    PAPER_GAMMA,
    AllReduceModel,
)
from .model import Collective, RingInterconnect
from .registry import register_fabric

#: Back-compat alias: the old ``core.comm_model.TpuInterconnect`` class IS
#: the generic two-tier ring fabric (same fields, same defaults).
TpuInterconnect = RingInterconnect

#: Default interconnect for the production mesh in launch/mesh.py — the
#: object ``core.comm_model.TPU_V5E`` has always been.
TPU_V5E = RingInterconnect(name="tpu_v5e")

GPU_NCCL = RingInterconnect(
    ici_link_bw=200e9,  # NVLink ring effective per-direction
    ici_alpha=3e-6,  # NCCL per-hop latency
    n_rings=1,
    dcn_bw=50e9,  # 400 Gb/s IB/RoCE per node
    dcn_alpha=20e-6,
    fixed_overhead=10e-6,  # CUDA kernel launch + NCCL channel setup
    gamma=1.0 / 1500e9,  # HBM-speed local reduction
    name="gpu_nccl",
)

DCN_ONLY = RingInterconnect(
    ici_link_bw=12.5e9,  # 100 GbE
    ici_alpha=25e-6,
    n_rings=1,
    dcn_bw=12.5e9,
    dcn_alpha=100e-6,
    fixed_overhead=20e-6,
    gamma=1.0 / 200e9,  # CPU-socket reduction bandwidth
    name="dcn_only",
)

PAPER_10GBE = RingInterconnect(
    ici_link_bw=1.0 / PAPER_10GBE_BETA,  # ≈ 1.07 GB/s payload bandwidth
    ici_alpha=PAPER_10GBE_ALPHA,
    n_rings=1,
    dcn_bw=1.0 / PAPER_10GBE_BETA,  # one flat 10GbE tier
    dcn_alpha=PAPER_10GBE_ALPHA,
    fixed_overhead=0.0,  # the paper's fit folds software overhead into α
    gamma=PAPER_GAMMA,
    name="paper_10gbe",
)

register_fabric("tpu_v5e", TPU_V5E)
register_fabric("gpu_nccl", GPU_NCCL)
register_fabric("dcn_only", DCN_ONLY)
register_fabric("paper_10gbe", PAPER_10GBE)


def tpu_psum_model(axis_sizes: dict[str, int]) -> AllReduceModel:
    """Historical convenience wrapper: the ``tpu_v5e`` preset's effective
    all-reduce model for ``axis_sizes`` (re-exported by ``core.comm_model``)."""
    return TPU_V5E.psum_model(axis_sizes)


__all__ = [
    "Collective",
    "DCN_ONLY",
    "GPU_NCCL",
    "PAPER_10GBE",
    "TPU_V5E",
    "TpuInterconnect",
    "tpu_psum_model",
]
