"""The Fabric surface: typed collectives + per-op affine cost algebra.

The paper's cost model (Eq. 9, Table II) is an *affine* map ``T(M) = a +
b·M`` derived from the point-to-point primitives (α, β, γ).  Nothing in
that algebra is all-reduce-specific: Table II's derivation applies to any
ring-style collective phase, and Wang & Vuduc (PAPERS.md) run the same
affine treatment for gather/scatter-style collectives.  This module makes
that explicit:

  * ``Collective`` — the typed op vocabulary the planner schedules:
    ``all_reduce`` | ``reduce_scatter`` | ``all_gather`` | ``all_to_all``;
  * ``Fabric``     — the protocol every backend preset implements:
    ``fabric.cost(op, axis_sizes) -> AllReduceModel`` (the affine model
    every policy/Plan already consumes — the *currency* is unchanged,
    only its *source* is now pluggable);
  * ``RingInterconnect`` — the generic two-tier analytic fabric: ring
    collectives on the fast per-axis tier (ICI / NVLink / node-local
    ethernet) plus a ``'pod'`` axis on the slow cross-cluster tier (DCN /
    IB), composed hierarchically exactly like the historical
    ``TpuInterconnect.psum_model`` (which this class absorbs — the
    ``tpu_v5e`` preset in ``presets.py`` IS a ``RingInterconnect`` with
    the TPU constants, and ``core.comm_model`` re-exports it under the
    old names).

Per-phase algebra (ring over one axis of size ``n``):

    reduce_scatter : a = (n-1)·α          b = (n-1)/n · (β + γ)
    all_gather     : a = (n-1)·α          b = (n-1)/n · β
    all_reduce     : reduce_scatter ∘ all_gather  (Table II row 4)
    all_to_all     : a = (n-1)·α          b = (n-1)/n · β

``fixed_overhead`` (dispatch / fusion-barrier cost) is charged per phase
— half for the single-phase ops, whole for the two-phase all-reduce — so
``reduce_scatter + all_gather`` composes to *exactly* the all-reduce
model, and the hierarchical identity

    rs(ici) ⊕ ar(pod, M/ici) ⊕ ag(ici)  ==  psum_model({ici, pod})

holds to the last bit (pinned by ``tests/test_fabric.py``).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Protocol, runtime_checkable

from ..core.comm_model import AllReduceModel, ring


class Collective(str, enum.Enum):
    """The typed collective vocabulary the planner can schedule."""

    ALL_REDUCE = "all_reduce"
    REDUCE_SCATTER = "reduce_scatter"
    ALL_GATHER = "all_gather"
    ALL_TO_ALL = "all_to_all"

    def __str__(self) -> str:  # 'all_gather', not 'Collective.ALL_GATHER'
        return self.value


@runtime_checkable
class Fabric(Protocol):
    """A backend interconnect: per-op affine cost models from one place.

    ``cost`` returns the ordinary ``AllReduceModel`` (a, b) pair for one
    collective over the given mesh axes — the same object every scheduler
    policy, ``Plan``, and ``ServePlan`` already consumes, so a fabric
    swap never touches the merge math.
    """

    name: str

    def cost(
        self, op: Collective | str, axis_sizes: dict[str, int]
    ) -> AllReduceModel: ...


@dataclasses.dataclass(frozen=True)
class RingInterconnect:
    """Generic two-tier ring fabric (absorbs the old ``TpuInterconnect``).

    Field names keep the historical TPU vocabulary (``ici_*`` = the fast
    per-axis tier, ``dcn_*`` = the cross-``'pod'`` tier) so the
    ``core.comm_model.TpuInterconnect`` shim is this exact class; presets
    for GPU/NCCL or flat-ethernet clusters just move the constants.

    ici_link_bw   : per-link, per-direction fast-tier bandwidth (B/s)
    ici_alpha     : per-hop fast-tier latency (s)
    n_rings       : parallel rings on the fast tier (multiplies bw)
    dcn_bw        : cross-pod bandwidth per pod (B/s)
    dcn_alpha     : cross-pod startup (s)
    fixed_overhead: per-collective software overhead (dispatch, fusion
                    barrier), charged per ring *phase* (s)
    gamma         : reduction time per byte on one node (s/B)
    """

    ici_link_bw: float = 50e9  # 50 GB/s/link  (TPU v5e ICI)
    ici_alpha: float = 1e-6
    n_rings: int = 1
    dcn_bw: float = 25e9
    dcn_alpha: float = 50e-6
    fixed_overhead: float = 5e-6
    # gamma: on-chip reduce is VPU-bound but effectively free vs the wire;
    # modeled at HBM speed.
    gamma: float = 1.0 / 819e9
    name: str = "tpu_v5e"

    # -- per-axis models ----------------------------------------------------

    def _tier(self, pod: bool) -> tuple[float, float]:
        """(α, β) of one tier."""
        if pod:
            return self.dcn_alpha, 1.0 / self.dcn_bw
        return self.ici_alpha, 1.0 / (self.ici_link_bw * self.n_rings)

    def ring_axis(self, n: int) -> AllReduceModel:
        """Ring all-reduce over one fast-tier mesh axis of size ``n``."""
        if n <= 1:
            return AllReduceModel(a=0.0, b=0.0, name="noop")
        alpha, beta = self._tier(pod=False)
        m = ring(n, alpha, beta, self.gamma)
        return AllReduceModel(a=m.a + self.fixed_overhead, b=m.b, name="ici_ring")

    def dcn_allreduce(self, n_pods: int) -> AllReduceModel:
        """Ring all-reduce across ``n_pods`` pods over the slow tier."""
        if n_pods <= 1:
            return AllReduceModel(a=0.0, b=0.0, name="noop")
        alpha, beta = self._tier(pod=True)
        m = ring(n_pods, alpha, beta, self.gamma)
        return AllReduceModel(a=m.a + self.fixed_overhead, b=m.b, name="dcn_ring")

    def _axis_model(self, op: Collective, n: int, pod: bool) -> AllReduceModel:
        """Affine model of one collective phase over one axis of size ``n``."""
        if n <= 1:
            return AllReduceModel(a=0.0, b=0.0, name="noop")
        if op is Collective.ALL_REDUCE:
            return self.dcn_allreduce(n) if pod else self.ring_axis(n)
        alpha, beta = self._tier(pod)
        frac = (n - 1) / n
        if op is Collective.REDUCE_SCATTER:
            b = frac * (beta + self.gamma)
        else:  # all_gather / all_to_all: pure transmission, no reduction
            b = frac * beta
        # single-phase ops carry half the dispatch overhead so that
        # reduce_scatter + all_gather == all_reduce exactly (module doc)
        return AllReduceModel(
            a=(n - 1) * alpha + self.fixed_overhead / 2, b=b, name=op.value
        )

    # -- the Fabric surface -------------------------------------------------

    def cost(self, op: Collective | str, axis_sizes: dict[str, int]) -> AllReduceModel:
        """Effective (a, b) for ``op`` over the given mesh axes.

        Hierarchical composition (identical to the historical
        ``psum_model``): fast-tier axes are composed as rings with phase
        ``i`` pricing ``1/prod(earlier fast sizes)`` of the message and
        the ``'pod'`` tier pricing ``1/ici_size`` of it.  For the
        scatter direction (all_reduce / reduce_scatter) that is the
        usual "later phases see shrunken shards"; for ``all_gather`` the
        same per-axis fractions describe the mirrored optimal phase
        order — the slow tier gathers first while the data is still
        scattered, the fast tier finishes at full volume — so ``rs + ag
        == all_reduce`` composes tier by tier.  ``all_to_all`` data
        never shrinks (each phase reshuffles the full local volume), so
        every tier prices the whole message.
        """
        op = Collective(op)
        a_total, b_total = 0.0, 0.0
        ici_size = 1
        for axis, n in axis_sizes.items():
            if axis == "pod" or n <= 1:
                continue
            m = self._axis_model(op, n, pod=False)
            a_total += m.a
            b_total += m.b / (1 if op is Collective.ALL_TO_ALL else ici_size)
            ici_size *= n
        n_pods = axis_sizes.get("pod", 1)
        if n_pods > 1:
            m = self._axis_model(op, n_pods, pod=True)
            a_total += m.a
            b_total += m.b / (1 if op is Collective.ALL_TO_ALL else ici_size)
        return AllReduceModel(a=a_total, b=b_total, name=f"{self.name}:{op.value}")

    def psum_model(self, axis_sizes: dict[str, int]) -> AllReduceModel:
        """Historical entry point: effective all-reduce (a, b) for a psum
        over ``axis_sizes`` — kept name-compatible with the old
        ``TpuInterconnect.psum_model`` (``tests/test_fabric.py`` pins the
        two surfaces identical)."""
        m = self.cost(Collective.ALL_REDUCE, axis_sizes)
        return AllReduceModel(a=m.a, b=m.b, name="tpu_psum")
