"""The Fabric API: typed collectives + backend-preset registry.

One registry serves TPU/GPU/CPU clusters for both training and decode:

    from repro.fabric import get_fabric, Collective

    fabric = get_fabric("gpu_nccl")            # or tpu_v5e | dcn_only |
                                               #    paper_10gbe | a live
                                               #    MeasuredFabric
    ar = fabric.cost(Collective.ALL_REDUCE, {"data": 32})   # AllReduceModel
    ag = fabric.cost("all_gather", {"model": 16})

``cost`` returns the ordinary affine ``AllReduceModel`` — the currency
every scheduler policy, ``Plan``, and ``ServePlan`` consumes — so the
whole merge-scheduling stack (Eq. 9/10) is collective- and
backend-agnostic.  ``fabric.ops.issue`` is the executable counterpart:
the single seam where a scheduled ``Collective`` becomes a ``jax.lax``
primitive (used by the training sync and the serve wire alike).
"""

from .hierarchical import (
    PIPELINE_10GBE,
    TPU_V5E_TREE_DCN,
    TREE_10GBE,
    HierarchicalFabric,
)
from .measured import MeasuredFabric
from .model import Collective, Fabric, RingInterconnect
from .ops import issue
from .presets import DCN_ONLY, GPU_NCCL, PAPER_10GBE, TPU_V5E, TpuInterconnect
from .registry import available_fabrics, get_fabric, register_fabric

__all__ = [
    "Collective",
    "DCN_ONLY",
    "Fabric",
    "GPU_NCCL",
    "HierarchicalFabric",
    "MeasuredFabric",
    "PAPER_10GBE",
    "PIPELINE_10GBE",
    "RingInterconnect",
    "TPU_V5E",
    "TPU_V5E_TREE_DCN",
    "TREE_10GBE",
    "TpuInterconnect",
    "available_fabrics",
    "get_fabric",
    "issue",
    "register_fabric",
]
