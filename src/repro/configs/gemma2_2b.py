"""Gemma2-2B [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) head_dim=256 d_ff=9216 vocab=256000.
Alternating local(4096)/global attention, GeGLU, gemma RMSNorm (scale+1)
with pre+post block norms, attention-logit softcap 50, final-logit softcap
30, tied embeddings.
"""

from repro.models.common import ArchConfig, Attention


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        d_ff=9216,
        vocab=256000,
        attention=Attention(
            n_heads=8, n_kv_heads=4, head_dim=256, softcap=50.0, rope_theta=10000.0
        ),
        pattern=("attn_local", "attn_global"),
        local_window=4096,
        norm="rmsnorm_gemma",
        post_norm=True,
        mlp="geglu",
        tie_embeddings=True,
        logit_softcap=30.0,
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="gemma2-2b-reduced",
        n_layers=4,
        d_model=128,
        d_ff=512,
        vocab=512,
        attention=Attention(n_heads=4, n_kv_heads=2, head_dim=32, softcap=50.0),
        local_window=64,
        q_chunk=32,
    )
