"""Layer profiles of the paper's own CNNs — GoogleNet and ResNet-50 —
used by the benchmark harness to reproduce Figs. 5(a), 6–9 through the
timeline simulator.

Each profile is an ordered list (forward order, paper layer 1..L) of
``(name, params, fwd_flops_per_image)``; backward flops are modeled as
2x forward (weight grads + input grads), the paper's Eq. 18 regime.
BatchNorm/scale/bias parameters are folded into their conv's message
(Caffe communicates them adjacently; they are <1% of the payload).

Parameter totals reproduce the paper's numbers: GoogleNet ≈13M (bvlc
googlenet ~7.0M + two auxiliary classifiers ~3.2M each, which Caffe
trains with and therefore communicates), ResNet-50 ≈25.5M.
"""

from __future__ import annotations

from ..core.cost_model import LayerCost


def _conv(name, cin, cout, k, hw, stride=1, params_extra=0):
    """(name, params, fwd_flops) for a conv producing hw x hw output."""
    params = cin * cout * k * k + cout + params_extra  # + bias (+bn folded)
    out_hw = hw // stride
    flops = 2.0 * out_hw * out_hw * cin * cout * k * k
    return (name, params, flops)


def _fc(name, cin, cout):
    return (name, cin * cout + cout, 2.0 * cin * cout)


def googlenet_layers() -> list[tuple[str, int, float]]:
    L: list[tuple[str, int, float]] = []
    L.append(_conv("conv1/7x7_s2", 3, 64, 7, 224, stride=2, params_extra=128))
    L.append(_conv("conv2/3x3_reduce", 64, 64, 1, 56, params_extra=128))
    L.append(_conv("conv2/3x3", 64, 192, 3, 56, params_extra=384))
    # inception table: (in, 1x1, 3x3r, 3x3, 5x5r, 5x5, pool_proj, hw)
    incs = [
        ("3a", 192, 64, 96, 128, 16, 32, 32, 28),
        ("3b", 256, 128, 128, 192, 32, 96, 64, 28),
        ("4a", 480, 192, 96, 208, 16, 48, 64, 14),
        ("4b", 512, 160, 112, 224, 24, 64, 64, 14),
        ("4c", 512, 128, 128, 256, 24, 64, 64, 14),
        ("4d", 512, 112, 144, 288, 32, 64, 64, 14),
        ("4e", 528, 256, 160, 320, 32, 128, 128, 14),
        ("5a", 832, 256, 160, 320, 32, 128, 128, 7),
        ("5b", 832, 384, 192, 384, 48, 128, 128, 7),
    ]
    for nm, cin, c1, c3r, c3, c5r, c5, cp, hw in incs:
        L.append(_conv(f"inc{nm}/1x1", cin, c1, 1, hw, params_extra=2 * c1))
        L.append(_conv(f"inc{nm}/3x3_reduce", cin, c3r, 1, hw, params_extra=2 * c3r))
        L.append(_conv(f"inc{nm}/3x3", c3r, c3, 3, hw, params_extra=2 * c3))
        L.append(_conv(f"inc{nm}/5x5_reduce", cin, c5r, 1, hw, params_extra=2 * c5r))
        L.append(_conv(f"inc{nm}/5x5", c5r, c5, 5, hw, params_extra=2 * c5))
        L.append(_conv(f"inc{nm}/pool_proj", cin, cp, 1, hw, params_extra=2 * cp))
        # Caffe's bvlc_googlenet trains with two auxiliary classifiers,
        # attached after 4a and 4d — they contribute gradient traffic too.
        if nm in ("4a", "4d"):
            L.append(_conv(f"aux_{nm}/conv1x1", cin if nm == "4a" else 528, 128, 1, 4))
            L.append(_fc(f"aux_{nm}/fc1", 128 * 4 * 4, 1024))
            L.append(_fc(f"aux_{nm}/fc2", 1024, 1000))
    L.append(_fc("loss3/classifier", 1024, 1000))
    return L


def resnet50_layers() -> list[tuple[str, int, float]]:
    L: list[tuple[str, int, float]] = []
    L.append(_conv("conv1", 3, 64, 7, 224, stride=2, params_extra=128))
    # (stage, blocks, cin, cmid, cout, hw)
    stages = [
        ("res2", 3, 64, 64, 256, 56),
        ("res3", 4, 256, 128, 512, 28),
        ("res4", 6, 512, 256, 1024, 14),
        ("res5", 3, 1024, 512, 2048, 7),
    ]
    for nm, blocks, cin, cmid, cout, hw in stages:
        for b in range(blocks):
            c_in = cin if b == 0 else cout
            stride = 2 if (b == 0 and nm != "res2") else 1
            if b == 0:
                L.append(
                    _conv(f"{nm}a_branch1", c_in, cout, 1, hw * stride, stride=stride,
                          params_extra=2 * cout)
                )
            L.append(
                _conv(f"{nm}{'abcdef'[b]}_branch2a", c_in, cmid, 1, hw * stride,
                      stride=stride, params_extra=2 * cmid)
            )
            L.append(_conv(f"{nm}{'abcdef'[b]}_branch2b", cmid, cmid, 3, hw,
                           params_extra=2 * cmid))
            L.append(_conv(f"{nm}{'abcdef'[b]}_branch2c", cmid, cout, 1, hw,
                           params_extra=2 * cout))
    L.append(_fc("fc1000", 2048, 1000))
    return L


def cnn_layer_costs(
    which: str,
    batch_size: int,
    comm_dtype_bytes: int = 4,
) -> list[LayerCost]:
    """LayerCost list for the simulator (paper order: layer 1 first)."""
    layers = googlenet_layers() if which == "googlenet" else resnet50_layers()
    out = []
    for name, params, fwd_flops in layers:
        out.append(
            LayerCost(
                name=name,
                params=params,
                grad_bytes=params * comm_dtype_bytes,
                bwd_flops=2.0 * fwd_flops * batch_size,
                fwd_flops=fwd_flops * batch_size,
            )
        )
    return out


def total_params(which: str) -> int:
    layers = googlenet_layers() if which == "googlenet" else resnet50_layers()
    return sum(p for _, p, _ in layers)
