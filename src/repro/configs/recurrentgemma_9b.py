"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified].

38L d_model=4096 16H (GQA kv=1 = MQA) head_dim=256 d_ff=12288
vocab=256000.  RG-LRU + local attention in a 2:1 pattern:
12 stages of (rec, rec, attn_local) plus a (rec, rec) tail = 38 layers,
local window 2048, tied embeddings, gemma norms.
"""

from repro.models.common import ArchConfig, Attention, Recurrent


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        d_ff=12288,
        vocab=256000,
        attention=Attention(n_heads=16, n_kv_heads=1, head_dim=256),
        pattern=("rec", "rec", "attn_local"),
        tail_pattern=("rec", "rec"),
        local_window=2048,
        recurrent=Recurrent(kind="rglru", conv_width=4, lru_width=4096),
        norm="rmsnorm_gemma",
        mlp="geglu",
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="recurrentgemma-9b-reduced",
        n_layers=8,
        d_model=128,
        d_ff=384,
        vocab=512,
        attention=Attention(n_heads=4, n_kv_heads=1, head_dim=32),
        pattern=("rec", "rec", "attn_local"),
        tail_pattern=("rec", "rec"),
        local_window=64,
        recurrent=Recurrent(kind="rglru", conv_width=4, lru_width=128),
        q_chunk=32,
    )
