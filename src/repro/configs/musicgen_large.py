"""MusicGen-large decoder backbone over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (MHA: kv=32) d_ff=8192 vocab=2048.  The EnCodec
frontend is a stub per the brief: ``input_specs()`` provides precomputed
frame embeddings (input_mode='embeds'); positions are additive sinusoidal
as in the original (no RoPE).
"""

from repro.models.common import ArchConfig, Attention


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        d_ff=8192,
        vocab=2048,
        attention=Attention(n_heads=32, n_kv_heads=32, head_dim=64, rope="sinusoidal"),
        pattern=("attn",),
        norm="layernorm",
        mlp="gelu",
        input_mode="embeds",
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="musicgen-large-reduced",
        n_layers=4,
        d_model=128,
        d_ff=512,
        vocab=64,
        attention=Attention(n_heads=4, n_kv_heads=4, head_dim=32, rope="sinusoidal"),
        q_chunk=32,
    )
