"""StarCoder2-3B [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""

from repro.models.common import ArchConfig, Attention


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        d_ff=12288,
        vocab=49152,
        attention=Attention(n_heads=24, n_kv_heads=2, head_dim=128, rope_theta=1e5),
        pattern=("attn",),
        norm="layernorm",
        mlp="gelu",
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="starcoder2-3b-reduced",
        n_layers=4,
        d_model=96,
        d_ff=384,
        vocab=512,
        attention=Attention(n_heads=4, n_kv_heads=2, head_dim=24, rope_theta=1e5),
        q_chunk=32,
    )
