"""Qwen2-VL-2B text backbone [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, M-RoPE with
(t, h, w) sections (16, 24, 24) over head_dim 128.  The vision frontend is
a stub per the brief: ``input_specs()`` provides precomputed patch
embeddings (input_mode='embeds') and three equal M-RoPE position streams
for the text-only dry-run shapes.
"""

from repro.models.common import ArchConfig, Attention


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        d_ff=8960,
        vocab=151936,
        attention=Attention(
            n_heads=12,
            n_kv_heads=2,
            head_dim=128,
            rope="mrope",
            mrope_sections=(16, 24, 24),
            rope_theta=1e6,
        ),
        pattern=("attn",),
        norm="rmsnorm",
        mlp="swiglu",
        input_mode="embeds",
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="qwen2-vl-2b-reduced",
        n_layers=4,
        d_model=96,
        d_ff=256,
        vocab=512,
        attention=Attention(
            n_heads=4,
            n_kv_heads=2,
            head_dim=24,
            rope="mrope",
            mrope_sections=(4, 4, 4),
            rope_theta=1e6,
        ),
        q_chunk=32,
    )
