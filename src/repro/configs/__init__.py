"""Architecture registry: the 10 assigned archs (+ reduced smoke variants)
and the paper's own CNN layer profiles (GoogleNet / ResNet-50).

``get_config(name)`` returns the full ArchConfig; ``get_reduced(name)`` a
small same-family variant for CPU smoke tests.  Input shapes for the
dry-run matrix live in ``shapes.py``.
"""

from __future__ import annotations

from importlib import import_module

from ..models.common import ArchConfig

_MODULES = {
    "musicgen-large": "musicgen_large",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma2-2b": "gemma2_2b",
    "starcoder2-3b": "starcoder2_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "dbrx-132b": "dbrx_132b",
    "rwkv6-7b": "rwkv6_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, **overrides) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    cfg = import_module(f".{_MODULES[name]}", __package__).config()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_reduced(name: str, **overrides) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    cfg = import_module(f".{_MODULES[name]}", __package__).reduced()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
