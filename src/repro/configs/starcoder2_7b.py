"""StarCoder2-7B [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.  Treated as full
(dense) attention per the assignment brief; the public model additionally
uses a 4096 sliding window — noted in DESIGN.md as a deliberate deviation
(the brief classifies this arch as pure full-attention for long_500k).
"""

from repro.models.common import ArchConfig, Attention


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        d_ff=18432,
        vocab=49152,
        attention=Attention(n_heads=36, n_kv_heads=4, head_dim=128, rope_theta=1e5),
        pattern=("attn",),
        norm="layernorm",
        mlp="gelu",
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="starcoder2-7b-reduced",
        n_layers=4,
        d_model=144,
        d_ff=576,
        vocab=512,
        attention=Attention(n_heads=6, n_kv_heads=2, head_dim=24, rope_theta=1e5),
        q_chunk=32,
    )
