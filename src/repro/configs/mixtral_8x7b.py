"""Mixtral-8x7B [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000; MoE with 8
experts, top-2 routing; 4096 sliding-window attention.
"""

from repro.models.common import ArchConfig, Attention, MoE


def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab=32000,
        attention=Attention(
            n_heads=32, n_kv_heads=8, head_dim=128, window=4096, rope_theta=1e6
        ),
        pattern=("moe",),
        moe=MoE(n_experts=8, top_k=2),
        norm="rmsnorm",
        mlp="swiglu",
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="mixtral-8x7b-reduced",
        n_layers=4,
        d_model=128,
        d_ff=256,
        vocab=256,
        attention=Attention(n_heads=4, n_kv_heads=2, head_dim=32, window=64),
        moe=MoE(n_experts=4, top_k=2),
        q_chunk=32,
        moe_token_chunk=256,
    )
