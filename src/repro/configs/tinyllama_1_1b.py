"""TinyLlama-1.1B (llama2 architecture) [arXiv:2401.02385].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from repro.models.common import ArchConfig, Attention


def config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b",
        family="dense",
        n_layers=22,
        d_model=2048,
        d_ff=5632,
        vocab=32000,
        attention=Attention(n_heads=32, n_kv_heads=4, head_dim=64),
        pattern=("attn",),
        norm="rmsnorm",
        mlp="swiglu",
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="tinyllama-1.1b-reduced",
        n_layers=4,
        d_model=128,
        d_ff=352,
        vocab=256,
        attention=Attention(n_heads=4, n_kv_heads=2, head_dim=32),
        q_chunk=32,
    )
