"""DBRX-132B [hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352; fine-grained MoE
with 16 experts, top-4 routing.
"""

from repro.models.common import ArchConfig, Attention, MoE


def config() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        d_ff=10752,
        vocab=100352,
        attention=Attention(n_heads=48, n_kv_heads=8, head_dim=128, rope_theta=5e5),
        pattern=("moe",),
        moe=MoE(n_experts=16, top_k=4),
        norm="layernorm",
        mlp="swiglu",
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="dbrx-132b-reduced",
        n_layers=4,
        d_model=128,
        d_ff=192,
        vocab=512,
        attention=Attention(n_heads=4, n_kv_heads=2, head_dim=32),
        moe=MoE(n_experts=4, top_k=2),
        q_chunk=32,
        moe_token_chunk=256,
    )
