"""The assigned input-shape set (LM-family: seq_len × global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len KV cache); the others lower ``train_step`` / prefill.

long_500k requires sub-quadratic attention: run for SSM / hybrid /
windowed archs, skip for pure full-attention archs (list below, per the
brief; rationale in DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: Pure full-attention archs: every layer would need the full 500k KV and
#: the architecture defines no sub-quadratic mechanism -> skip long_500k.
LONG_CONTEXT_SKIP = frozenset(
    {
        "musicgen-large",
        "tinyllama-1.1b",
        "starcoder2-7b",
        "starcoder2-3b",
        "dbrx-132b",
        "qwen2-vl-2b",
    }
)


def applicable_shapes(arch: str) -> list[str]:
    out = []
    for name in SHAPES:
        if name == "long_500k" and arch in LONG_CONTEXT_SKIP:
            continue
        out.append(name)
    return out
