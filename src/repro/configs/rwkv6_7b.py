"""RWKV6 "Finch" 7B [arXiv:2404.05892] — attention-free, data-dependent
decay linear recurrence.

32L d_model=4096 d_ff=14336 vocab=65536, wkv head size 64.
"""

from repro.models.common import ArchConfig, Recurrent


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab=65536,
        attention=None,
        pattern=("rwkv",),
        recurrent=Recurrent(kind="rwkv6", head_dim=64),
        norm="layernorm",
        mlp="rwkv_cmix",  # built into the block
    )


def reduced() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        config(),
        name="rwkv6-7b-reduced",
        n_layers=3,
        d_model=128,
        d_ff=448,
        vocab=256,
        recurrent=Recurrent(kind="rwkv6", head_dim=32),
        rec_chunk=16,
    )
