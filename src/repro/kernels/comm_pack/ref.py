"""Pure-jnp oracle for the gradient-arena pack/unpack pair.

Pack writes each (flattened) gradient part into its slot of one flat wire
arena with ``dynamic_update_slice`` — no ``concatenate`` in the lowering,
which is the whole point of the arena wire layout (``core/sync.py``
``fuse='arena'``): XLA updates the preallocated buffer in place instead
of materializing a second copy of every group's gradients.

The wire-dtype cast is fused into the pack; optionally so is the
error-feedback residual (``runtime/compression.py``): the carried
quantization error is re-added *before* the cast and the new residual is
whatever the cast dropped.  Unpack fuses the inverse cast and the DP
averaging scale.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp


def pack_arena_ref(
    parts: Sequence[jax.Array],  # flattened 1-D gradient parts
    offsets: Sequence[int],  # element offset of each part in the arena
    size: int,  # total arena elements (== sum of part sizes)
    comm_dtype: Any,
    residuals: Sequence[jax.Array] | None = None,  # 1-D f32, same sizes
) -> tuple[jax.Array, list[jax.Array] | None]:
    """(arena, new_residuals) — residuals None for the stateless cast."""
    arena = jnp.zeros((size,), comm_dtype)
    new_res: list[jax.Array] | None = None if residuals is None else []
    for i, (p, off) in enumerate(zip(parts, offsets)):
        if residuals is not None:
            acc = p.astype(jnp.float32) + residuals[i].astype(jnp.float32)
            wire = acc.astype(comm_dtype)
            new_res.append(acc - wire.astype(jnp.float32))
        else:
            wire = p.astype(comm_dtype)
        arena = jax.lax.dynamic_update_slice(arena, wire, (off,))
    return arena, new_res


def unpack_arena_ref(
    arena: jax.Array,  # 1-D reduced wire buffer
    slots: Sequence[tuple[int, int]],  # (offset, size) per part
    dtypes: Sequence[Any],  # destination dtype per part
    scale: jax.Array | float = 1.0,  # DP averaging factor (1/world)
) -> list[jax.Array]:
    """Static slices out of the reduced arena, decompress + scale fused."""
    out = []
    for (off, n), dt in zip(slots, dtypes):
        seg = jax.lax.slice(arena, (off,), (off + n,))
        out.append((seg.astype(jnp.float32) * scale).astype(dt))
    return out
