from .kernel import pack_arena_pallas, unpack_arena_pallas
from .ops import pack_arena, unpack_arena
from .ref import pack_arena_ref, unpack_arena_ref

__all__ = [
    "pack_arena",
    "pack_arena_pallas",
    "pack_arena_ref",
    "unpack_arena",
    "unpack_arena_pallas",
    "unpack_arena_ref",
]
