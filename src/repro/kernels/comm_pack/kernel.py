"""Pallas TPU kernels for the gradient-arena wire path.

One ``pallas_call`` per schedule group, both directions.  The gradient
parts and the arena live in ``ANY`` (compiler-placed, HBM at these
sizes); the kernel streams each part through a small VMEM staging buffer
with explicit async copies:

    pack    part[c:c+m] ──DMA──► VMEM ──cast(+EF)──► VMEM ──DMA──► arena[off+c:]
    unpack  arena[off+c:] ──DMA──► VMEM ──cast·scale──► VMEM ──DMA──► part[c:]

so the bf16 (or any wire-dtype) cast — and optionally the
error-feedback residual add/update of ``runtime/compression.py`` — costs
zero extra HBM round-trips: exactly one read of the gradients and one
write of the arena, where XLA's concatenate layout pays a full extra
copy each way.  Slot offsets are exact-packed (element granularity; the
wire buffer is byte-identical in size to the concat layout) — TPU DMAs
take arbitrary element offsets, trading a little engine efficiency on
odd tails for never shipping padding over the wire.

The chunk loop is unrolled at trace time (sizes are static) and the
staging copies are double-buffered (the DMA-pipeline pattern from
flash_attention): every VMEM staging buffer has two slots and a
two-entry DMA semaphore array, the first inbound copy is warmed up
before the loop, and at chunk ``k`` the kernel starts the inbound copy
for chunk ``k+1`` into slot ``(k+1) % 2`` before waiting on chunk
``k``'s — so the next HBM read is in flight while the current chunk is
cast (and the previous chunk's arena write drains).  Slot reuse is
fenced by waiting chunk ``k-1``'s *outbound* copy before starting chunk
``k+1``'s inbound one, which shares its slot.
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Staging-buffer length in elements (f32: 256 KiB — comfortably inside
#: VMEM next to its wire-dtype twin).
DEFAULT_CHUNK = 1 << 16

_ANY = pl.BlockSpec(memory_space=pltpu.ANY)


def _pack_kernel(
    *refs,
    sizes: tuple[int, ...],
    offsets: tuple[int, ...],
    chunk: int,
    comm_dtype: Any,
    ef: bool,
):
    n = len(sizes)
    parts = refs[:n]
    resid = refs[n : 2 * n] if ef else ()
    outs = refs[2 * n :] if ef else refs[n:]
    arena, new_res = outs[0], outs[1:]

    for i in range(n):
        ck = min(chunk, sizes[i])
        c0s = tuple(range(0, sizes[i], ck))

        def part(
            src,
            wire,
            in_sem,
            out_sem,
            res=None,
            res_in_sem=None,
            res_out_sem=None,
            i=i,
            ck=ck,
            c0s=c0s,
        ):
            def in_dmas(k):
                c0 = c0s[k]
                m = min(ck, sizes[i] - c0)
                s = k % 2
                cps = [
                    pltpu.make_async_copy(
                        parts[i].at[pl.ds(c0, m)], src.at[s, pl.ds(0, m)], in_sem.at[s]
                    )
                ]
                if ef:
                    cps.append(
                        pltpu.make_async_copy(
                            resid[i].at[pl.ds(c0, m)],
                            res.at[s, pl.ds(0, m)],
                            res_in_sem.at[s],
                        )
                    )
                return cps

            def out_dmas(k):
                c0 = c0s[k]
                m = min(ck, sizes[i] - c0)
                s = k % 2
                cps = [
                    pltpu.make_async_copy(
                        wire.at[s, pl.ds(0, m)],
                        arena.at[pl.ds(offsets[i] + c0, m)],
                        out_sem.at[s],
                    )
                ]
                if ef:
                    cps.append(
                        pltpu.make_async_copy(
                            res.at[s, pl.ds(0, m)],
                            new_res[i].at[pl.ds(c0, m)],
                            res_out_sem.at[s],
                        )
                    )
                return cps

            for cp in in_dmas(0):  # warm-up: first chunk's inbound copies
                cp.start()
            for k in range(len(c0s)):
                m = min(ck, sizes[i] - c0s[k])
                s = k % 2
                if k >= 1:
                    # Drain chunk k-1's outbound copies: they share slot
                    # (k+1) % 2 with chunk k+1's inbound ones.
                    for cp in out_dmas(k - 1):
                        cp.wait()
                if k + 1 < len(c0s):
                    for cp in in_dmas(k + 1):
                        cp.start()
                for cp in in_dmas(k):
                    cp.wait()
                x = src[s, pl.ds(0, m)].astype(jnp.float32)
                if ef:
                    x = x + res[s, pl.ds(0, m)]
                w = x.astype(comm_dtype)
                wire[s, pl.ds(0, m)] = w
                if ef:
                    res[s, pl.ds(0, m)] = x - w.astype(jnp.float32)
                for cp in out_dmas(k):
                    cp.start()
            for cp in out_dmas(len(c0s) - 1):
                cp.wait()

        scratch = dict(
            src=pltpu.VMEM((2, ck), parts[i].dtype),
            wire=pltpu.VMEM((2, ck), comm_dtype),
            in_sem=pltpu.SemaphoreType.DMA((2,)),
            out_sem=pltpu.SemaphoreType.DMA((2,)),
        )
        if ef:
            scratch["res"] = pltpu.VMEM((2, ck), jnp.float32)
            scratch["res_in_sem"] = pltpu.SemaphoreType.DMA((2,))
            scratch["res_out_sem"] = pltpu.SemaphoreType.DMA((2,))
        pl.run_scoped(part, **scratch)


def pack_arena_pallas(
    parts: Sequence[jax.Array],  # flattened 1-D gradient parts
    offsets: Sequence[int],
    size: int,
    comm_dtype: Any,
    residuals: Sequence[jax.Array] | None = None,  # 1-D f32
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> tuple[jax.Array, list[jax.Array] | None]:
    """Fused pack(+cast[+error-feedback]) of one group's wire arena."""
    ef = residuals is not None
    sizes = tuple(int(p.size) for p in parts)
    kernel = functools.partial(
        _pack_kernel,
        sizes=sizes,
        offsets=tuple(int(o) for o in offsets),
        chunk=chunk,
        comm_dtype=comm_dtype,
        ef=ef,
    )
    out_shape = [jax.ShapeDtypeStruct((size,), comm_dtype)]
    if ef:
        out_shape += [jax.ShapeDtypeStruct((s,), jnp.float32) for s in sizes]
    operands = list(parts) + (list(residuals) if ef else [])
    out = pl.pallas_call(
        kernel,
        in_specs=[_ANY] * len(operands),
        out_specs=[_ANY] * len(out_shape),
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return (out[0], list(out[1:])) if ef else (out[0], None)


def _unpack_kernel(
    arena,
    scale_ref,  # (1,) f32 in SMEM: the DP averaging factor
    *outs,
    slots: tuple[tuple[int, int], ...],
    dtypes: tuple[Any, ...],
    chunk: int,
):
    for i, (off, sz) in enumerate(slots):
        ck = min(chunk, sz)
        c0s = tuple(range(0, sz, ck))

        def part(wire, dst, in_sem, out_sem, i=i, off=off, sz=sz, ck=ck, c0s=c0s):
            def in_dma(k):
                c0 = c0s[k]
                m = min(ck, sz - c0)
                s = k % 2
                return pltpu.make_async_copy(
                    arena.at[pl.ds(off + c0, m)], wire.at[s, pl.ds(0, m)], in_sem.at[s]
                )

            def out_dma(k):
                c0 = c0s[k]
                m = min(ck, sz - c0)
                s = k % 2
                return pltpu.make_async_copy(
                    dst.at[s, pl.ds(0, m)], outs[i].at[pl.ds(c0, m)], out_sem.at[s]
                )

            in_dma(0).start()  # warm-up
            for k in range(len(c0s)):
                m = min(ck, sz - c0s[k])
                s = k % 2
                if k >= 1:
                    out_dma(k - 1).wait()  # frees the slot chunk k+1 stages into
                if k + 1 < len(c0s):
                    in_dma(k + 1).start()
                in_dma(k).wait()
                x = wire[s, pl.ds(0, m)].astype(jnp.float32) * scale_ref[0]
                dst[s, pl.ds(0, m)] = x.astype(dtypes[i])
                out_dma(k).start()
            out_dma(len(c0s) - 1).wait()

        pl.run_scoped(
            part,
            wire=pltpu.VMEM((2, ck), arena.dtype),
            dst=pltpu.VMEM((2, ck), dtypes[i]),
            in_sem=pltpu.SemaphoreType.DMA((2,)),
            out_sem=pltpu.SemaphoreType.DMA((2,)),
        )


def unpack_arena_pallas(
    arena: jax.Array,
    slots: Sequence[tuple[int, int]],  # (offset, size) per part
    dtypes: Sequence[Any],
    scale: jax.Array,  # shape-(1,) f32
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> list[jax.Array]:
    """Fused unpack(+decompress+average) of one reduced arena."""
    kernel = functools.partial(
        _unpack_kernel,
        slots=tuple((int(o), int(s)) for o, s in slots),
        dtypes=tuple(dtypes),
        chunk=chunk,
    )
    out = pl.pallas_call(
        kernel,
        in_specs=[_ANY, pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=[_ANY] * len(slots),
        out_shape=[jax.ShapeDtypeStruct((s,), dt) for (_, s), dt in zip(slots, dtypes)],
        interpret=interpret,
    )(arena, scale.astype(jnp.float32).reshape(1))
    return list(out)
