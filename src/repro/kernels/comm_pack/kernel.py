"""Pallas TPU kernels for the gradient-arena wire path.

One ``pallas_call`` per schedule group, both directions.  The gradient
parts and the arena live in ``ANY`` (compiler-placed, HBM at these
sizes); the kernel streams each part through a small VMEM staging buffer
with explicit async copies:

    pack    part[c:c+m] ──DMA──► VMEM ──cast(+EF)──► VMEM ──DMA──► arena[off+c:]
    unpack  arena[off+c:] ──DMA──► VMEM ──cast·scale──► VMEM ──DMA──► part[c:]

so the bf16 (or any wire-dtype) cast — and optionally the
error-feedback residual add/update of ``runtime/compression.py`` — costs
zero extra HBM round-trips: exactly one read of the gradients and one
write of the arena, where XLA's concatenate layout pays a full extra
copy each way.  Slot offsets are exact-packed (element granularity; the
wire buffer is byte-identical in size to the concat layout) — TPU DMAs
take arbitrary element offsets, trading a little engine efficiency on
odd tails for never shipping padding over the wire.

The chunk loop is unrolled at trace time (sizes are static) and single-
buffered for clarity; double-buffering the staging copies is a local
change (see the DMA-pipeline pattern in flash_attention) left until a
profile shows these group-sized copies anywhere near the critical path —
the arena pack replaces copies XLA was *already* making.
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Staging-buffer length in elements (f32: 256 KiB — comfortably inside
#: VMEM next to its wire-dtype twin).
DEFAULT_CHUNK = 1 << 16

_ANY = pl.BlockSpec(memory_space=pltpu.ANY)


def _copy(src_ref, dst_ref, sem) -> None:
    cp = pltpu.make_async_copy(src_ref, dst_ref, sem)
    cp.start()
    cp.wait()


def _pack_kernel(
    *refs,
    sizes: tuple[int, ...],
    offsets: tuple[int, ...],
    chunk: int,
    comm_dtype: Any,
    ef: bool,
):
    n = len(sizes)
    parts = refs[:n]
    resid = refs[n : 2 * n] if ef else ()
    outs = refs[2 * n :] if ef else refs[n:]
    arena, new_res = outs[0], outs[1:]

    for i in range(n):
        ck = min(chunk, sizes[i])

        def part(src, wire, sem, res=None, i=i, ck=ck):
            for c0 in range(0, sizes[i], ck):
                m = min(ck, sizes[i] - c0)
                _copy(parts[i].at[pl.ds(c0, m)], src.at[pl.ds(0, m)], sem)
                x = src[pl.ds(0, m)].astype(jnp.float32)
                if ef:
                    _copy(resid[i].at[pl.ds(c0, m)], res.at[pl.ds(0, m)], sem)
                    x = x + res[pl.ds(0, m)]
                w = x.astype(comm_dtype)
                wire[pl.ds(0, m)] = w
                _copy(wire.at[pl.ds(0, m)], arena.at[pl.ds(offsets[i] + c0, m)], sem)
                if ef:
                    res[pl.ds(0, m)] = x - w.astype(jnp.float32)
                    _copy(res.at[pl.ds(0, m)], new_res[i].at[pl.ds(c0, m)], sem)

        scratch = dict(
            src=pltpu.VMEM((ck,), parts[i].dtype),
            wire=pltpu.VMEM((ck,), comm_dtype),
            sem=pltpu.SemaphoreType.DMA(()),
        )
        if ef:
            scratch["res"] = pltpu.VMEM((ck,), jnp.float32)
        pl.run_scoped(part, **scratch)


def pack_arena_pallas(
    parts: Sequence[jax.Array],  # flattened 1-D gradient parts
    offsets: Sequence[int],
    size: int,
    comm_dtype: Any,
    residuals: Sequence[jax.Array] | None = None,  # 1-D f32
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> tuple[jax.Array, list[jax.Array] | None]:
    """Fused pack(+cast[+error-feedback]) of one group's wire arena."""
    ef = residuals is not None
    sizes = tuple(int(p.size) for p in parts)
    kernel = functools.partial(
        _pack_kernel,
        sizes=sizes,
        offsets=tuple(int(o) for o in offsets),
        chunk=chunk,
        comm_dtype=comm_dtype,
        ef=ef,
    )
    out_shape = [jax.ShapeDtypeStruct((size,), comm_dtype)]
    if ef:
        out_shape += [jax.ShapeDtypeStruct((s,), jnp.float32) for s in sizes]
    operands = list(parts) + (list(residuals) if ef else [])
    out = pl.pallas_call(
        kernel,
        in_specs=[_ANY] * len(operands),
        out_specs=[_ANY] * len(out_shape),
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return (out[0], list(out[1:])) if ef else (out[0], None)


def _unpack_kernel(
    arena,
    scale_ref,  # (1,) f32 in SMEM: the DP averaging factor
    *outs,
    slots: tuple[tuple[int, int], ...],
    dtypes: tuple[Any, ...],
    chunk: int,
):
    for i, (off, sz) in enumerate(slots):
        ck = min(chunk, sz)

        def part(wire, dst, sem, i=i, off=off, sz=sz, ck=ck):
            for c0 in range(0, sz, ck):
                m = min(ck, sz - c0)
                _copy(arena.at[pl.ds(off + c0, m)], wire.at[pl.ds(0, m)], sem)
                x = wire[pl.ds(0, m)].astype(jnp.float32) * scale_ref[0]
                dst[pl.ds(0, m)] = x.astype(dtypes[i])
                _copy(dst.at[pl.ds(0, m)], outs[i].at[pl.ds(c0, m)], sem)

        pl.run_scoped(
            part,
            wire=pltpu.VMEM((ck,), arena.dtype),
            dst=pltpu.VMEM((ck,), dtypes[i]),
            sem=pltpu.SemaphoreType.DMA(()),
        )


def unpack_arena_pallas(
    arena: jax.Array,
    slots: Sequence[tuple[int, int]],  # (offset, size) per part
    dtypes: Sequence[Any],
    scale: jax.Array,  # shape-(1,) f32
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> list[jax.Array]:
    """Fused unpack(+decompress+average) of one reduced arena."""
    kernel = functools.partial(
        _unpack_kernel,
        slots=tuple((int(o), int(s)) for o, s in slots),
        dtypes=tuple(dtypes),
        chunk=chunk,
    )
    out = pl.pallas_call(
        kernel,
        in_specs=[_ANY, pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=[_ANY] * len(slots),
        out_shape=[jax.ShapeDtypeStruct((s,), dt) for (_, s), dt in zip(slots, dtypes)],
        interpret=interpret,
    )(arena, scale.astype(jnp.float32).reshape(1))
    return list(out)
