"""Public ops for the gradient-arena wire path: Pallas on TPU, the
``dynamic_update_slice``/``slice`` oracle otherwise.  Both paths lower
with ZERO concatenate ops — the oracle is not just a test double, it is
the production CPU/GPU layout (XLA turns the update-slice chain into
in-place writes on the preallocated buffer).

Parts may be arbitrary-shaped gradient leaves / scan slices; flattening
to the 1-D wire layout happens here so the kernels only see flat spans.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .kernel import pack_arena_pallas, unpack_arena_pallas
from .ref import pack_arena_ref, unpack_arena_ref


def _use_pallas(use_pallas: bool | None) -> bool:
    return jax.default_backend() == "tpu" if use_pallas is None else use_pallas


def pack_arena(
    parts: Sequence[jax.Array],
    offsets: Sequence[int],
    size: int,
    comm_dtype: Any,
    residuals: Sequence[jax.Array] | None = None,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    chunk: int | None = None,
) -> tuple[jax.Array, list[jax.Array] | None]:
    """Pack one group's parts into its flat wire arena.

    Fuses the wire-dtype cast, and — when ``residuals`` (f32, same
    structure) is given — the error-feedback accumulate/update.  Returns
    ``(arena, new_residuals)``; residuals keep the parts' shapes.
    ``chunk`` overrides the staging-buffer length (elements) on the
    Pallas path — tests shrink it to force the multi-chunk DMA pipeline.
    """
    flat = [p.reshape(-1) for p in parts]
    res_flat = None if residuals is None else [r.reshape(-1) for r in residuals]
    if _use_pallas(use_pallas) or interpret:
        kw = {} if chunk is None else {"chunk": chunk}
        arena, new_res = pack_arena_pallas(
            flat, offsets, size, comm_dtype, res_flat, interpret=interpret, **kw
        )
    else:
        arena, new_res = pack_arena_ref(flat, offsets, size, comm_dtype, res_flat)
    if new_res is not None:
        new_res = [r.reshape(p.shape) for r, p in zip(new_res, parts)]
    return arena, new_res


def unpack_arena(
    arena: jax.Array,
    slots: Sequence[tuple[int, int]],  # (offset, size) per part
    shapes: Sequence[tuple[int, ...]],
    dtypes: Sequence[Any],
    scale: jax.Array | float = 1.0,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    chunk: int | None = None,
) -> list[jax.Array]:
    """Slice the reduced arena back into parts (decompress + DP-average
    fused); parts come back in their original shapes/dtypes."""
    if _use_pallas(use_pallas) or interpret:
        kw = {} if chunk is None else {"chunk": chunk}
        out = unpack_arena_pallas(
            arena, slots, dtypes, jnp.asarray(scale, jnp.float32).reshape(1),
            interpret=interpret, **kw,
        )
    else:
        out = unpack_arena_ref(arena, slots, dtypes, scale)
    return [p.reshape(s) for p, s in zip(out, shapes)]
