"""Public ops: flash attention forward and the differentiable training op.

``flash_attention`` dispatches to the Pallas TPU kernel on TPU backends
(or in interpret mode for validation) and to the dense jnp oracle
otherwise.  ``flash_attention_train`` is the custom-VJP op whose forward
saves only (o, lse) and whose backward runs the Pallas dQ/dKV kernels —
no S×S residuals in HBM (kernel_bwd.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .kernel_bwd import flash_attention_bwd
from .ref import attention_ref


def _use_pallas(explicit: bool | None) -> bool:
    if explicit is not None:
        return explicit
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "use_pallas", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    if _use_pallas(use_pallas) or interpret:
        return flash_attention_fwd(
            q, k, v,
            causal=causal, window=window, softcap=softcap, interpret=interpret,
        )
    return attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention_train(
    q, k, v,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    interpret: bool = False,
):
    o, _ = flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=interpret, return_lse=True,
    )
    return o


def _fat_fwd(q, k, v, causal, window, softcap, interpret):
    o, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=interpret, return_lse=True,
    )
    return o, (q, k, v, o, lse)


def _fat_bwd(causal, window, softcap, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do,
        causal=causal, window=window, softcap=softcap, interpret=interpret,
    )
    return dq, dk, dv


flash_attention_train.defvjp(_fat_fwd, _fat_bwd)
