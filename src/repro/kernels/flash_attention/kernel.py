"""Pallas TPU flash attention (forward): online-softmax over KV blocks.

TPU mapping
-----------
grid = (batch * q_heads, num_q_blocks, num_kv_blocks); the last grid axis
is sequential on TPU ("arbitrary"), so fp32 scratch accumulators persist
across KV blocks of one (head, q-block):

  acc (block_q, hd)   running unnormalized output
  m   (block_q, 128)  running row max (lane-replicated)
  l   (block_q, 128)  running row sum

Block shapes are MXU-aligned: block_q x hd and block_k x hd tiles with
hd ∈ {64, 128, 256} and block_{q,k} multiples of 128 (sublane-packed for
bf16).  VMEM footprint per program ≈ (block_q + 2·block_k) · hd · 2B +
block_q · hd · 4B + 2 · block_q · 512B — e.g. ~0.6 MB at 256/512/128,
far under the ~16 MB v5e budget, leaving room for double buffering.

GQA is expressed in the BlockSpec index maps: the KV block index maps the
query head h to KV head h // group, so no KV replication is materialized.
Causal/window skipping is done with block-level masks (correctness) —
skipped-block *scheduling* (not issuing the dot at all) is a grid-mapping
refinement noted in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...compat import tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _fwd_kernel(
    q_ref,  # (block_q, hd)
    k_ref,  # (block_k, hd)
    v_ref,  # (block_k, hd)
    o_ref,  # (block_q, hd)
    lse_ref,  # (block_q, LANES) out: row logsumexp (bwd residual)
    acc_ref,  # scratch (block_q, hd) f32
    m_ref,  # scratch (block_q, LANES) f32
    l_ref,  # scratch (block_q, LANES) f32
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    softcap: float | None,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # (block_q, block_k)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]  # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (block_q, block_k)
    correction = jnp.exp(m_prev - m_new)  # (block_q, 1)

    l_ref[...] = correction * l_ref[...] + jnp.broadcast_to(
        jnp.sum(p, axis=1, keepdims=True), l_ref.shape
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    v = v_ref[...].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[...] = (acc_ref[...] / safe_l).astype(o_ref.dtype)
        lse_ref[...] = (m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))).astype(
            lse_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret",
        "return_lse",
    ),
)
def flash_attention_fwd(
    q: jax.Array,  # (B, Sq, Hq, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    return_lse: bool = False,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    while Sq % block_q:
        block_q //= 2
    while Sk % block_k:
        block_k //= 2
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    # layout: fold (B, H) into the first grid axis; heads-minor
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ik, 0)

    kernel = functools.partial(
        _fwd_kernel,
        sm_scale=hd**-0.5,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
    )
    from jax.experimental.pallas import tpu as pltpu

    out, lse = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), q_map),
            pl.BlockSpec((None, block_k, hd), kv_map),
            pl.BlockSpec((None, block_k, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, hd), q_map),
            pl.BlockSpec((None, block_q, LANES), q_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, Sq, LANES), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    o = out.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
    if return_lse:
        return o, lse[..., 0].reshape(B, Hq, Sq).transpose(0, 2, 1)
    return o
