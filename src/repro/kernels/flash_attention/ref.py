"""Pure-jnp oracle for the flash attention kernel: dense masked softmax
attention with GQA, causal, sliding-window and softcap options."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Sq, Hq, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,  # (B, Sk, Hkv, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qf, kf) * hd**-0.5
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    # rows that are fully masked (can happen with window) produce NaN; zero them
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgst,btkh->bskgh", p, vf)
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)
