"""Pallas TPU flash attention backward: dQ / dK / dV with recomputation.

Standard two-kernel decomposition (FlashAttention-2 style):

  * ``_dq_kernel``  — grid (B·Hq, nq, nk), KV axis sequential; fp32
    dQ accumulator (block_q, hd) persists across KV blocks;
  * ``_dkv_kernel`` — grid (B·Hq, nk, nq), Q axis sequential; fp32
    dK/dV accumulators (block_k, hd) persist across Q blocks.  Gradients
    are produced per *query* head and group-summed to KV heads outside
    (GQA), trading G× transient memory for perfectly regular tiles.

Both recompute p = exp(s − L) from the forward's saved row logsumexp
L = m + log l — no S×S residuals are ever written to HBM.  Softcap
backward chains d tanh = 1 − (s/cap)².  VMEM per program ≈
(q + k + v + dO + dQ) blocks ≈ 5·block·hd·4B ≲ 1 MB at 256×128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...compat import tpu_compiler_params
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _scores(q, k, sm_scale, softcap):
    s_raw = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale
    if softcap is not None:
        t = jnp.tanh(s_raw / softcap)
        return t * softcap, (1.0 - t * t)  # value, d(softcap)/d(raw)
    return s_raw, None


def _mask(iq, ik, block_q, block_k, causal, window):
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    m = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        m &= qpos >= kpos
    if window is not None:
        m &= (qpos - kpos) < window
    return m


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, acc_ref,
    *, sm_scale, causal, window, softcap, block_q, block_k, num_kv_blocks,
):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...].astype(jnp.float32)[:, :1]  # (block_q, 1)
    delta = delta_ref[...].astype(jnp.float32)[:, :1]

    s, dcap = _scores(q, k, sm_scale, softcap)
    mask = _mask(iq, ik, block_q, block_k, causal, window)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta)
    if dcap is not None:
        ds = ds * dcap
    ds = ds * sm_scale
    acc_ref[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ik == num_kv_blocks - 1)
    def _done():
        dq_ref[...] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, sm_scale, causal, window, softcap, block_q, block_k, num_q_blocks,
):
    ik, iq = pl.program_id(1), pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...].astype(jnp.float32)[:, :1]
    delta = delta_ref[...].astype(jnp.float32)[:, :1]

    s, dcap = _scores(q, k, sm_scale, softcap)
    mask = _mask(iq, ik, block_q, block_k, causal, window)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)

    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta)
    if dcap is not None:
        ds = ds * dcap
    ds = ds * sm_scale
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(iq == num_q_blocks - 1)
    def _done():
        dk_ref[...] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def flash_attention_bwd(
    q: jax.Array,  # (B, Sq, Hq, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,
    o: jax.Array,  # forward output
    lse: jax.Array,  # (B, Sq, Hq) row logsumexp from forward
    do: jax.Array,  # cotangent of o
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    while Sq % block_q:
        block_q //= 2
    while Sk % block_k:
        block_k //= 2
    nq, nk = Sq // block_q, Sk // block_k

    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    dot = do.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    ot = o.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    lset = lse.transpose(0, 2, 1).reshape(B * Hq, Sq)
    delta = jnp.sum(dot.astype(jnp.float32) * ot.astype(jnp.float32), axis=-1)

    LANES = 128
    lse2 = jnp.broadcast_to(lset[..., None], lset.shape + (LANES,))
    delta2 = jnp.broadcast_to(delta[..., None], delta.shape + (LANES,))

    def q_map_q(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map_q(bh, iq, ik):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ik, 0)

    common = dict(
        sm_scale=hd**-0.5, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k,
    )
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, num_kv_blocks=nk, **common),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), q_map_q),
            pl.BlockSpec((None, block_k, hd), kv_map_q),
            pl.BlockSpec((None, block_k, hd), kv_map_q),
            pl.BlockSpec((None, block_q, hd), q_map_q),
            pl.BlockSpec((None, block_q, LANES), q_map_q),
            pl.BlockSpec((None, block_q, LANES), q_map_q),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), q_map_q),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt, dot, lse2, delta2)

    def k_map(bh, ik, iq):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ik, 0)

    def q_map_k(bh, ik, iq):
        return (bh, iq, 0)

    dk_e, dv_e = pl.pallas_call(
        functools.partial(_dkv_kernel, num_q_blocks=nq, **common),
        grid=(B * Hq, nk, nq),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), q_map_k),
            pl.BlockSpec((None, block_k, hd), k_map),
            pl.BlockSpec((None, block_k, hd), k_map),
            pl.BlockSpec((None, block_q, hd), q_map_k),
            pl.BlockSpec((None, block_q, LANES), q_map_k),
            pl.BlockSpec((None, block_q, LANES), q_map_k),
        ],
        out_specs=[
            pl.BlockSpec((None, block_k, hd), lambda bh, ik, iq: (bh, ik, 0)),
            pl.BlockSpec((None, block_k, hd), lambda bh, ik, iq: (bh, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Sk, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * Hq, Sk, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, hd), jnp.float32),
            pltpu.VMEM((block_k, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt, dot, lse2, delta2)

    # group-sum the per-q-head dK/dV back to KV heads
    dk = dk_e.reshape(B, Hkv, G, Sk, hd).sum(axis=2).transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv_e.reshape(B, Hkv, G, Sk, hd).sum(axis=2).transpose(0, 2, 1, 3).astype(v.dtype)
    dq_out = dq.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
    return dq_out, dk, dv
