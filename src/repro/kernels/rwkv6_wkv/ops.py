"""Public op for the WKV6 recurrence: Pallas on TPU, chunked jnp otherwise."""

from __future__ import annotations

import jax

from ...models.rwkv6 import wkv_chunked
from .kernel import wkv_pallas
from .ref import wkv_ref


def wkv(
    r, k, v, w, u, s0=None, *, chunk: int = 128,
    use_pallas: bool | None = None, interpret: bool = False,
):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return wkv_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)
    return wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
