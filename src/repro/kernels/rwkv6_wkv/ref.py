"""Pure-jnp oracle for the WKV6 kernel: the *sequential* recurrence, the
ground truth both the chunked jnp path (models/rwkv6.py) and the Pallas
kernel must match.

    out_t = r_t · S + (r_t · (u ⊙ k_t)) v_t
    S    <- diag(w_t) · S + k_tᵀ v_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0, 1)
    u: jax.Array,  # (H, K)
    s0: jax.Array | None = None,  # (B, H, K, K)
) -> tuple[jax.Array, jax.Array]:
    B, T, H, K = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    if s0 is None:
        s0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs  # (B, H, K)
        inter = jnp.einsum("bhk,bhkv->bhv", r_t, S)
        cur = jnp.einsum("bhk,bhk->bh", r_t, u[None] * k_t)[..., None] * v_t
        out = inter + cur
        S = S * w_t[..., None] + jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        return S, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    S, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return outs.transpose(1, 0, 2, 3), S
