"""Pallas TPU kernel for the RWKV6 WKV recurrence (chunked-parallel form).

TPU mapping
-----------
grid = (B * H, T / chunk); the chunk axis is sequential ("arbitrary") so
the (K, K) fp32 state scratch persists across chunks of one (batch, head).
Per chunk everything is (chunk, K) resident in VMEM:

  intra-chunk:  (chunk x chunk) strictly-lower-triangular matmul — MXU
  inter-chunk:  r̃ @ S — MXU
  state update: diag-decay + k̃ᵀ @ v — MXU

K = 64 (RWKV6 head size) packs one fp32 state tile of 16 KB; chunk = 128
keeps every operand MXU-aligned.  VMEM per program ≈ 6 · chunk·K·4B +
K·K·4B ≈ 0.2 MB.  This is the same algorithm as models/rwkv6.wkv_chunked,
so kernel-vs-chunked-vs-sequential all cross-validate (tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...compat import tpu_compiler_params
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(
    r_ref, k_ref, v_ref, w_ref,  # (chunk, K)
    u_ref,  # (1, K)
    s0_ref,  # (K, K) initial state for this (b, h)
    o_ref,  # (chunk, K)
    s_out_ref,  # (K, K) final state
    s_ref,  # scratch (K, K) f32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)  # (1, K)

    logw = jnp.log(jnp.maximum(w, 1e-30))
    clw = jnp.cumsum(logw, axis=0)
    w_prev = jnp.exp(clw - logw)  # decay up to t-1
    w_inc = jnp.exp(clw)
    w_end = w_inc[-1:, :]  # (1, K)

    r_t = r * w_prev
    k_t = k / jnp.maximum(w_inc, 1e-30)

    S = s_ref[...]
    inter = jax.lax.dot_general(
        r_t, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    A = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (chunk, chunk)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(ii > jj, A, 0.0)  # strictly lower triangular
    intra = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    cur = jnp.sum(r * (u * k), axis=1, keepdims=True) * v
    o_ref[...] = (inter + intra + cur).astype(o_ref.dtype)

    kw = k_t * w_end  # (chunk, K)
    s_new = S * w_end.T + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ic == num_chunks - 1)
    def _finish():
        s_out_ref[...] = s_new.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,  # (H, K)
    s0: jax.Array | None = None,  # (B, H, K, K)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, T, H, K = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, K, K), jnp.float32)

    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, T, K)

    rt, kt, vt, wt = map(to_bh, (r, k, v, w))
    ub = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)
    s0t = s0.reshape(B * H, K, K)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, num_chunks=nc)
    out, s_final = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, chunk, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, chunk, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, chunk, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, 1, K), lambda bh, ic: (bh, 0, 0)),
            pl.BlockSpec((None, K, K), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, K), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, K, K), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T, K), jnp.float32),
            jax.ShapeDtypeStruct((B * H, K, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(rt, kt, vt, wt, ub, s0t)
    return (
        out.reshape(B, H, T, K).transpose(0, 2, 1, 3),
        s_final.reshape(B, H, K, K),
    )
