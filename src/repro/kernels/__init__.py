"""Pallas TPU kernels for the compute hot-spots the MG-WFBP schedule
overlaps against — flash attention, RWKV6 WKV, RG-LRU — plus the
communication-side pack/unpack pair behind the arena wire layout
(``core/sync.py`` ``fuse='arena'``).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (dispatching wrapper) and ref.py (pure-jnp oracle);
tests sweep shapes/dtypes in interpret mode against the oracles.
"""

from .comm_pack import pack_arena, pack_arena_ref, unpack_arena, unpack_arena_ref
from .flash_attention import attention_ref, flash_attention, flash_attention_fwd
from .rglru import rglru, rglru_pallas, rglru_ref
from .rwkv6_wkv import wkv, wkv_pallas, wkv_ref

__all__ = [
    "attention_ref",
    "pack_arena",
    "pack_arena_ref",
    "unpack_arena",
    "unpack_arena_ref",
    "flash_attention",
    "flash_attention_fwd",
    "rglru",
    "rglru_pallas",
    "rglru_ref",
    "wkv",
    "wkv_pallas",
    "wkv_ref",
]
