"""Public op for the RG-LRU recurrence: Pallas on TPU, associative_scan
fallback otherwise (see models/rglru.py for the full Griffin block)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import rglru_pallas
from .ref import rglru_ref


def rglru(
    a, g, h0=None, *, use_pallas: bool | None = None, interpret: bool = False
):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return rglru_pallas(a, g, h0, interpret=interpret)

    af, gf = a.astype(jnp.float32), g.astype(jnp.float32)
    if h0 is not None:
        gf = gf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, gf), axis=1)
    return h, h[:, -1]
