"""Pure-jnp oracle for the RG-LRU recurrence kernel: sequential scan of

    h_t = a_t ⊙ h_{t-1} + g_t

(the gates/decays a_t and pre-gated inputs g_t are computed by the caller;
see models/rglru.py for the full block)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(
    a: jax.Array,  # (B, T, W) decay in (0, 1]
    g: jax.Array,  # (B, T, W) gated input
    h0: jax.Array | None = None,  # (B, W)
) -> tuple[jax.Array, jax.Array]:
    B, T, W = a.shape
    af, gf = a.astype(jnp.float32), g.astype(jnp.float32)
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, xs):
        a_t, g_t = xs
        h = a_t * h + g_t
        return h, h

    h_final, hs = jax.lax.scan(step, h, (af.transpose(1, 0, 2), gf.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), h_final
