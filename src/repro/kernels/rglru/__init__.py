from .kernel import rglru_pallas
from .ops import rglru
from .ref import rglru_ref

__all__ = ["rglru", "rglru_pallas", "rglru_ref"]
