"""Pallas TPU kernel for the RG-LRU diagonal recurrence.

TPU mapping
-----------
grid = (B, W / block_w, T / chunk) — batch and width are parallel, the
time-chunk axis is sequential so the (1, block_w) fp32 state persists in
VMEM scratch.  Within a chunk the recurrence is a log₂(chunk)-step
Blelloch-style doubling entirely on VPU registers/VMEM:

    (a, g) ∘ (a', g') = (a·a', a'·g + g')

i.e. after k doubling steps row t holds the composition of rows
(t-2ᵏ, t]; chunk=128, block_w=512 → 7 doubling steps over a (128, 512)
fp32 tile ≈ 0.25 MB VMEM.  HBM traffic is exactly 2 reads + 1 write of
the sequence — the kernel exists to avoid XLA's materialized
associative_scan intermediates (log T extra HBM round-trips).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...compat import tpu_compiler_params
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(
    a_ref, g_ref,  # (chunk, block_w)
    h0_ref,  # (1, block_w)
    h_ref,  # out (chunk, block_w)
    hT_ref,  # out (1, block_w) final state
    state_ref,  # scratch (1, block_w) f32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)

    # inclusive associative scan over the chunk (doubling)
    step = 1
    while step < chunk:
        a_shift = jnp.roll(a, step, axis=0)
        g_shift = jnp.roll(g, step, axis=0)
        rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        valid = rows >= step
        g = jnp.where(valid, a * g_shift + g, g)
        a = jnp.where(valid, a * a_shift, a)
        step *= 2

    # fold in carried state: h_t = a_{1..t} * h0 + g_t
    h = a * state_ref[...] + g
    h_ref[...] = h.astype(h_ref.dtype)
    state_ref[...] = h[-1:, :]

    @pl.when(ic == num_chunks - 1)
    def _finish():
        hT_ref[...] = h[-1:, :].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_pallas(
    a: jax.Array,  # (B, T, W)
    g: jax.Array,
    h0: jax.Array | None = None,  # (B, W)
    *,
    chunk: int = 128,
    block_w: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, T, W = a.shape
    chunk = min(chunk, T)
    block_w = min(block_w, W)
    assert T % chunk == 0 and W % block_w == 0, (T, chunk, W, block_w)
    nc, nw = T // chunk, W // block_w
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    h0 = h0.reshape(B, 1, W)

    kernel = functools.partial(_rglru_kernel, chunk=chunk, num_chunks=nc)
    h, hT = pl.pallas_call(
        kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, block_w), lambda b, iw, ic: (b, ic, iw)),
            pl.BlockSpec((None, chunk, block_w), lambda b, iw, ic: (b, ic, iw)),
            pl.BlockSpec((None, 1, block_w), lambda b, iw, ic: (b, 0, iw)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, block_w), lambda b, iw, ic: (b, ic, iw)),
            pl.BlockSpec((None, 1, block_w), lambda b, iw, ic: (b, 0, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, g, h0)
    return h, hT.reshape(B, W)
