"""Sharding rules: DP / FSDP / TP / EP / SP as PartitionSpec pytrees.

Axes
----
``('data', 'model')`` single-pod, ``('pod', 'data', 'model')`` multi-pod.
Batch shards over the data axes; parameters shard FSDP-style:

  * the largest weight dim divisible by |model| shards over ``'model'``
    (expert-stacked weights prefer the expert dim — true EP — when
    divisible, e.g. dbrx 16e on a 16-way model axis);
  * optionally (``fsdp_data=True``, the beyond-paper memory optimization)
    a second dim shards over the data axes, ZeRO-3 style.  The
    paper-faithful baseline keeps parameters replicated across data so
    the gradient synchronization is a pure all-reduce — exactly the
    operation MG-WFBP schedules.

KV caches shard batch over data when divisible, else sequence (SP — the
long_500k single-request regime), and head_dim over ``'model'``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.common import ArchConfig

Pytree = Any

MOE_LEAF_NAMES = ("w_gate", "w_up", "w_down")


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved sharding policy for one (arch, mesh) pair."""

    data_axes: tuple[str, ...]
    model_axis: str
    mesh_shape: dict[str, int]
    # False: params sharded over 'model' only (paper-faithful: DP grads are
    #        pure all-reduces).  True: second dim over the data axes
    #        (ZeRO-3).  'experts_only': serving mode — dense weights stay
    #        model-only (no per-token gathers) while the big expert tables
    #        keep the data dim (they are consumed shard-local under EP).
    fsdp_data: bool | str = False
    # EP archs reserve the model axis for experts: the batch must not
    # shard over it (the MoE all-to-all runs G@data <-> E@model).
    reserve_model: bool = False

    @property
    def data_size(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh_shape[a]
        return n

    @property
    def model_size(self) -> int:
        return self.mesh_shape[self.model_axis]

    def _axes_size(self, axes: tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh_shape[a]
        return n

    def batch_axes(self, batch: int) -> tuple[str, ...] | None:
        """Maximal mesh-axis combination that divides the batch.

        train_4k's 256 rows == one pod's 256 chips, so the batch shards
        over *every* axis (pure 256-way DP; ZeRO-3 gathers the FSDP
        weights).  Smaller batches fall back to fewer axes; batch-1 decode
        returns None and sequence-parallel cache sharding carries the
        parallelism instead.
        """
        candidates = [
            self.data_axes + (self.model_axis,),
            self.data_axes,
            self.data_axes[-1:],
        ]
        if self.reserve_model:
            candidates = candidates[1:]
        for axes in candidates:
            if axes and batch % self._axes_size(axes) == 0:
                return axes
        return None


def rules_for_mesh(mesh: jax.sharding.Mesh, fsdp_data: bool = False) -> ShardingRules:
    names = tuple(mesh.axis_names)
    shape = dict(zip(names, mesh.devices.shape))
    data_axes = tuple(a for a in names if a != "model")
    return ShardingRules(
        data_axes=data_axes, model_axis="model", mesh_shape=shape, fsdp_data=fsdp_data
    )


def rules_for_arch(cfg: ArchConfig, mesh: jax.sharding.Mesh, fsdp_data: bool = False) -> ShardingRules:
    """Arch-aware rules: EP archs reserve the model axis for experts."""
    rules = rules_for_mesh(mesh, fsdp_data)
    ep = cfg.moe is not None and cfg.moe.n_experts % rules.model_size == 0
    return dataclasses.replace(rules, reserve_model=ep)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _leaf_spec(path_names: list[str], shape: tuple[int, ...], rules: ShardingRules) -> P:
    """FSDP spec for one parameter leaf."""
    in_stages = "stages" in path_names
    dims = list(enumerate(shape))
    if in_stages:
        dims = dims[1:]  # leading n_stages axis stays replicated (scan axis)
    if len(dims) < 2:
        return P()  # 1-D (norm scales, biases, lambdas): replicate

    spec: list[str | None] = [None] * len(shape)
    is_moe = any(n in MOE_LEAF_NAMES for n in path_names)
    model_dim = None
    if is_moe:
        e_axis, e_size = dims[0]
        if e_size % rules.model_size == 0:
            model_dim = e_axis
    if model_dim is None:
        for ax, size in sorted(dims, key=lambda t: -t[1]):
            if size % rules.model_size == 0:
                model_dim = ax
                break
    if model_dim is not None:
        spec[model_dim] = rules.model_axis

    want_data = rules.fsdp_data is True or (
        rules.fsdp_data == "experts_only" and is_moe
    )
    if want_data:
        for ax, size in sorted(dims, key=lambda t: -t[1]):
            if ax != model_dim and size % rules.data_size == 0:
                spec[ax] = rules.data_axes
                break

    return P(*spec)


def param_pspecs(param_shapes: Pytree, rules: ShardingRules) -> Pytree:
    """PartitionSpec pytree matching a params (shape) pytree."""

    def spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return _leaf_spec(names, tuple(leaf.shape), rules)

    return jax.tree_util.tree_map_with_path(spec, param_shapes)


# ---------------------------------------------------------------------------
# Activations / batch / caches
# ---------------------------------------------------------------------------


def _batch_axes(rules: ShardingRules, batch: int) -> tuple[str, ...] | None:
    return rules.batch_axes(batch)


def batch_specs(cfg: ArchConfig, rules: ShardingRules, batch: int, seq: int) -> Pytree:
    ba = _batch_axes(rules, batch)
    out = {"targets": P(ba, None)}
    if cfg.input_mode == "embeds":
        out["embeds"] = P(ba, None, None)
    else:
        out["tokens"] = P(ba, None)
    return out


def act_constraint(cfg: ArchConfig, rules: ShardingRules, batch: int):
    """Between-stage activation constraint: batch over data axes."""
    ba = _batch_axes(rules, batch)

    def constrain(x):
        if ba is None:
            return x
        return jax.lax.with_sharding_constraint(x, P(ba, None, None))

    return constrain


def logits_constraint(cfg: ArchConfig, rules: ShardingRules, batch: int):
    ba = _batch_axes(rules, batch)
    vocab_ax = rules.model_axis if cfg.vocab % rules.model_size == 0 else None
    if ba and rules.model_axis in ba:
        vocab_ax = None  # model axis already consumed by the batch

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, P(ba, None, vocab_ax))

    return constrain


def cache_pspecs(cfg: ArchConfig, rules: ShardingRules, cache_shapes: Pytree, batch: int) -> Pytree:
    """Decode cache shardings.

    KV leaves are (n_stages?, B, T, Hkv, hd) (+ kpos (n_stages?, T));
    recurrent states are (n_stages?, B, ...).  Batch shards over data when
    divisible.  The KV *sequence* axis shards over 'model'
    (flash-decoding style): scores contract locally per shard and only the
    per-row softmax statistics and the (B, H, hd) partial outputs cross
    the wire — no weight or cache gathers.  Recurrent state width shards
    over 'model' (elementwise recurrences are embarrassingly parallel
    across width).
    """
    ba = rules.batch_axes(batch)
    if ba and rules.model_axis in ba:
        ba = tuple(a for a in ba if a != rules.model_axis) or None
    ba_size = rules._axes_size(ba) if ba else 0

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        staged = "stages" in names
        body = shape[1:] if staged else shape
        lead: list[str | None] = [None] if staged else []
        if len(body) == 1:  # kpos (T,) — replicated with the seq shards
            return P(*lead, None)
        s: list[Any] = [None] * len(body)
        if ba and body[0] % ba_size == 0:
            s[0] = ba
        if len(body) >= 3:
            # KV cache (B, T, Hkv, hd) or wkv state (B, H, K, K):
            # shard T (axis 1) over 'model' when it divides
            if body[1] % rules.model_size == 0:
                s[1] = rules.model_axis
        elif len(body) == 2 and body[-1] % rules.model_size == 0:
            # (B, W) recurrent state: width over model
            s[-1] = rules.model_axis
        return P(*lead, *s)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def named(tree_of_pspecs: Pytree, mesh: jax.sharding.Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s),
        tree_of_pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
