"""Trace-time activation-sharding context.

Model code is sharding-agnostic; the launcher activates a context and
layers call ``constrain(x, {axis: role})`` at the tensor sites that matter
(projections, hidden states, dispatch buffers).  Roles:

  'batch'  — shard over the data axes (skipped when not divisible, e.g.
             the batch-1 long_500k decode, or inside a shard_map where the
             data axes are manual and must not appear in constraints)
  'model'  — shard over the model axis, bound only under prefer='tp'
             (Megatron TP: MLP hidden, heads — a hillclimb lever)
  'expert' — shard over the model axis regardless of prefer (EP: expert
             dim of MoE dispatch buffers follows the static expert-weight
             sharding)

Without an active context every constrain() is the identity, so tests and
single-device smoke runs never see mesh axes.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ActSharding:
    batch_axes: tuple[str, ...] | None  # None inside shard_map manual DP
    model_axis: str | None
    data_size: int
    model_size: int
    # raw data axes of the mesh (for 'data' contraction-dim roles —
    # decode-EP shards weight-contraction dims instead of gathering)
    data_axes: tuple[str, ...] | None = None
    raw_data_size: int = 1
    # 'fsdp': only batch roles bind; weights are gathered per use and all
    #         activation traffic stays zero (best when tokens/device >> 1).
    # 'tp':   'model' roles also bind (Megatron-style hidden/head sharding;
    #         a hillclimb lever for small-token regimes).
    prefer: str = "fsdp"


_CTX: contextvars.ContextVar[ActSharding | None] = contextvars.ContextVar(
    "act_sharding", default=None
)


@contextlib.contextmanager
def activation_sharding(ctx: ActSharding | None):
    token = _CTX.set(ctx)
    try:
        yield
    finally:
        _CTX.reset(token)


def current() -> ActSharding | None:
    return _CTX.get()


def tp_active() -> bool:
    ctx = _CTX.get()
    return ctx is not None and ctx.prefer == "tp" and ctx.model_axis is not None


def tp_size() -> int:
    ctx = _CTX.get()
    return ctx.model_size if ctx is not None else 1


def constrain(x: jax.Array, roles: dict[int, str]) -> jax.Array:
    """Apply a with_sharding_constraint built from axis roles (see module
    docstring); identity when no context is active or nothing divides."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec: list = [None] * x.ndim
    used = False
    for ax, role in roles.items():
        dim = x.shape[ax]
        if role == "batch" and ctx.batch_axes and dim % ctx.data_size == 0:
            spec[ax] = ctx.batch_axes
            used = True
        elif (
            role == "model"
            and ctx.prefer in ("tp", "seq_tp")
            and ctx.model_axis
            and dim % ctx.model_size == 0
        ):
            spec[ax] = ctx.model_axis
            used = True
        elif role == "expert" and ctx.model_axis and dim % ctx.model_size == 0:
            spec[ax] = ctx.model_axis
            used = True
        elif role == "data" and ctx.data_axes and dim % ctx.raw_data_size == 0:
            spec[ax] = ctx.data_axes
            used = True
    if not used:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def from_rules(rules, batch: int, prefer: str = "fsdp") -> ActSharding:
    """Build the context from ShardingRules for a given global batch."""
    ba = rules.batch_axes(batch)
    size = 1
    if ba:
        for a in ba:
            size *= rules.mesh_shape[a]
    model_ax = rules.model_axis if (not ba or rules.model_axis not in ba) else None
    return ActSharding(
        batch_axes=ba,
        model_axis=model_ax,
        data_size=size if ba else rules.data_size,
        model_size=rules.model_size,
        prefer=prefer,
        data_axes=rules.data_axes,
        raw_data_size=rules.data_size,
    )
