"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention (full /
sliding-window / softcap), dense & gated MLPs, logit softcap.

All attention here is the **jnp fallback path** used for CPU dry-runs and
smoke tests: query-chunked online attention with bounded memory.  The TPU
production path swaps in the Pallas flash kernel (``repro.kernels``) via
``ArchConfig.attn_impl = 'pallas'`` — same signature, same semantics, no
S×S HBM materialization at all.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.context import constrain, tp_active, tp_size
from .common import ArchConfig, Attention, truncated_normal

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, dim: int) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), cfg.param_dtype), "bias": jnp.zeros((dim,), cfg.param_dtype)}
    return {"scale": jnp.zeros((dim,), cfg.param_dtype) if cfg.norm == "rmsnorm_gemma" else jnp.ones((dim,), cfg.param_dtype)}


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    scale = p["scale"].astype(jnp.float32)
    if cfg.norm == "rmsnorm_gemma":
        scale = scale + 1.0  # gemma stores scale-1
    return (y * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE, M-RoPE, sinusoidal)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,  # (3, ..., S): (t, h, w) streams
    theta: float,
    sections: tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary half-dims are split into three
    sections rotated by temporal / height / width positions respectively.
    For text-only tokens the three streams coincide and M-RoPE reduces to
    standard RoPE (tested)."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)  # (half,)
    # section id per half-dim
    sec = np.concatenate([np.full((s,), i) for i, s in enumerate(sections)])
    pos_per_dim = jnp.stack([positions[i] for i in range(3)], axis=0)  # (3, ..., S)
    # select stream per half-dim: (..., S, half)
    ang = jnp.einsum("k...s,kf->...sf", pos_per_dim.astype(jnp.float32),
                     jnp.asarray((sec[None, :] == np.arange(3)[:, None]), jnp.float32) * freqs)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq_len: int, dim: int, offset: int = 0) -> jax.Array:
    """MusicGen-style additive sinusoidal positions."""
    pos = np.arange(offset, offset + seq_len, dtype=np.float64)[:, None]
    freqs = np.exp(-np.log(10000.0) * np.arange(0, dim, 2, dtype=np.float64) / dim)
    ang = pos * freqs[None, :]
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# GQA attention (jnp chunked fallback)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, att: Attention) -> dict:
    d = cfg.d_model
    qd, kvd = att.n_heads * att.head_dim, att.n_kv_heads * att.head_dim
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (d, qd), cfg.param_dtype, std),
        "wk": truncated_normal(ks[1], (d, kvd), cfg.param_dtype, std),
        "wv": truncated_normal(ks[2], (d, kvd), cfg.param_dtype, std),
        "wo": truncated_normal(ks[3], (qd, d), cfg.param_dtype, (qd) ** -0.5),
    }
    if att.qk_norm:
        p["q_norm"] = jnp.ones((att.head_dim,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((att.head_dim,), cfg.param_dtype)
    return p


def _chunk_iter(fn, n_chunks: int, mode: str):
    """Run ``fn(i)`` for i in range(n_chunks), stacked on axis 0.

    mode='map'    -> lax.map (one body in HLO; memory-realistic, used for
                     full-program dry-runs)
    mode='unroll' -> python loop (exact cost_analysis; segment lowering)
    """
    if mode == "unroll":
        return jnp.stack([fn(jnp.asarray(i)) for i in range(n_chunks)], axis=0)
    return jax.lax.map(fn, jnp.arange(n_chunks))


def gqa_attention(
    q: jax.Array,  # (B, S, Hq, hd) — rope already applied
    k: jax.Array,  # (B, T, Hkv, hd)
    v: jax.Array,  # (B, T, Hkv, hd)
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,  # absolute position of q[0] (decode)
    window: int | None = None,
    softcap: float | None = None,
    q_chunk: int = 256,
    chunk_impl: str = "map",
    kpos: jax.Array | None = None,  # absolute key positions (ring caches)
) -> jax.Array:
    """Query-chunked masked attention with bounded score memory.

    Returns (B, S, Hq, hd).  Flash-equivalent numerics (full softmax per
    row — each chunk sees every key, so no online rescaling is needed; the
    Pallas kernel is the tiled-KV variant).  ``kpos`` carries absolute key
    positions for ring-buffer KV caches; unwritten slots hold a large
    sentinel so the causal mask hides them.
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    scale = hd ** -0.5

    if kpos is None:
        kpos = jnp.arange(T)

    # Sequence-TP (prefill on non-EP archs): the model axis is otherwise
    # idle (batch < chips), so q is reshaped into model_size row-blocks
    # sharded over 'model' — every device computes 1/16 of the score rows
    # against the (replicated) K/V.  Context-parallel without all-to-alls.
    from ..parallel.context import current as _ctx_current

    ctx = _ctx_current()
    # peak-memory guard: seq_tp materializes the whole (S/16, S) score
    # block per shard; for many-head archs that exceeds the budget and the
    # chunked-loop path stays the better trade (measured: starcoder2-7b
    # 41.7 GiB vs 13.6 GiB — see EXPERIMENTS.md §Perf It-3b).
    _seq_tp_bytes = 0
    if ctx is not None and ctx.model_axis is not None and ctx.batch_axes:
        _b_loc = max(1, B // ctx.data_size)
        _seq_tp_bytes = _b_loc * Hq * (S // ctx.model_size) * T * 4
    if (
        ctx is not None
        and ctx.prefer == "seq_tp"
        and ctx.model_axis is not None
        and S % (ctx.model_size) == 0
        and S > 1
        and S == T  # self-attention prefill only
        and 0 < _seq_tp_bytes < 8 * 2**30
    ):
        nc = ctx.model_size
        chunk = S // nc
        qb = constrain(qg.reshape(B, nc, chunk, Hkv, G, hd), {0: "batch", 1: "model"})
        scores = jnp.einsum("bnckgh,btkh->bnkgct", qb, k).astype(jnp.float32) * scale
        if softcap is not None:
            scores = jnp.tanh(scores / softcap) * softcap
        qpos = (
            q_offset
            + (jnp.arange(nc) * chunk)[:, None]
            + jnp.arange(chunk)[None, :]
        )  # (nc, chunk)
        mask = jnp.ones((nc, chunk, T), bool)
        if causal:
            mask &= qpos[..., None] >= kpos[None, None, :]
        if window is not None:
            mask &= (qpos[..., None] - kpos[None, None, :]) < window
        scores = jnp.where(mask[None, :, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        o = jnp.einsum("bnkgct,btkh->bnckgh", p, v)
        return o.reshape(B, S, Hq, hd)

    def one_chunk(ci):
        start = ci * q_chunk
        qs = jax.lax.dynamic_slice_in_dim(qg, start, q_chunk, axis=1)
        scores = jnp.einsum("bskgh,btkh->bkgst", qs, k).astype(jnp.float32) * scale
        if softcap is not None:
            scores = jnp.tanh(scores / softcap) * softcap
        qpos = q_offset + start + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, T), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bkgst,btkh->bskgh", p, v)

    if S <= q_chunk:
        # single chunk (decode / short prefill)
        q_chunk = S
        out = one_chunk(0)
        return out.reshape(B, S, Hq, hd)

    assert S % q_chunk == 0, (S, q_chunk)
    chunks = _chunk_iter(one_chunk, S // q_chunk, chunk_impl)
    out = jnp.moveaxis(chunks, 0, 1).reshape(B, S, Hkv, G, hd)
    return out.reshape(B, S, Hq, hd)


def attention_block(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ArchConfig,
    att: Attention,
    *,
    positions: jax.Array,  # (B, S) or (3, B, S) for mrope
    causal: bool = True,
    window: int | None = None,
    kv_cache: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    q_offset: jax.Array | int = 0,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array] | None]:
    """Full attention block: project, position-encode, attend, out-project.

    ``kv_cache`` is ``(k, v, kpos)`` where ``kpos`` (T,) int32 holds the
    absolute position stored in each slot (ring buffer for windowed
    layers; a large sentinel marks unwritten slots).

    * prefill (S > 1): attention runs over the freshly projected k/v; the
      last min(S, T_cache) positions are then written into the cache.
    * decode (S == 1): the new k/v is written at ``q_offset % T_cache``
      and attention runs against the whole cache using stored positions.
    """
    B, S, D = x.shape
    x = constrain(x, {0: "batch"})
    # Under TP (EP archs: the batch must leave the model axis to experts)
    # heads shard over 'model'.  GQA K/V are expanded to the full head
    # count first so the (KV, G) split never fights the head sharding —
    # Megatron-style, at the cost of G× K/V reads (noted in DESIGN.md).
    q = constrain(
        (x @ p["wq"]).reshape(B, S, att.n_heads, att.head_dim), {0: "batch", 2: "model"}
    )
    k = (x @ p["wk"]).reshape(B, S, att.n_kv_heads, att.head_dim)
    v = (x @ p["wv"]).reshape(B, S, att.n_kv_heads, att.head_dim)
    if tp_active() and kv_cache is None and att.n_heads % tp_size() == 0:
        grp = att.n_heads // att.n_kv_heads
        k = jnp.repeat(k, grp, axis=2)
        v = jnp.repeat(v, grp, axis=2)
    k = constrain(k, {0: "batch", 2: "model"})
    v = constrain(v, {0: "batch", 2: "model"})

    if att.qk_norm:
        q = q * jax.lax.rsqrt(jnp.mean(jnp.square(q.astype(jnp.float32)), -1, keepdims=True) + 1e-6).astype(q.dtype) * p["q_norm"]
        k = k * jax.lax.rsqrt(jnp.mean(jnp.square(k.astype(jnp.float32)), -1, keepdims=True) + 1e-6).astype(k.dtype) * p["k_norm"]

    if att.rope == "rope":
        q = apply_rope(q, positions, att.rope_theta)
        k = apply_rope(k, positions, att.rope_theta)
    elif att.rope == "mrope":
        q = apply_mrope(q, positions, att.rope_theta, att.mrope_sections)
        k = apply_mrope(k, positions, att.rope_theta, att.mrope_sections)
    # 'sinusoidal' positions are added at the embedding level; 'none' = NoPE.

    new_cache = None
    kpos = None
    if kv_cache is not None:
        ck, cv, ckpos = kv_cache
        Tc = ck.shape[1]
        if S == 1:
            # decode: ring-buffer write, attend against the cache
            idx = q_offset % Tc if window else q_offset
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), idx, axis=1)
            pos_val = jnp.reshape(jnp.asarray(q_offset, ckpos.dtype), (1,))
            ckpos = jax.lax.dynamic_update_slice_in_dim(ckpos, pos_val, idx, axis=0)
            k, v, kpos = ck, cv, ckpos
        else:
            # prefill: attend over own k/v, then store the trailing window
            keep = min(S, Tc)
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k[:, S - keep :].astype(ck.dtype), 0, axis=1
            )
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v[:, S - keep :].astype(cv.dtype), 0, axis=1
            )
            ckpos = jnp.where(
                jnp.arange(Tc) < keep,
                jnp.arange(Tc) + (S - keep) + q_offset,
                2**30,
            ).astype(ckpos.dtype)
        new_cache = (ck, cv, ckpos)

    o = gqa_attention(
        q, k, v,
        causal=causal,
        q_offset=q_offset,
        window=window,
        softcap=att.softcap,
        q_chunk=cfg.q_chunk,
        chunk_impl=cfg.chunk_impl,
        kpos=kpos,
    )
    out = (o.reshape(B, S, -1).astype(x.dtype)) @ p["wo"]
    return constrain(out.astype(x.dtype), {0: "batch"}), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    std_in, std_out = d ** -0.5, f ** -0.5
    if cfg.mlp in ("swiglu", "geglu"):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": truncated_normal(k1, (d, f), cfg.param_dtype, std_in),
            "w_up": truncated_normal(k2, (d, f), cfg.param_dtype, std_in),
            "w_down": truncated_normal(k3, (f, d), cfg.param_dtype, std_out),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": truncated_normal(k1, (d, f), cfg.param_dtype, std_in),
        "w_down": truncated_normal(k2, (f, d), cfg.param_dtype, std_out),
    }


def mlp_block(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Megatron-style TP: the hidden dim shards over 'model'; the w_down
    contraction then reduces over 'model' and the output is batch-sharded."""
    x = constrain(x, {0: "batch"})
    tp = {0: "batch", 2: "model"}
    if cfg.mlp == "swiglu":
        h = constrain(jax.nn.silu(x @ p["w_gate"]), tp) * constrain(x @ p["w_up"], tp)
    elif cfg.mlp == "geglu":
        h = constrain(jax.nn.gelu(x @ p["w_gate"], approximate=True), tp) * constrain(
            x @ p["w_up"], tp
        )
    else:
        h = constrain(jax.nn.gelu(x @ p["w_up"], approximate=True), tp)
    return constrain(h @ p["w_down"], {0: "batch"})


def softcap_logits(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap
