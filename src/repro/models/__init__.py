"""Model definitions: decoder LMs over heterogeneous block patterns
(dense / local+global / MoE / RWKV6 / RG-LRU hybrid) with the
bucket-segmented layer scan used by the MG-WFBP sync engine."""

from .common import ArchConfig, Attention, MoE, Recurrent, param_count
from .transformer import (
    describe_params,
    forward,
    init_caches,
    init_params,
    loss_fn,
    staged_loss_fns,
)

__all__ = [
    "ArchConfig",
    "Attention",
    "MoE",
    "Recurrent",
    "param_count",
    "describe_params",
    "forward",
    "init_caches",
    "init_params",
    "loss_fn",
    "staged_loss_fns",
]
