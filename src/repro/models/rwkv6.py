"""RWKV6 "Finch" block: token-shift time-mix with data-dependent decay WKV
recurrence, plus squared-ReLU channel-mix [arXiv:2404.05892].

The WKV recurrence per head (state S ∈ R^{hd×hd}):

    out_t = r_t · S  +  (r_t · (u ⊙ k_t)) v_t
    S    <- diag(w_t) · S + k_tᵀ v_t

with per-channel, per-step decay w_t = exp(-exp(base + lora(x_t))).

Training uses the *chunked* parallel form (flash-linear-attention style):
within a chunk of C steps decay products are materialized and the
intra-chunk interaction is a C×C masked matmul; the inter-chunk state is
carried by a scan over chunks.  This is exact (same numerics up to fp
reassociation) and is also the algorithm the Pallas TPU kernel implements
with the state held in VMEM (see kernels/rwkv6_wkv/).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.context import constrain
from .common import ArchConfig, truncated_normal

LORA_MIX = 32  # low-rank size of the 5-way interpolation lora
LORA_DECAY = 64  # low-rank size of the decay lora


def init_rwkv6_block(key, cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.recurrent.head_dim
    n_heads = d // hd
    ks = jax.random.split(key, 12)
    std = d ** -0.5
    pd = cfg.param_dtype
    return {
        "ln1": {"scale": jnp.ones((d,), pd), "bias": jnp.zeros((d,), pd)},
        "ln2": {"scale": jnp.ones((d,), pd), "bias": jnp.zeros((d,), pd)},
        "tm": {
            "mu_x": jnp.zeros((d,), pd),
            "mu_rkvwg": jnp.zeros((5, d), pd),
            "lora_a": truncated_normal(ks[0], (d, 5 * LORA_MIX), pd, std),
            "lora_b": truncated_normal(ks[1], (5, LORA_MIX, d), pd, LORA_MIX ** -0.5),
            "w_r": truncated_normal(ks[2], (d, d), pd, std),
            "w_k": truncated_normal(ks[3], (d, d), pd, std),
            "w_v": truncated_normal(ks[4], (d, d), pd, std),
            "w_g": truncated_normal(ks[5], (d, d), pd, std),
            "w_o": truncated_normal(ks[6], (d, d), pd, std),
            "decay_base": jnp.full((d,), -1.0, jnp.float32),
            "decay_a": truncated_normal(ks[7], (d, LORA_DECAY), pd, std),
            "decay_b": truncated_normal(ks[8], (LORA_DECAY, d), pd, LORA_DECAY ** -0.5),
            "u": jnp.zeros((n_heads, hd), jnp.float32),  # per-head bonus
            "gn_scale": jnp.ones((d,), pd),
        },
        "cm": {
            "mu_k": jnp.zeros((d,), pd),
            "mu_r": jnp.zeros((d,), pd),
            "w_k": truncated_normal(ks[9], (d, f), pd, std),
            "w_v": truncated_normal(ks[10], (f, d), pd, f ** -0.5),
            "w_r": truncated_normal(ks[11], (d, d), pd, std),
        },
    }


def _layernorm(p, x):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Return the previous token's features (first position uses ``prev`` or 0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None] if prev.ndim == 2 else prev
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv_chunked(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # (B, T, H, K) decay in (0, 1), fp32
    u: jax.Array,  # (H, K) current-token bonus
    s0: jax.Array | None = None,  # (B, H, K, K) initial state
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Chunked-parallel WKV6.  Returns (out (B,T,H,K) fp32, final state)."""
    B, T, H, K = r.shape
    chunk = min(chunk, T)
    T_orig = T
    if T % chunk:
        # pad to a chunk multiple: padded steps use decay 1 and zero k/v, so
        # the state passes through unchanged and padded outputs are dropped.
        pad = chunk - T % chunk
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        T = T + pad
    n_chunks = T // chunk
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = w.astype(jnp.float32)

    def reshape_c(a):
        return a.reshape(B, n_chunks, chunk, H, K).transpose(1, 0, 3, 2, 4)  # (N,B,H,C,K)

    rc, kc, vc, wc = map(reshape_c, (rf, kf, vf, wf))
    logw = jnp.log(jnp.maximum(wc, 1e-30))  # (N,B,H,C,K)
    clw = jnp.cumsum(logw, axis=-2)  # inclusive cumulative log-decay

    if s0 is None:
        s0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(S, xs):
        rc_, kc_, vc_, clw_, logw_ = xs  # (B,H,C,K)
        W_prev = jnp.exp(clw_ - logw_)  # prod decay up to t-1 (W_{i-1})
        W_inc = jnp.exp(clw_)  # inclusive W_i
        W_end = W_inc[..., -1:, :]  # (B,H,1,K) full-chunk decay
        r_t = rc_ * W_prev  # r̃
        k_t = kc_ / jnp.maximum(W_inc, 1e-30)  # k̃
        # inter-chunk: r̃ @ S
        inter = jnp.einsum("bhck,bhkv->bhcv", r_t, S)
        # intra-chunk: strictly-lower-triangular (j < i)
        A = jnp.einsum("bhck,bhdk->bhcd", r_t, k_t)
        mask = jnp.tril(jnp.ones((A.shape[-2], A.shape[-1]), bool), k=-1)
        intra = jnp.einsum("bhcd,bhdv->bhcv", jnp.where(mask, A, 0.0), vc_)
        # current-token bonus
        diag = jnp.einsum("bhck,bhck->bhc", rc_, u[None, :, None, :] * kc_)
        cur = diag[..., None] * vc_
        out = inter + intra + cur  # (B,H,C,V)
        # state update
        kw = k_t * W_end  # k̃_j * W_C
        S_new = S * W_end.squeeze(-2)[..., :, None] + jnp.einsum("bhck,bhcv->bhkv", kw, vc_)
        return S_new, out

    S_final, outs = jax.lax.scan(step, s0, (rc, kc, vc, clw, logw))
    # outs: (N, B, H, C, K) -> (B, T, H, K)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, K)
    return out[:, :T_orig], S_final


def rwkv6_time_mix(
    p: dict,
    x: jax.Array,  # (B, T, D)
    cfg: ArchConfig,
    state: dict | None = None,  # {'shift': (B,D), 'wkv': (B,H,K,K)}
) -> tuple[jax.Array, dict]:
    B, T, D = x.shape
    hd = cfg.recurrent.head_dim
    H = D // hd
    prev = _token_shift(x, None if state is None else state["shift"])
    xx = prev - x
    xxx = x + xx * p["mu_x"]
    m = jnp.einsum(
        "btkl,kld->btkd",
        jnp.tanh(xxx @ p["lora_a"]).astype(jnp.float32).reshape(B, T, 5, LORA_MIX),
        p["lora_b"].astype(jnp.float32),
    )  # (B,T,5,D)
    mix = x[:, :, None, :] + xx[:, :, None, :] * (p["mu_rkvwg"].astype(x.dtype) + m.astype(x.dtype))
    x_r, x_k, x_v, x_w, x_g = (mix[:, :, i] for i in range(5))

    r = constrain((x_r @ p["w_r"]).reshape(B, T, H, hd), {0: "batch", 2: "model"})
    k = constrain((x_k @ p["w_k"]).reshape(B, T, H, hd), {0: "batch", 2: "model"})
    v = constrain((x_v @ p["w_v"]).reshape(B, T, H, hd), {0: "batch", 2: "model"})
    g = constrain(jax.nn.silu(x_g @ p["w_g"]), {0: "batch"})

    ww = jnp.tanh(x_w @ p["decay_a"]).astype(jnp.float32) @ p["decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["decay_base"] + ww)).reshape(B, T, H, hd)  # (0,1)

    s0 = None if state is None else state["wkv"]
    out, s_final = wkv_chunked(r, k, v, w, p["u"], s0, chunk=cfg.rec_chunk)

    # per-head group norm
    out = out.reshape(B, T, H, hd)
    mu = jnp.mean(out, -1, keepdims=True)
    var = jnp.var(out, -1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(B, T, D) * p["gn_scale"].astype(jnp.float32)
    out = (out.astype(x.dtype) * g) @ p["w_o"]
    new_state = {"shift": x[:, -1], "wkv": s_final}
    return out, new_state


def rwkv6_channel_mix(
    p: dict, x: jax.Array, state: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    prev = _token_shift(x, state)
    xx = prev - x
    x_k = x + xx * p["mu_k"]
    x_r = x + xx * p["mu_r"]
    kk = constrain(jnp.square(jax.nn.relu(x_k @ p["w_k"])), {0: "batch", 2: "model"})
    out = jax.nn.sigmoid(x_r @ p["w_r"]) * (kk @ p["w_v"])
    return constrain(out, {0: "batch"}), x[:, -1]


def rwkv6_block(
    p: dict, x: jax.Array, cfg: ArchConfig, state: dict | None = None
) -> tuple[jax.Array, dict]:
    """One full RWKV6 layer: time-mix + channel-mix with pre-LN residuals."""
    st_tm = None if state is None else state["tm"]
    st_cm = None if state is None else state["cm"]
    h, new_tm = rwkv6_time_mix(p["tm"], _layernorm(p["ln1"], x), cfg, st_tm)
    x = x + h
    h, new_cm = rwkv6_channel_mix(p["cm"], _layernorm(p["ln2"], x), st_cm)
    x = x + h
    return x, {"tm": new_tm, "cm": new_cm}


def init_rwkv6_state(cfg: ArchConfig, batch: int) -> dict:
    d = cfg.d_model
    hd = cfg.recurrent.head_dim
    H = d // hd
    return {
        "tm": {
            "shift": jnp.zeros((batch, d), cfg.param_dtype),
            "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        },
        "cm": jnp.zeros((batch, d), cfg.param_dtype),
    }
