"""Mixture-of-Experts FFN: top-k routing with GShard-style *grouped*
capacity dispatch [arXiv:2006.16668].

Tokens are laid out as (G groups, T/G tokens); each group routes its own
tokens into per-group expert buffers with capacity C = (T/G)·k·cf/E, so
the dispatch one-hot is (G, T/G, E, C) — linear in total tokens for a
fixed group size.  The launcher sets G to the number of token shards:

  * every group is then shard-local (no cross-shard reductions in the
    dispatch einsums), and
  * with experts sharded over 'model' (EP — dbrx: 16 experts on the
    16-way axis) the (G@batch, E@model) buffer resharding lowers to the
    classic MoE all-to-all; without EP (mixtral: 8 experts don't divide
    16) expert weights are FSDP-gathered per layer instead.

G=1 for smoke tests / single device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.context import constrain
from .common import ArchConfig, MoE, truncated_normal


def init_moe(key, cfg: ArchConfig) -> dict:
    moe = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, moe.n_experts
    ks = jax.random.split(key, 4)
    std_in, std_out = d ** -0.5, f ** -0.5
    return {
        "router": truncated_normal(ks[0], (d, e), jnp.float32, std_in),
        "w_gate": truncated_normal(ks[1], (e, d, f), cfg.param_dtype, std_in),
        "w_up": truncated_normal(ks[2], (e, d, f), cfg.param_dtype, std_in),
        "w_down": truncated_normal(ks[3], (e, f, d), cfg.param_dtype, std_out),
    }


def _capacity(tokens_per_group: int, moe: MoE) -> int:
    c = int(tokens_per_group * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(4, min(tokens_per_group, (c + 3) // 4 * 4))


def moe_block(p: dict, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    moe = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = cfg.moe_groups if T % cfg.moe_groups == 0 else 1
    tg = T // G
    C = _capacity(tg, moe)
    E = moe.n_experts

    # decode-EP: at tiny token counts, gathering the data-dim shards of the
    # expert tables per step is the cost (GB/token); instead replicate the
    # few tokens and shard the weight-CONTRACTION dims over the data axes —
    # every resulting psum is activation-sized (KB at decode shapes).
    decode_ep = T <= 4096 and E >= 2
    if decode_ep:
        xt = constrain(x.reshape(G, tg, D), {2: "data"})
    else:
        xt = constrain(x.reshape(G, tg, D), {0: "batch"})
    logits = xt.astype(jnp.float32) @ p["router"]  # (G, tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, moe.top_k)  # (G, tg, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # position of each (token, k) choice within its expert's capacity
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # (G, tg, k, E)
    flat = onehot.reshape(G, tg * moe.top_k, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, tg, moe.top_k, E)
    pos = jnp.sum(pos * onehot, axis=-1)  # (G, tg, k)
    keep = pos < C

    disp = (
        jax.nn.one_hot(gate_idx, E, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x.dtype)[..., None, :-1]
    )  # (G, tg, k, E, C)
    combine = jnp.sum(disp * gate_vals[..., None, None].astype(x.dtype), axis=2)
    disp = jnp.sum(disp, axis=2)  # (G, tg, E, C)

    # dispatch -> (G, E, C, D); EP reshards G@batch -> E@model (all-to-all)
    if decode_ep:
        xe = constrain(jnp.einsum("gtec,gtd->gecd", disp, xt), {1: "expert", 3: "data"})
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
        h = constrain(
            h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"]), {1: "expert", 3: "data"}
        )
        ye = constrain(jnp.einsum("gecf,efd->gecd", h, p["w_down"]), {1: "expert"})
    else:
        xe = constrain(jnp.einsum("gtec,gtd->gecd", disp, xt), {0: "batch", 1: "expert"})
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
        h = constrain(
            h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"]), {0: "batch", 1: "expert"}
        )
        ye = constrain(jnp.einsum("gecf,efd->gecd", h, p["w_down"]), {0: "batch", 1: "expert"})
    out = jnp.einsum("gecd,gtec->gtd", ye, combine)
    out = constrain(out, {0: "batch"}).reshape(B, S, D)

    # load-balance aux (Switch/GShard)
    frac = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return out, aux
