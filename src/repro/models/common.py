"""Shared model configuration and initialization helpers."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Attention:
    """Attention block options."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int | None = None  # sliding-window size (None = full causal)
    softcap: float | None = None  # attention-logit softcap (gemma2)
    rope_theta: float = 10000.0
    rope: str = "rope"  # 'rope' | 'mrope' | 'sinusoidal' | 'none'
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl
    qk_norm: bool = False


@dataclasses.dataclass(frozen=True)
class MoE:
    """Mixture-of-experts options (None on the config = dense FFN)."""

    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class Recurrent:
    """RG-LRU / RWKV-style recurrent block options."""

    kind: str  # 'rglru' | 'rwkv6'
    conv_width: int = 4  # temporal conv in the Griffin recurrent block
    lru_width: int | None = None  # defaults to d_model
    head_dim: int = 64  # rwkv6 wkv head size


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool (or a reduced smoke variant)."""

    name: str
    family: str  # audio|dense|moe|ssm|hybrid|vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attention: Attention | None
    # repeating block pattern making up one scan stage, e.g. ('attn',) or
    # ('attn_local', 'attn_global') or ('rec', 'rec', 'attn_local');
    # n_layers = len(pattern) * n_stages + len(tail_pattern)
    pattern: tuple[str, ...] = ("attn",)
    tail_pattern: tuple[str, ...] = ()
    moe: MoE | None = None
    recurrent: Recurrent | None = None
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm' | 'rmsnorm_gemma'
    post_norm: bool = False  # gemma2 adds post-block norms
    mlp: str = "swiglu"  # 'swiglu' | 'geglu' | 'gelu' | 'rwkv_cmix'
    tie_embeddings: bool = False
    logit_softcap: float | None = None
    input_mode: str = "tokens"  # 'tokens' | 'embeds' (audio/vlm stub frontends)
    param_dtype: Any = jnp.bfloat16
    # local-attention window used by '*_local' pattern entries
    local_window: int = 4096
    # implementation knobs (not architecture):
    q_chunk: int = 256  # query chunk for the jnp attention fallback
    moe_groups: int = 1  # GShard dispatch groups (= token shards in prod)
    moe_token_chunk: int = 2048  # legacy knob (grouped dispatch supersedes)
    rec_chunk: int = 128  # time chunk for chunked linear recurrences
    chunk_impl: str = "map"  # 'map' (memory-realistic) | 'unroll' (exact cost)
    attn_impl: str = "jnp"  # 'jnp' | 'pallas' (TPU)
    remat: str = "full"  # 'full' | 'dots' | 'none'

    @property
    def n_stages(self) -> int:
        body = self.n_layers - len(self.tail_pattern)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {self.n_layers} layers do not tile by pattern "
            f"{self.pattern} + tail {self.tail_pattern}"
        )
        return body // len(self.pattern)

    def block_kinds(self) -> list[str]:
        """Per-layer kinds, length n_layers."""
        return list(self.pattern) * self.n_stages + list(self.tail_pattern)


def truncated_normal(key, shape, dtype, stddev: float):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev).astype(
        dtype
    )


def param_count(tree: Any) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
