"""Decoder-only LM over heterogeneous block patterns, with the
bucket-segmented layer scan that makes MG-WFBP's merge schedule a
structural property of the compiled program.

Parameters
----------
::

    params = {
      'embed':  (vocab, d)                      # tokens mode
      'stages': pytree stacked on a leading n_stages axis; each stage holds
                one param set per pattern element, keyed '<kind>_<i>'
      'tail':   like one stage, for tail_pattern (or absent)
      'final_norm': {...}
      'head':   (d, vocab)                      # absent when tie_embeddings
    }

The train/serve step functions take ``segments`` — ``(start, stop)`` stage
ranges produced by the MG-WFBP schedule (``core.bucketing``); each segment
is scanned separately so its gradient message is an independent HLO value
that the sync engine all-reduces as one merged (variadic) collective which
XLA can overlap with the previous segment's backward compute.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .common import ArchConfig, param_count, truncated_normal
from .layers import (
    apply_norm,
    attention_block,
    init_attention,
    init_mlp,
    init_norm,
    mlp_block,
    sinusoidal_embedding,
    softcap_logits,
)
from .moe import init_moe, moe_block
from .rglru import init_rglru_block, init_rglru_state, rglru_block
from .rwkv6 import init_rwkv6_block, init_rwkv6_state, rwkv6_block

Pytree = Any

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_sublayer(key, cfg: ArchConfig, kind: str) -> dict:
    if kind == "rwkv":
        return init_rwkv6_block(key, cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict = {"norm1": init_norm(cfg, cfg.d_model), "norm2": init_norm(cfg, cfg.d_model)}
    if cfg.post_norm:
        p["post_norm1"] = init_norm(cfg, cfg.d_model)
        p["post_norm2"] = init_norm(cfg, cfg.d_model)
    if kind == "rec":
        p["mix"] = init_rglru_block(k1, cfg)
    else:  # attn / attn_local / attn_global / moe
        p["attn"] = init_attention(k1, cfg, cfg.attention)
    if kind == "moe":
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k3, cfg)
    return p


def init_params(key, cfg: ArchConfig) -> Pytree:
    ks = jax.random.split(key, 8)
    params: dict = {}
    params["embed"] = truncated_normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32, 1.0)

    def init_stage(k):
        sub = {}
        kk = jax.random.split(k, len(cfg.pattern))
        for i, kind in enumerate(cfg.pattern):
            sub[f"{kind}_{i}"] = _init_sublayer(kk[i], cfg, kind)
        return sub

    stage_keys = jax.random.split(ks[1], cfg.n_stages)
    params["stages"] = jax.vmap(init_stage)(stage_keys)

    if cfg.tail_pattern:
        tail = {}
        kk = jax.random.split(ks[2], len(cfg.tail_pattern))
        for i, kind in enumerate(cfg.tail_pattern):
            tail[f"{kind}_{i}"] = _init_sublayer(kk[i], cfg, kind)
        params["tail"] = tail

    params["final_norm"] = init_norm(cfg, cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = truncated_normal(
            ks[3], (cfg.d_model, cfg.vocab), cfg.param_dtype, cfg.d_model ** -0.5
        )
    return params


# ---------------------------------------------------------------------------
# Stage application
# ---------------------------------------------------------------------------


def _window_for(cfg: ArchConfig, kind: str) -> int | None:
    if kind == "attn_local":
        return cfg.local_window
    if kind in ("attn", "moe") and cfg.attention and cfg.attention.window:
        return cfg.attention.window
    return None


def apply_sublayer(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    kind: str,
    *,
    positions: jax.Array,
    cache: Pytree | None = None,
    q_offset: jax.Array | int = 0,
) -> tuple[jax.Array, Pytree | None, jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        x, new_state = rwkv6_block(p, x, cfg, cache)
        return x, new_state, aux

    if kind == "rec":
        h = apply_norm(cfg, p["norm1"], x)
        h, new_state = rglru_block(p["mix"], h, cfg, cache)
        if cfg.post_norm:
            h = apply_norm(cfg, p["post_norm1"], h)
        x = x + h
    else:
        h = apply_norm(cfg, p["norm1"], x)
        h, new_state = attention_block(
            p["attn"], h, cfg, cfg.attention,
            positions=positions,
            window=_window_for(cfg, kind),
            kv_cache=cache,
            q_offset=q_offset,
        )
        if cfg.post_norm:
            h = apply_norm(cfg, p["post_norm1"], h)
        x = x + h

    h = apply_norm(cfg, p["norm2"], x)
    if kind == "moe":
        h, aux = moe_block(p["moe"], h, cfg)
    else:
        h = mlp_block(p["mlp"], h, cfg)
    if cfg.post_norm:
        h = apply_norm(cfg, p["post_norm2"], h)
    return x + h, new_state, aux


def _remat_wrap(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.remat(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.remat(fn)


def apply_stage(
    stage_p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    pattern: tuple[str, ...],
    *,
    positions: jax.Array,
    caches: Pytree | None = None,
    q_offset: jax.Array | int = 0,
) -> tuple[jax.Array, Pytree | None, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None
    for i, kind in enumerate(pattern):
        key = f"{kind}_{i}"
        cache = caches[key] if caches is not None else None
        x, nc, aux = apply_sublayer(
            stage_p[key], x, cfg, kind,
            positions=positions, cache=cache, q_offset=q_offset,
        )
        aux_total = aux_total + aux
        if new_caches is not None:
            new_caches[key] = nc
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Forward (training / prefill / decode share this body)
# ---------------------------------------------------------------------------


def forward(
    params: Pytree,
    cfg: ArchConfig,
    *,
    tokens: jax.Array | None = None,  # (B, S) int32
    embeds: jax.Array | None = None,  # (B, S, D) — audio/vlm stub frontends
    positions: jax.Array | None = None,
    segments: tuple[tuple[int, int], ...] | None = None,
    caches: Pytree | None = None,  # stacked per-stage caches for serving
    q_offset: jax.Array | int = 0,
    act_sharding_constraint=None,  # callable x -> x, applied between stages
    return_hidden: bool = False,  # skip the head (chunked-CE path)
) -> tuple[jax.Array, Pytree | None, jax.Array]:
    """Returns (logits fp32 — or final hidden states when
    ``return_hidden`` — , new_caches, moe_aux)."""
    if embeds is None:
        x = params["embed"][tokens].astype(cfg.param_dtype)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.param_dtype)  # gemma scaling
    else:
        x = embeds.astype(cfg.param_dtype)
    B, S = x.shape[:2]

    if positions is None:
        base = jnp.arange(S)[None, :] + q_offset
        if cfg.attention and cfg.attention.rope == "mrope":
            positions = jnp.broadcast_to(base, (3, B, S))
        else:
            positions = jnp.broadcast_to(base, (B, S))

    if cfg.attention and cfg.attention.rope == "sinusoidal":
        pos0 = q_offset if isinstance(q_offset, int) else 0
        pe = sinusoidal_embedding(S, cfg.d_model, offset=pos0).astype(x.dtype)
        x = x + pe[None]

    if segments is None:
        segments = ((0, cfg.n_stages),)
    constrain = act_sharding_constraint or (lambda a: a)

    aux_total = jnp.zeros((), jnp.float32)
    new_stage_caches = None

    def stage_body(x, stage_p_and_cache):
        stage_p, cache = stage_p_and_cache
        x = constrain(x)
        fn = _remat_wrap(
            cfg,
            lambda sp, xx, cc: apply_stage(
                sp, xx, cfg, cfg.pattern,
                positions=positions, caches=cc, q_offset=q_offset,
            ),
        )
        x, new_cache, aux = fn(stage_p, x, cache)
        return x, (new_cache, aux)

    collected_caches = []
    aux_parts = []
    for (start, stop) in segments:
        seg_params = jax.tree.map(lambda a: a[start:stop], params["stages"])
        seg_caches = (
            jax.tree.map(lambda a: a[start:stop], caches["stages"])
            if caches is not None
            else None
        )
        x, (seg_new_caches, seg_aux) = jax.lax.scan(
            stage_body, x, (seg_params, seg_caches)
        )
        aux_parts.append(jnp.sum(seg_aux))
        if caches is not None:
            collected_caches.append(seg_new_caches)

    if caches is not None:
        new_stage_caches = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *collected_caches
        ) if len(collected_caches) > 1 else collected_caches[0]

    new_caches = None
    if cfg.tail_pattern:
        tail_cache = caches["tail"] if caches is not None else None
        x = constrain(x)
        fn = _remat_wrap(
            cfg,
            lambda sp, xx, cc: apply_stage(
                sp, xx, cfg, cfg.tail_pattern,
                positions=positions, caches=cc, q_offset=q_offset,
            ),
        )
        x, new_tail_cache, aux = fn(params["tail"], x, tail_cache)
        aux_parts.append(aux)
        if caches is not None:
            new_caches = {"stages": new_stage_caches, "tail": new_tail_cache}
    elif caches is not None:
        new_caches = {"stages": new_stage_caches}

    aux_total = sum(aux_parts) if aux_parts else aux_total

    x = apply_norm(cfg, params["final_norm"], x)
    if return_hidden:
        return x, new_caches, aux_total
    head = params["embed"].T.astype(cfg.param_dtype) if cfg.tie_embeddings else params["head"]
    logits = (x @ head).astype(jnp.float32)
    logits = softcap_logits(logits, cfg.logit_softcap)
    return logits, new_caches, aux_total


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------


CHUNKED_CE_VOCAB = 64000  # big-vocab archs never materialize full logits
CE_SEQ_CHUNK = 512


def _ce_from_hidden(
    cfg: ArchConfig,
    head: jax.Array,
    x: jax.Array,
    targets: jax.Array,
    mask: jax.Array | None = None,
    logits_sharding_constraint=None,
) -> jax.Array:
    """Cross-entropy from post-final-norm hidden states.

    The one CE implementation both the monolithic ``loss_fn`` and the
    DAG step's staged head closure run — shared so the two steps compute
    the same floats.  ``head`` is the (d, vocab) projection (already
    transposed when embeddings are tied).

    Chunked path: the (B, S, V) fp32 logits of a 100k–256k vocab
    dominate training memory when the model axis is consumed by the
    batch; computing the loss per sequence chunk under remat bounds the
    transient to (B, CE_SEQ_CHUNK, V) and recomputes it in backward.
    (``mask`` is a standard-path feature; the chunked archs train
    unmasked.)
    """
    seq = targets.shape[1]
    use_chunked = (
        cfg.vocab >= CHUNKED_CE_VOCAB
        and seq > CE_SEQ_CHUNK
        and seq % CE_SEQ_CHUNK == 0
    )
    if use_chunked:

        @jax.remat
        def ce_chunk(x_c, t_c):
            logits = (x_c @ head).astype(jnp.float32)
            if logits_sharding_constraint is not None:
                logits = logits_sharding_constraint(logits)
            logits = softcap_logits(logits, cfg.logit_softcap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
            return jnp.sum(lse - ll)

        n_chunks = seq // CE_SEQ_CHUNK

        def body(ci):
            x_c = jax.lax.dynamic_slice_in_dim(x, ci * CE_SEQ_CHUNK, CE_SEQ_CHUNK, 1)
            t_c = jax.lax.dynamic_slice_in_dim(targets, ci * CE_SEQ_CHUNK, CE_SEQ_CHUNK, 1)
            return ce_chunk(x_c, t_c)

        if cfg.chunk_impl == "unroll":
            total_nll = sum(body(i) for i in range(n_chunks))
        else:
            total_nll = jnp.sum(jax.lax.map(body, jnp.arange(n_chunks)))
        return total_nll / (targets.shape[0] * seq)

    logits = (x @ head).astype(jnp.float32)
    logits = softcap_logits(logits, cfg.logit_softcap)
    if logits_sharding_constraint is not None:
        logits = logits_sharding_constraint(logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(lse - ll)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(
    params: Pytree,
    batch: dict,
    cfg: ArchConfig,
    segments: tuple[tuple[int, int], ...] | None = None,
    act_sharding_constraint=None,
    logits_sharding_constraint=None,
) -> tuple[jax.Array, dict]:
    x, _, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        segments=segments,
        act_sharding_constraint=act_sharding_constraint,
        return_hidden=True,
    )
    head = (
        params["embed"].T.astype(cfg.param_dtype)
        if cfg.tie_embeddings
        else params["head"]
    )
    ce = _ce_from_hidden(
        cfg, head, x, batch["targets"], mask=batch.get("mask"),
        logits_sharding_constraint=logits_sharding_constraint,
    )
    total = ce + MOE_AUX_COEF * aux
    return total, {"ce": ce, "moe_aux": aux}


def staged_loss_fns(
    cfg: ArchConfig,
    batch: dict,
    segments: tuple[tuple[int, int], ...],
    act_sharding_constraint=None,
    logits_sharding_constraint=None,
):
    """Split the training loss into per-unit closures for the DAG step.

    Returns ``(embed_fn, seg_fns, tail_fn, head_fn)``:

    * ``embed_fn(embed_p) -> x`` — token lookup (+ gemma scaling +
      sinusoidal PE), or the input cast in ``embeds`` mode;
    * ``seg_fns[j](seg_params, x) -> (x, aux)`` — one scan over the
      stages of ``segments[j]`` (caller slices the stacked params);
    * ``tail_fn(tail_p, x) -> (x, aux)`` or ``None``;
    * ``head_fn(head_p, embed_p, x, aux) -> (loss, metrics)`` —
      final-norm + projection + CE (``head_p`` holds ``final_norm`` and,
      untied, ``head``; tied embeddings read ``embed_p`` so its vjp
      carries the tied d_embed contribution).

    Chained with ``jax.vjp`` these compute the same loss as ``loss_fn``
    over the same ``segments`` (shared ``apply_stage`` bodies, shared
    ``_ce_from_hidden``); the split exists so the train step can walk
    the pullbacks in backward order and issue each schedule group's
    all-reduce at the event where its last gradient lands.
    """
    targets = batch["targets"]
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    B, S = (tokens.shape if embeds is None else embeds.shape[:2])

    base = jnp.arange(S)[None, :]
    if cfg.attention and cfg.attention.rope == "mrope":
        positions = jnp.broadcast_to(base, (3, B, S))
    else:
        positions = jnp.broadcast_to(base, (B, S))
    constrain = act_sharding_constraint or (lambda a: a)

    def embed_fn(embed_p):
        if embeds is None:
            x = embed_p[tokens].astype(cfg.param_dtype)
            if cfg.tie_embeddings:
                x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.param_dtype)
        else:
            x = embeds.astype(cfg.param_dtype)
        if cfg.attention and cfg.attention.rope == "sinusoidal":
            pe = sinusoidal_embedding(S, cfg.d_model, offset=0).astype(x.dtype)
            x = x + pe[None]
        return x

    def _stage_apply(pattern):
        def apply(p, v):
            y, _, aux = apply_stage(p, v, cfg, pattern, positions=positions)
            return y, aux

        return _remat_wrap(cfg, apply)

    def make_seg_fn():
        stage_fn = _stage_apply(cfg.pattern)

        def seg_fn(seg_params, x):
            def body(xx, sp):
                return stage_fn(sp, constrain(xx))

            x, auxs = jax.lax.scan(body, x, seg_params)
            return x, jnp.sum(auxs)

        return seg_fn

    seg_fns = tuple(make_seg_fn() for _ in segments)

    tail_fn = None
    if cfg.tail_pattern:
        tail_stage_fn = _stage_apply(cfg.tail_pattern)

        def tail_fn(tail_p, x):
            return tail_stage_fn(tail_p, constrain(x))

    def head_fn(head_p, embed_p, x, aux):
        x = apply_norm(cfg, head_p["final_norm"], x)
        head = (
            embed_p.T.astype(cfg.param_dtype)
            if cfg.tie_embeddings
            else head_p["head"]
        )
        ce = _ce_from_hidden(
            cfg, head, x, targets, mask=batch.get("mask"),
            logits_sharding_constraint=logits_sharding_constraint,
        )
        total = ce + MOE_AUX_COEF * aux
        return total, {"ce": ce, "moe_aux": aux}

    return embed_fn, seg_fns, tail_fn, head_fn


# ---------------------------------------------------------------------------
# Serving: KV/state caches
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Pytree:
    """Empty decode caches for all stages (+tail)."""
    att = cfg.attention

    def cache_for(kind: str):
        if kind == "rwkv":
            return init_rwkv6_state(cfg, batch)
        if kind == "rec":
            return init_rglru_state(cfg, batch)
        window = _window_for(cfg, kind)
        T = min(max_seq, window) if window else max_seq
        shape = (batch, T, att.n_kv_heads, att.head_dim)
        return (
            jnp.zeros(shape, dtype),
            jnp.zeros(shape, dtype),
            jnp.full((T,), 2**30, jnp.int32),  # slot -> absolute position
        )

    def stage_cache():
        return {f"{kind}_{i}": cache_for(kind) for i, kind in enumerate(cfg.pattern)}

    one = stage_cache()
    stages = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_stages,) + a.shape), one
    )
    out = {"stages": stages}
    if cfg.tail_pattern:
        out["tail"] = {
            f"{kind}_{i}": cache_for(kind) for i, kind in enumerate(cfg.tail_pattern)
        }
    return out


def describe_params(cfg: ArchConfig, params: Pytree) -> str:
    n = param_count(params)
    return f"{cfg.name}: {n / 1e9:.3f}B params ({cfg.n_layers} layers, d={cfg.d_model})"
