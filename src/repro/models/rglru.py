"""Griffin / RecurrentGemma recurrent block: temporal conv + RG-LRU
[arXiv:2402.19427].

RG-LRU (real-gated linear recurrent unit), diagonal recurrence:

    r_t = σ(x_t W_a + b_a)            recurrence gate
    i_t = σ(x_t W_x + b_x)            input gate
    a_t = exp(-c · softplus(Λ) ⊙ r_t) with c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Being diagonal and linear in h, the sequence dimension parallelizes with
``jax.lax.associative_scan`` (training/prefill); decode threads the state
directly.  The Pallas kernel (kernels/rglru/) implements the chunked
VMEM-resident variant of the same recurrence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.context import constrain
from .common import ArchConfig, truncated_normal

RGLRU_C = 8.0


def init_rglru_block(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    w = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv_width
    ks = jax.random.split(key, 6)
    pd = cfg.param_dtype
    std = d ** -0.5
    # Λ init so that a^(1/r) spans ~(0.9, 0.999) as in the paper
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / RGLRU_C))
    return {
        "w_main": truncated_normal(ks[0], (d, w), pd, std),
        "w_gate": truncated_normal(ks[1], (d, w), pd, std),
        "conv_w": truncated_normal(ks[2], (cw, w), pd, cw ** -0.5),
        "conv_b": jnp.zeros((w,), pd),
        "wa": truncated_normal(ks[3], (w, w), pd, w ** -0.5),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": truncated_normal(ks[4], (w, w), pd, w ** -0.5),
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_out": truncated_normal(ks[5], (w, d), pd, w ** -0.5),
    }


def causal_conv1d(
    x: jax.Array,  # (B, T, W)
    w: jax.Array,  # (cw, W) depthwise
    b: jax.Array,
    state: jax.Array | None = None,  # (B, cw-1, W) trailing inputs
) -> tuple[jax.Array, jax.Array]:
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(cw)) + b
    return out.astype(x.dtype), xp[:, -(cw - 1) :]


def rglru_scan(
    x: jax.Array,  # (B, T, W) conv output
    p: dict,
    h0: jax.Array | None = None,  # (B, W)
) -> tuple[jax.Array, jax.Array]:
    """Apply the RG-LRU over time via associative scan.  fp32 internally."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r  # (B,T,W), <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)

    if h0 is not None:
        # fold the initial state in as a virtual step-0 contribution:
        # h_1 = a_1 h_0 + sqrt(1-a_1²) i_1 x_1
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block(
    p: dict,
    x: jax.Array,  # (B, T, D) — already normed by the caller
    cfg: ArchConfig,
    state: dict | None = None,  # {'conv': (B,cw-1,W), 'h': (B,W)}
) -> tuple[jax.Array, dict]:
    gate = constrain(jax.nn.gelu(x @ p["w_gate"], approximate=True), {0: "batch", 2: "model"})
    main = constrain(x @ p["w_main"], {0: "batch", 2: "model"})
    conv_state = None if state is None else state["conv"]
    h0 = None if state is None else state["h"]
    main, new_conv = causal_conv1d(main, p["conv_w"], p["conv_b"], conv_state)
    rec, new_h = rglru_scan(main, p, h0)
    out = (rec * gate) @ p["w_out"]
    return constrain(out, {0: "batch"}), {"conv": new_conv, "h": new_h}


def init_rglru_state(cfg: ArchConfig, batch: int) -> dict:
    w = cfg.recurrent.lru_width or cfg.d_model
    cw = cfg.recurrent.conv_width
    return {
        "conv": jnp.zeros((batch, cw - 1, w), cfg.param_dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
