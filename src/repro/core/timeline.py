"""Discrete WFBP timeline evaluation (paper Eqs. 6–8 and 19–21).

Layer convention follows the paper: layers are numbered ``1..L`` in forward
order; backward propagation runs ``L -> 1``; the gradient of layer ``l``
becomes *available* when its backward step finishes; gradient communication
of distinct messages is serialized on one channel (all-reduce is a
collective — only one can make full-bandwidth progress at a time) but
overlaps freely with backward compute.

A *schedule* partitions layers into contiguous groups.  A group ``[lo..hi]``
(1-based, inclusive) is communicated as one merged message whose payload is
the sum of member gradient sizes, becoming available when the gradient of
``lo`` (computed last during backward) is ready.  Groups are communicated in
backward order: the group containing layer ``L`` first, the group containing
layer ``1`` last.  WFBP is the all-singleton partition; SyncEASGD is the
single-group partition; MG-WFBP picks the optimum (paper Theorem 1).

Two *issue-order modes* price the same partition against two executions:

  ``overlap``     — the WFBP/MG-WFBP DAG execution (Shi et al.'s DAG model
                    of S-SGD, arXiv 1805.03812): group g's merged message
                    becomes available the moment its lowest layer's
                    gradient lands, so its wire time hides behind the
                    backward compute of groups g+1.. (the historical — and
                    default — semantics of this module).
  ``serialized``  — the post-backward execution: no message may start
                    before the whole backward pass finishes (the behavior
                    of a train step that synchronizes after
                    ``value_and_grad`` returns).  Same channel law, same
                    payloads — only the availability times move.

For any partition, overlapped ``t_iter`` <= serialized ``t_iter`` (comm
can only start earlier); the property suite pins this.
"""

from __future__ import annotations

import dataclasses

from .comm_model import AllReduceModel
from .cost_model import Hardware, LayerCost, TPU_V5E


@dataclasses.dataclass(frozen=True)
class GroupTrace:
    """Timeline of one merged communication group."""

    layers: tuple[int, int]  # (lo, hi), 1-based inclusive
    nbytes: int
    avail: float  # when the merged gradient is fully available
    start: float  # τ_c — when the all-reduce starts
    finish: float  # when the all-reduce completes


@dataclasses.dataclass(frozen=True)
class TimelineResult:
    """Evaluated iteration timeline for one schedule."""

    t_iter: float
    t_f: float
    t_b: float
    t_comm_total: float  # Σ T_ar over groups (pure wire time)
    t_comm_exposed: float  # t_c^no: non-overlapped communication (paper Fig. 8)
    groups: tuple[GroupTrace, ...]

    @property
    def comm_ratio(self) -> float:
        """r = t_c^no / (t_f + t_b) (paper §II-C)."""
        return self.t_comm_exposed / (self.t_f + self.t_b)

    def speedup(self, n: int) -> float:
        """S(N) = N (t_f + t_b) / t_iter (paper Eq. 4)."""
        return n * (self.t_f + self.t_b) / self.t_iter


def backward_start_times(costs: list[LayerCost], hw: Hardware, t_f: float) -> list[float]:
    """τ_b per layer, 1-based list of length L+1 (index 0 unused).

    τ_b[L] = t_f;  τ_b[l] = τ_b[l+1] + t_b[l+1]                    (Eq. 6/19)
    """
    L = len(costs)
    tau_b = [0.0] * (L + 1)
    tau_b[L] = t_f
    for l in range(L - 1, 0, -1):
        tau_b[l] = tau_b[l + 1] + costs[l].t_b(hw)  # costs is 0-based
    return tau_b


def gradient_avail_times(costs: list[LayerCost], hw: Hardware, t_f: float) -> list[float]:
    """avail[l] = τ_b[l] + t_b[l] — when layer l's gradient is ready."""
    tau_b = backward_start_times(costs, hw, t_f)
    L = len(costs)
    return [0.0] + [tau_b[l] + costs[l - 1].t_b(hw) for l in range(1, L + 1)]


#: Issue-order modes the timeline can price (see module docstring).
MODES = ("overlap", "serialized")


def comm_avail_times(
    costs: list[LayerCost], hw: Hardware, t_f: float, mode: str = "overlap"
) -> list[float]:
    """Per-layer communication availability under an issue-order mode.

    ``overlap``: layer l's message may go the moment its gradient lands
    (``gradient_avail_times``).  ``serialized``: every message waits for
    the end of backward (``t_f + Σ t_b``) — the post-backward step.
    1-based list of length L+1 (index 0 unused).
    """
    if mode not in MODES:
        raise ValueError(f"unknown issue-order mode {mode!r}; known: {MODES}")
    if mode == "overlap":
        return gradient_avail_times(costs, hw, t_f)
    end = t_f + sum(c.t_b(hw) for c in costs)
    return [0.0] + [end] * len(costs)


def evaluate(
    groups: list[tuple[int, int]],
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    t_f: float | None = None,
    mode: str = "overlap",
) -> TimelineResult:
    """Evaluate a contiguous-partition schedule against the WFBP timeline.

    ``groups`` are (lo, hi) 1-based inclusive ranges covering 1..L exactly,
    in ascending order.  Returns the full per-group trace.  ``mode``
    selects the issue order the schedule executes under: ``overlap``
    (default — comm of group g hides behind backward of groups g+1..) or
    ``serialized`` (all comm waits for the end of backward).
    """
    L = len(costs)
    _check_partition(groups, L)
    if t_f is None:
        t_f = sum(c.t_f(hw) for c in costs)
    t_b_total = sum(c.t_b(hw) for c in costs)
    avail = comm_avail_times(costs, hw, t_f, mode)

    traces: list[GroupTrace] = []
    channel_free = 0.0
    for lo, hi in reversed(groups):  # backward (descending) order
        nbytes = sum(costs[i - 1].grad_bytes for i in range(lo, hi + 1))
        t_avail = avail[lo]  # lowest layer's gradient lands last
        start = max(channel_free, t_avail)
        finish = start + ar_model(nbytes)
        traces.append(GroupTrace((lo, hi), nbytes, t_avail, start, finish))
        channel_free = finish

    t_iter = max(traces[-1].finish, t_f + t_b_total)
    t_comm_total = sum(tr.finish - tr.start for tr in traces)
    return TimelineResult(
        t_iter=t_iter,
        t_f=t_f,
        t_b=t_b_total,
        t_comm_total=t_comm_total,
        t_comm_exposed=t_iter - (t_f + t_b_total),
        groups=tuple(traces),
    )


def _check_partition(groups: list[tuple[int, int]], L: int) -> None:
    if not groups:
        raise ValueError("empty schedule")
    expect = 1
    for lo, hi in groups:
        if lo != expect or hi < lo:
            raise ValueError(f"groups {groups} are not a contiguous partition of 1..{L}")
        expect = hi + 1
    if expect != L + 1:
        raise ValueError(f"groups {groups} do not cover 1..{L}")
