"""Gradient synchronization engine (paper Algorithm 2, TPU-native).

The paper's Algorithm 2 runs a background communication thread that pops
layer indices from a queue and calls ``SynchronizedAllReduce`` on merged
buffers.  In JAX the same structure is expressed to the compiler instead:

  * the train step runs inside ``shard_map`` with the data-parallel mesh
    axes **manual** and the model axes **auto** (GSPMD), so the DP
    gradient reduction is written explicitly by us — one all-reduce per
    schedule group;
  * XLA's latency-hiding scheduler overlaps each group's all-reduce with
    the backward computation of earlier layers, because the groups are
    independent ops — structurally the same overlap WFBP gets from its
    background thread.

There is exactly ONE bucketed reducer, ``make_gradient_sync``, driven by
a ``ParamLayout``'s communication units.  Both unit kinds flow through
the same path: ``leaf`` units contribute whole pytree leaves, ``stacked``
units contribute contiguous slices of scan-stacked leaves (a group
spanning stages [a, b) ships ``leaf[a:b]``; XLA folds
slice-of-assembled-grad back to the per-segment gradient value, so each
group's all-reduce depends only on its own scan segment's backward).
The WFBP / SyncEASGD / MG-WFBP distinction is *entirely* in the schedule
a policy produced — there is no separate strategy switch (the old
``SyncConfig.strategy`` is absorbed by ``planning.registry`` aliases).

Three wire layouts:

  ``concat``    — each group's encoded leaves are flattened into one
                  buffer and reduced with a single ``psum``: the merged
                  message of Definition 1, guaranteed one all-reduce HLO
                  op per group on every jax/XLA version — but the merge
                  is paid for with a full extra round-trip of gradient
                  memory traffic (concatenate in, split out).
  ``variadic``  — one ``psum`` over the tuple of leaves (zero-copy);
                  newer XLA lowers this to a single variadic all-reduce
                  (``compat.variadic_psum_is_single_op``), older versions
                  emit one op per leaf and rely on the combiner.
  ``arena``     — the merged buffer without the merge tax: each group's
                  leaves are packed into a preallocated flat arena by the
                  ``kernels/comm_pack`` pack kernel (wire-dtype cast and
                  optional error-feedback residual fused in), reduced
                  with one ``psum``, and unpacked (decompress + DP
                  average fused).  One all-reduce HLO op per group on
                  every jax version, zero concatenate ops, and the only
                  copies are the cast the wire needed anyway.

Every gradient reduction is issued through the typed collective seam
(``fabric.ops.issue(Collective.ALL_REDUCE, ...)``) — the same vocabulary
the planner's fabric cost models price and the serve wire path uses.

``compression='bf16'`` halves fp32 wire traffic on any layout;
``'bf16_ef'`` (arena only) additionally carries the rounding error in a
local error-feedback residual — the EF-SGD trick of
``runtime/compression.py`` fused into the pack —  at which point the
sync is stateful: ``sync(grads, residual) -> (grads, residual)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import axis_size, variadic_psum_is_single_op
# submodule imports (not the fabric package) — core and fabric import each
# other's leaves, and the package __init__s would cycle
from ..fabric.model import Collective
from ..fabric.ops import issue
from ..kernels.comm_pack import pack_arena, unpack_arena
from .bucketing import (
    ParamLayout,
    WireEntry,
    bucket_assignment,
    group_arenas,
    tree_get as _get,
    tree_set as _set,
    wire_entries,
)
from .schedule import Schedule

Pytree = Any

__all__ = [
    "SyncConfig",
    "WireEntry",
    "count_expected_allreduces",
    "make_gradient_sync",
    "wire_entries",
]


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How DP gradients are reduced.

    comm_dtype  : dtype gradients are cast to on the wire (uniform per
                  bucket — required for the merged buffer, and how real
                  systems ship grads anyway).
    average     : divide by the DP world size after summing.
    compression : None | 'bf16' | 'bf16_ef' (arena only; int8 lives in
                  ``runtime/compression.py``).
    fuse        : 'concat' (one flat buffer per group, exactly one
                  all-reduce op, copy each way) | 'variadic' (tuple psum,
                  zero-copy, op count is version-dependent) | 'arena'
                  (packed flat buffer via kernels/comm_pack: one op per
                  group AND no concatenate copies).

    Which layers ride together is NOT configured here — that is the
    schedule, produced by a ``planning.registry`` policy.
    """

    comm_dtype: Any = jnp.float32
    average: bool = True
    compression: str | None = None
    fuse: str = "concat"

    @property
    def wire_dtype(self) -> Any:
        if self.compression in ("bf16", "bf16_ef"):
            return jnp.bfloat16
        return self.comm_dtype


def device_index(dp_axes: tuple[str, ...]):
    """Flat device index over the (manual) DP axes — the trace recorder's
    per-device span attribution key.  Must run inside shard_map."""
    idx = 0
    for ax in dp_axes:
        idx = idx * axis_size(ax) + jax.lax.axis_index(ax)
    return idx


def make_gradient_sync(
    layout: ParamLayout,
    schedule: Schedule,
    dp_axes: tuple[str, ...],
    config: SyncConfig = SyncConfig(),
    recorder=None,
) -> Callable[..., Pytree]:
    """Build ``sync_fn(grads) -> reduced_grads`` for use inside shard_map.

    One all-reduce is issued per schedule group (``fuse='concat'`` /
    ``'arena'``); ``count_expected_allreduces`` states the invariant and
    the tier-1 suite pins it against lowered HLO.  With
    ``compression='bf16_ef'`` the returned function is stateful:
    ``sync_fn(grads, residual) -> (reduced_grads, new_residual)`` where
    ``residual`` is an f32 pytree of ``grads``' structure (zeros to
    start) carrying each device's local quantization error.

    The returned closure exposes the per-group seam the DAG train step
    issues through: ``sync.sync_group(gi, grads, out, residual=None) ->
    (out, residual)`` reduces backward-order group ``gi`` alone, reading
    that group's gradient paths from ``grads`` and writing the reduced
    values into ``out`` — so a caller that knows *when* group ``gi``'s
    last gradient lands can place the all-reduce at exactly that event.
    ``sync(grads)`` is simply all groups in backward order.

    ``recorder`` (a ``profiler.TraceRecorder``) plants data-dependent
    span markers around each group's reduction: the begin marker consumes
    the on-wire value (fires when the merged gradient is ready), the end
    marker consumes the reduced result — the ``wfbp_group{gi}_l{lo}_{hi}``
    spans the overlap report parses.
    """
    if config.fuse not in ("concat", "variadic", "arena"):
        raise ValueError(f"unknown fuse mode {config.fuse!r}")
    if config.compression == "bf16_ef" and config.fuse != "arena":
        raise ValueError("error-feedback compression requires fuse='arena'")
    group_entries = wire_entries(layout, schedule)
    stateful = config.compression == "bf16_ef"
    # (lo, hi) layer spans in backward issue order — names the profiler
    # scopes below and lets the timeline layer know what group i is.
    group_spans = tuple(reversed(schedule.groups))
    # Per-group wire payload (per device): CommUnit.grad_bytes already
    # carries the model-shard division and the wire dtype the layout was
    # built with — the same "p" vector the schedule was optimized over.
    group_wire_bytes = tuple(
        sum(u.grad_bytes for u in units)
        for units in reversed(bucket_assignment(layout, schedule))
    )

    def _marked_issue(name: str, gi: int, val, dp_axes_):
        """The group's psum, optionally bracketed by trace markers."""
        if recorder is None:
            return issue(Collective.ALL_REDUCE, val, dp_axes_)
        dev = device_index(dp_axes_)
        val = recorder.span_begin(name, val, device=dev, nbytes=group_wire_bytes[gi])
        red = issue(Collective.ALL_REDUCE, val, dp_axes_)
        return recorder.span_end(name, red, device=dev)

    def sync_group(gi: int, grads: Pytree, out: Pytree, residual: Pytree | None = None):
        """Reduce group ``gi`` (backward issue order) only."""
        entries = group_entries[gi]
        lo, hi = group_spans[gi]
        world = 1.0
        for ax in dp_axes:
            world *= axis_size(ax)
        name = f"wfbp_group{gi}_l{lo}_{hi}"
        with jax.named_scope(name):
            if config.fuse == "arena":
                return _arena_group(
                    entries, grads, out, residual, dp_axes, world, config,
                    issue_fn=lambda v: _marked_issue(name, gi, v, dp_axes),
                )
            vals, metas = [], []
            for kind, path, ab in entries:
                g = _get(grads, path)
                if kind == "slice":
                    g = g[ab[0] : ab[1]]
                metas.append((kind, path, ab, g.dtype, g.shape))
                vals.append(_encode(g, config))
            if config.fuse == "concat":
                flat = (
                    jnp.concatenate([v.reshape(-1) for v in vals])
                    if len(vals) > 1
                    else vals[0].reshape(-1)
                )
                red = _marked_issue(name, gi, flat, dp_axes)
                parts, off = [], 0
                for _, _, _, _, shp in metas:
                    n = int(np.prod(shp)) if shp else 1
                    parts.append(red[off : off + n].reshape(shp))
                    off += n
            else:
                parts = list(_marked_issue(name, gi, tuple(vals), dp_axes))
            for (kind, path, ab, dt, _), r in zip(metas, parts):
                r = r.astype(dt)
                if config.average:
                    r = (r.astype(jnp.float32) / world).astype(dt)
                out = _write_back(out, kind, path, ab, r)
        return out, residual

    def sync(grads: Pytree, residual: Pytree | None = None):
        if stateful and residual is None:
            raise ValueError("compression='bf16_ef' needs the residual pytree")
        out = grads
        res_out = residual
        # Issue groups in backward order (layer-L group first), matching the
        # availability order the schedule was optimized for.  Each group is
        # wrapped in a named scope so device profiles (and the timeline
        # layer's per-group comm attribution) see the schedule boundaries.
        for gi in range(len(group_entries)):
            out, res_out = sync_group(gi, grads, out, res_out)
        return (out, res_out) if stateful else out

    # Metadata for the instrumentation layer (runtime/timeline.py): the
    # per-group wire payloads, in the same backward issue order the groups
    # execute in — what time_group_comm probes one psum per.
    sync.schedule = schedule
    sync.group_spans = group_spans
    sync.group_wire_bytes = group_wire_bytes
    sync.stateful = stateful
    sync.sync_group = sync_group
    sync.n_groups = len(group_entries)
    return sync


def _arena_group(
    entries: list[WireEntry],
    grads: Pytree,
    out: Pytree,
    residual: Pytree | None,
    dp_axes: tuple[str, ...],
    world,
    config: SyncConfig,
    issue_fn=None,
) -> tuple[Pytree, Pytree | None]:
    """One group over the arena wire path: pack(+cast[+EF]) -> one psum
    -> unpack(+decompress+average).  The arena layout is the plan-time
    ``bucketing.group_arenas`` layout, re-derived here from the traced
    gradient shapes (identical by construction — ``test_arena`` pins it).
    """
    parts, resid, metas = [], [], []
    off = 0
    for kind, path, ab in entries:
        g = _get(grads, path)
        if kind == "slice":
            g = g[ab[0] : ab[1]]
        if residual is not None:
            r = _get(residual, path)
            resid.append(r[ab[0] : ab[1]] if kind == "slice" else r)
        n = int(np.prod(g.shape)) if g.shape else 1
        metas.append((kind, path, ab, g.dtype, g.shape, off, n))
        parts.append(g)
        off += n
    arena, new_res = pack_arena(
        parts, [m[5] for m in metas], off, config.wire_dtype,
        residuals=resid if residual is not None else None,
    )
    if issue_fn is None:
        issue_fn = lambda v: issue(Collective.ALL_REDUCE, v, dp_axes)
    red = issue_fn(arena)
    scale = (1.0 / world) if config.average else 1.0
    unpacked = unpack_arena(
        red,
        [(m[5], m[6]) for m in metas],
        [m[4] for m in metas],
        [m[3] for m in metas],
        scale=scale,
    )
    for (kind, path, ab, _, _, _, _), r in zip(metas, unpacked):
        out = _write_back(out, kind, path, ab, r)
    if new_res is not None:
        for (kind, path, ab, _, _, _, _), r in zip(metas, new_res):
            residual = _write_back(residual, kind, path, ab, r)
    return out, residual


def _write_back(tree: Pytree, kind: str, path, ab, value: jax.Array) -> Pytree:
    if kind == "leaf":
        return _set(tree, path, value)
    cur = _get(tree, path)
    return _set(tree, path, cur.at[ab[0] : ab[1]].set(value.astype(cur.dtype)))


def _encode(g: jax.Array, config: SyncConfig) -> jax.Array:
    """Cast to the wire dtype.  'bf16' compression halves DP traffic for
    fp32 grads.  Sub-16-bit wire formats are not expressible through a TPU
    psum (the switch reduces in-flight); the int8 error-feedback path lives
    in ``runtime/compression.py`` and uses a reduce-scatter + quantized
    all-gather decomposition instead of this hook."""
    return g.astype(config.wire_dtype)


def count_expected_allreduces(
    schedule: Schedule,
    config: SyncConfig = SyncConfig(),
    layout: ParamLayout | None = None,
) -> int:
    """Gradient all-reduce ops the sync lowers to.

    'concat' and 'arena' reduce one flat buffer per group — exactly one
    op per group on every jax version.  'variadic' issues one psum per
    group: modern XLA lowers that to a single variadic op per group too,
    while 0.4.x emits one op per operand — the honest expectation there
    needs the layout (wire-leaf count per group).
    """
    if (
        config.fuse in ("concat", "arena")
        or layout is None
        or variadic_psum_is_single_op()
    ):
        return len(schedule.groups)
    return sum(len(entries) for entries in wire_entries(layout, schedule))
