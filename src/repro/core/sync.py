"""Gradient synchronization engine (paper Algorithm 2, TPU-native).

The paper's Algorithm 2 runs a background communication thread that pops
layer indices from a queue and calls ``SynchronizedAllReduce`` on merged
buffers.  In JAX the same structure is expressed to the compiler instead:

  * the train step runs inside ``jax.shard_map`` with the data-parallel
    mesh axes **manual** and the model axes **auto** (GSPMD), so the DP
    gradient reduction is written explicitly by us — one
    ``jax.lax.psum(tuple_of_grads, axes)`` per schedule group;
  * ``psum`` over a tuple lowers to a *single variadic all-reduce* HLO op —
    the merged message of Definition 1 with **zero copies** (beyond-paper:
    B-Caffe materialized a fused buffer);
  * XLA's latency-hiding scheduler overlaps each group's all-reduce with
    the backward computation of earlier layers, because the groups are
    independent ops — structurally the same overlap WFBP gets from its
    background thread.

Three strategies mirror the paper's compared systems:

  ``per_tensor``  — WFBP:   one psum per communication unit
  ``single``      — SyncEASGD: one variadic psum over everything
  ``bucketed``    — MG-WFBP: one variadic psum per schedule group

plus ``compressed`` wrappers (bf16 / int8 + error feedback) as the
communication-dtype option discussed in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .bucketing import CommUnit, ParamLayout, bucket_assignment
from .schedule import Schedule, synceasgd_schedule, wfbp_schedule

Pytree = Any


def _get(tree: Pytree, path: tuple[Any, ...]) -> Any:
    for p in path:
        if hasattr(p, "key"):
            tree = tree[p.key]
        elif hasattr(p, "idx"):
            tree = tree[p.idx]
        else:
            tree = tree[p]
    return tree


def _set(tree: Pytree, path: tuple[Any, ...], value: Any) -> Pytree:
    """Functional set on nested dict/list pytrees."""
    if not path:
        return value
    p = path[0]
    key = p.key if hasattr(p, "key") else p.idx if hasattr(p, "idx") else p
    if isinstance(tree, dict):
        new = dict(tree)
        new[key] = _set(tree[key], path[1:], value)
        return new
    if isinstance(tree, (list, tuple)):
        new_l = list(tree)
        new_l[key] = _set(tree[key], path[1:], value)
        return type(tree)(new_l)
    raise TypeError(f"unsupported container {type(tree)} at {path}")


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How DP gradients are reduced.

    strategy    : 'per_tensor' | 'single' | 'bucketed'
    comm_dtype  : dtype gradients are cast to on the wire (uniform per
                  bucket — required for variadic all-reduce, and how real
                  systems ship grads anyway).
    average     : divide by the DP world size after summing.
    compression : None | 'bf16' | 'int8' (int8 adds error-feedback state).
    """

    strategy: str = "bucketed"
    comm_dtype: Any = jnp.float32
    average: bool = True
    compression: str | None = None


def make_gradient_sync(
    layout: ParamLayout,
    schedule: Schedule,
    dp_axes: tuple[str, ...],
    config: SyncConfig = SyncConfig(),
) -> Callable[[Pytree], Pytree]:
    """Build ``sync_fn(grads) -> reduced_grads`` for use inside shard_map.

    One variadic ``psum`` is issued per schedule group; tests assert the
    lowered HLO contains exactly ``len(schedule.groups)`` all-reduce ops.
    """
    if config.strategy == "per_tensor":
        schedule = wfbp_schedule(layout.num_layers)
    elif config.strategy == "single":
        schedule = synceasgd_schedule(layout.num_layers)
    buckets = bucket_assignment(layout, schedule)

    def sync(grads: Pytree) -> Pytree:
        world = 1.0
        for ax in dp_axes:
            world *= jax.lax.axis_size(ax)
        out = grads
        # Issue groups in backward order (layer-L group first), matching the
        # availability order the schedule was optimized for.
        for units in reversed(buckets):
            leaves, paths, orig_dtypes = [], [], []
            for u in units:
                for path in u.paths:
                    g = _get(grads, path)
                    paths.append(path)
                    orig_dtypes.append(g.dtype)
                    leaves.append(_encode(g, config))
            reduced = jax.lax.psum(tuple(leaves), dp_axes)
            for path, r, dt in zip(paths, reduced, orig_dtypes):
                r = _decode(r, dt, config)
                if config.average:
                    r = (r / world).astype(dt)
                out = _set(out, path, r)
        return out

    return sync


def _encode(g: jax.Array, config: SyncConfig) -> jax.Array:
    """Cast to the wire dtype.  'bf16' compression halves DP traffic for
    fp32 grads.  Sub-16-bit wire formats are not expressible through a TPU
    psum (the switch reduces in-flight); the int8 error-feedback path lives
    in ``runtime/compression.py`` and uses a reduce-scatter + quantized
    all-gather decomposition instead of this hook."""
    if config.compression == "bf16":
        return g.astype(jnp.bfloat16)
    return g.astype(config.comm_dtype)


def _decode(r: jax.Array, orig_dtype: Any, config: SyncConfig) -> jax.Array:
    return r.astype(orig_dtype)


def count_expected_allreduces(schedule: Schedule, config: SyncConfig, num_units: int) -> int:
    if config.strategy == "per_tensor":
        return num_units
    if config.strategy == "single":
        return 1
    return len(schedule.groups)


# ---------------------------------------------------------------------------
# Stacked-LM sync: schedule units = [embed, stage_1..stage_n, head]
# ---------------------------------------------------------------------------


def make_stacked_lm_sync(
    schedule: Schedule,
    n_stages: int,
    dp_axes: tuple[str, ...],
    config: SyncConfig = SyncConfig(),
    has_tail: bool = False,
):
    """Bucketed gradient sync for the stacked-layer LM param layout.

    Schedule units (paper layer numbering, gradient of unit 1 lands last):
      unit 1            = embed (+ tied head)
      units 2..n+1      = scan stages (stacked leaves, sliced per bucket)
      unit n+2 (+tail)  = head + final_norm (+ tail stage)

    One variadic psum per schedule group; a group spanning stages [a, b)
    psums the *slices* of the stacked gradients — XLA folds
    slice-of-assembled-grad back to the per-segment gradient value, so
    each group's all-reduce depends only on its own scan segment's
    backward (that is what the schedule's overlap model assumes).
    """
    L = schedule.num_layers
    expected = n_stages + 2 + (1 if has_tail else 0)
    if L != expected:
        raise ValueError(f"schedule has {L} units, layout needs {expected}")

    def sync(grads: Pytree) -> Pytree:
        out = jax.tree.map(lambda g: g, grads)  # shallow copy
        stages_out = dict(out["stages"]) if isinstance(out["stages"], dict) else out["stages"]

        world = 1.0
        for ax in dp_axes:
            world *= jax.lax.axis_size(ax)

        def finish(leaves, reduced):
            outv = []
            for (dtype, _), r in zip(leaves, reduced):
                r = r.astype(jnp.float32) / world if config.average else r
                outv.append(r.astype(dtype))
            return outv

        new_stage_slices: list[tuple[int, int, list]] = []
        new_scalars: dict[str, Any] = {}

        for lo, hi in reversed(schedule.groups):  # backward order
            payload = []  # (orig_dtype, array) in fixed order
            keys = []  # ('embed', path) | ('stage', (a,b), path) | ...
            # tail unit index = n_stages + 2 (+ head at n_stages + 2 or +3)
            for unit in range(hi, lo - 1, -1):
                if unit == 1:
                    for path, leaf in jax.tree_util.tree_flatten_with_path(grads["embed"])[0]:
                        payload.append((leaf.dtype, _encode(leaf, config)))
                        keys.append(("embed", tuple(path)))
                elif 2 <= unit <= n_stages + 1:
                    continue  # handled as a contiguous slice below
                else:
                    names = ["final_norm"] + (["head"] if "head" in grads else [])
                    if has_tail and unit == n_stages + 2:
                        names = ["tail"]
                    for nm in names:
                        for path, leaf in jax.tree_util.tree_flatten_with_path(grads[nm])[0]:
                            payload.append((leaf.dtype, _encode(leaf, config)))
                            keys.append((nm, tuple(path)))
            a = max(lo - 2, 0)
            b = min(hi - 1, n_stages)
            if b > a:
                for path, leaf in jax.tree_util.tree_flatten_with_path(grads["stages"])[0]:
                    payload.append((leaf.dtype, _encode(leaf[a:b], config)))
                    keys.append(("stages", (a, b), tuple(path)))

            reduced = jax.lax.psum(tuple(arr for _, arr in payload), dp_axes)
            reduced = finish(payload, reduced)
            for key, r in zip(keys, reduced):
                if key[0] == "stages":
                    _, (a_, b_), path = key
                    new_stage_slices.append((a_, b_, [(path, r)]))
                else:
                    new_scalars.setdefault(key[0], []).append((key[1], r))

        # reassemble
        for nm, items in new_scalars.items():
            sub = grads[nm]
            for path, r in items:
                sub = _set(sub, path, r)
            out[nm] = sub
        stages = grads["stages"]
        for a, b, items in new_stage_slices:
            for path, r in items:
                cur = _get(stages, path)
                cur = cur.at[a:b].set(r.astype(cur.dtype))
                stages = _set(stages, path, cur)
        out["stages"] = stages
        return out

    return sync
