"""Gradient synchronization engine (paper Algorithm 2, TPU-native).

The paper's Algorithm 2 runs a background communication thread that pops
layer indices from a queue and calls ``SynchronizedAllReduce`` on merged
buffers.  In JAX the same structure is expressed to the compiler instead:

  * the train step runs inside ``shard_map`` with the data-parallel mesh
    axes **manual** and the model axes **auto** (GSPMD), so the DP
    gradient reduction is written explicitly by us — one all-reduce per
    schedule group;
  * XLA's latency-hiding scheduler overlaps each group's all-reduce with
    the backward computation of earlier layers, because the groups are
    independent ops — structurally the same overlap WFBP gets from its
    background thread.

There is exactly ONE bucketed reducer, ``make_gradient_sync``, driven by
a ``ParamLayout``'s communication units.  Both unit kinds flow through
the same path: ``leaf`` units contribute whole pytree leaves, ``stacked``
units contribute contiguous slices of scan-stacked leaves (a group
spanning stages [a, b) ships ``leaf[a:b]``; XLA folds
slice-of-assembled-grad back to the per-segment gradient value, so each
group's all-reduce depends only on its own scan segment's backward).
The WFBP / SyncEASGD / MG-WFBP distinction is *entirely* in the schedule
a policy produced — there is no separate strategy switch (the old
``SyncConfig.strategy`` is absorbed by ``planning.registry`` aliases).

Two wire layouts:

  ``concat``    — each group's encoded leaves are flattened into one
                  buffer and reduced with a single ``psum``: the merged
                  message of Definition 1, guaranteed one all-reduce HLO
                  op per group on every jax/XLA version (one copy each
                  way, like B-Caffe's fused buffer).
  ``variadic``  — one ``psum`` over the tuple of leaves (zero-copy);
                  newer XLA lowers this to a single variadic all-reduce,
                  older versions emit one op per leaf and rely on the
                  all-reduce combiner.

plus ``compressed`` wrappers (bf16 + error feedback) as the
communication-dtype option discussed in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import axis_size, variadic_psum_is_single_op
from .bucketing import LEAF, ParamLayout, bucket_assignment
from .schedule import Schedule

Pytree = Any


def _get(tree: Pytree, path: tuple[Any, ...]) -> Any:
    for p in path:
        if hasattr(p, "key"):
            tree = tree[p.key]
        elif hasattr(p, "idx"):
            tree = tree[p.idx]
        else:
            tree = tree[p]
    return tree


def _set(tree: Pytree, path: tuple[Any, ...], value: Any) -> Pytree:
    """Functional set on nested dict/list pytrees."""
    if not path:
        return value
    p = path[0]
    key = p.key if hasattr(p, "key") else p.idx if hasattr(p, "idx") else p
    if isinstance(tree, dict):
        new = dict(tree)
        new[key] = _set(tree[key], path[1:], value)
        return new
    if isinstance(tree, (list, tuple)):
        new_l = list(tree)
        new_l[key] = _set(tree[key], path[1:], value)
        return type(tree)(new_l)
    raise TypeError(f"unsupported container {type(tree)} at {path}")


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How DP gradients are reduced.

    comm_dtype  : dtype gradients are cast to on the wire (uniform per
                  bucket — required for the merged buffer, and how real
                  systems ship grads anyway).
    average     : divide by the DP world size after summing.
    compression : None | 'bf16' (int8 adds error-feedback state and lives
                  in ``runtime/compression.py``).
    fuse        : 'concat' (one flat buffer per group, exactly one
                  all-reduce op) | 'variadic' (tuple psum, zero-copy).

    Which layers ride together is NOT configured here — that is the
    schedule, produced by a ``planning.registry`` policy.
    """

    comm_dtype: Any = jnp.float32
    average: bool = True
    compression: str | None = None
    fuse: str = "concat"


# One wire entry: ('leaf', path, None) or ('slice', path, (a, b)).
WireEntry = tuple[str, tuple[Any, ...], tuple[int, int] | None]


def wire_entries(layout: ParamLayout, schedule: Schedule) -> list[list[WireEntry]]:
    """Per-group wire plan in backward issue order (layer-L group first).

    Leaf units contribute one entry per leaf path; contiguous stacked
    units collapse into one ``[a:b)`` slice entry per stacked leaf path.
    """
    groups: list[list[WireEntry]] = []
    for units in reversed(bucket_assignment(layout, schedule)):
        entries: list[WireEntry] = []
        runs: dict[tuple, list[int]] = {}
        for u in units:
            if u.kind == LEAF:
                entries.extend(("leaf", p, None) for p in u.paths)
            else:
                runs.setdefault(u.paths, []).append(u.stack_index)
        for paths, idxs in runs.items():
            a, b = min(idxs), max(idxs) + 1
            if sorted(idxs) != list(range(a, b)):
                raise ValueError(f"stacked units in one group must be contiguous: {idxs}")
            entries.extend(("slice", p, (a, b)) for p in paths)
        groups.append(entries)
    return groups


def make_gradient_sync(
    layout: ParamLayout,
    schedule: Schedule,
    dp_axes: tuple[str, ...],
    config: SyncConfig = SyncConfig(),
) -> Callable[[Pytree], Pytree]:
    """Build ``sync_fn(grads) -> reduced_grads`` for use inside shard_map.

    One all-reduce is issued per schedule group (``fuse='concat'``);
    ``count_expected_allreduces`` states the invariant and
    ``tests/test_planning.py`` pins it against lowered HLO.
    """
    if config.fuse not in ("concat", "variadic"):
        raise ValueError(f"unknown fuse mode {config.fuse!r}")
    group_entries = wire_entries(layout, schedule)

    def sync(grads: Pytree) -> Pytree:
        world = 1.0
        for ax in dp_axes:
            world *= axis_size(ax)
        out = grads
        # Issue groups in backward order (layer-L group first), matching the
        # availability order the schedule was optimized for.
        for entries in group_entries:
            vals, metas = [], []
            for kind, path, ab in entries:
                g = _get(grads, path)
                if kind == "slice":
                    g = g[ab[0] : ab[1]]
                metas.append((kind, path, ab, g.dtype, g.shape))
                vals.append(_encode(g, config))
            if config.fuse == "concat":
                flat = (
                    jnp.concatenate([v.reshape(-1) for v in vals])
                    if len(vals) > 1
                    else vals[0].reshape(-1)
                )
                red = jax.lax.psum(flat, dp_axes)
                parts, off = [], 0
                for _, _, _, _, shp in metas:
                    n = int(np.prod(shp)) if shp else 1
                    parts.append(red[off : off + n].reshape(shp))
                    off += n
            else:
                parts = list(jax.lax.psum(tuple(vals), dp_axes))
            for (kind, path, ab, dt, _), r in zip(metas, parts):
                r = _decode(r, dt, config)
                if config.average:
                    r = (r.astype(jnp.float32) / world).astype(dt)
                if kind == "leaf":
                    out = _set(out, path, r)
                else:
                    cur = _get(out, path)
                    out = _set(out, path, cur.at[ab[0] : ab[1]].set(r.astype(cur.dtype)))
        return out

    return sync


def _encode(g: jax.Array, config: SyncConfig) -> jax.Array:
    """Cast to the wire dtype.  'bf16' compression halves DP traffic for
    fp32 grads.  Sub-16-bit wire formats are not expressible through a TPU
    psum (the switch reduces in-flight); the int8 error-feedback path lives
    in ``runtime/compression.py`` and uses a reduce-scatter + quantized
    all-gather decomposition instead of this hook."""
    if config.compression == "bf16":
        return g.astype(jnp.bfloat16)
    return g.astype(config.comm_dtype)


def _decode(r: jax.Array, orig_dtype: Any, config: SyncConfig) -> jax.Array:
    return r.astype(orig_dtype)


def count_expected_allreduces(
    schedule: Schedule,
    config: SyncConfig = SyncConfig(),
    layout: ParamLayout | None = None,
) -> int:
    """Gradient all-reduce ops the sync lowers to.

    'concat' fuses each group into one buffer — exactly one op per group
    on every jax version.  'variadic' issues one psum per group: modern
    XLA lowers that to a single variadic op per group too, while 0.4.x
    emits one op per operand — the honest expectation there needs the
    layout (wire-leaf count per group).
    """
    if config.fuse == "concat" or layout is None or variadic_psum_is_single_op():
        return len(schedule.groups)
    return sum(len(entries) for entries in wire_entries(layout, schedule))
