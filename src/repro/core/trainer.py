"""The MG-WFBP training engine (Tier 2): explicit, scheduled DP gradient
communication inside ``shard_map``.

Pipeline (paper Algorithm 2, compiler-expressed):

  1. cost     — per-unit gradient sizes + backward times from a
                ``planning.CostSource`` (analytic Eq. 18 by default, or a
                measured wall-clock / HLO-segment profile);
  2. plan     — a ``planning.registry`` policy (Algorithm 1 ``mg_wfbp``,
                the exact DP ``dp_optimal``, or the WFBP / SyncEASGD /
                fixed-bucket baselines) turns the cost vector into a
                frozen, JSON-serializable ``Plan``;
  3. execute  — the layer scan is segmented on the plan's bucket
                boundaries and gradients are reduced with one all-reduce
                per bucket, all inside ``shard_map`` with the DP axes
                manual and the model axis left to GSPMD.

The engine is re-plannable: ``replan_if_drifted`` (journal MG-WFBP's
online re-planning) swaps in a successor plan built from measured costs,
and elastic restarts rebuild the plan for the new N — plans are cheap
pure functions of (arch, mesh, α–β model) and serialize to JSON so
restarts and dry-runs can reuse them instead of recomputing.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import shard_map
from ..models import loss_fn, staged_loss_fns
from ..models.common import ArchConfig
from ..optim.optimizers import Optimizer
from ..planning import AnalyticCosts, CostSource, build_plan, replan_if_drifted
from ..planning import build_schedule as _registry_build_schedule
from ..planning.plan import Plan
from .bucketing import stacked_lm_layout
from .comm_model import AllReduceModel
from .cost_model import Hardware, LayerCost, TPU_V5E
from .schedule import Schedule
from .sync import SyncConfig, device_index, make_gradient_sync

Pytree = Any


def _tree_size(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def lm_unit_costs(
    cfg: ArchConfig,
    param_shapes: Pytree,
    tokens_per_device: int,
    hw: Hardware = TPU_V5E,
    comm_dtype_bytes: int = 4,
    model_shards: int = 1,
) -> list[LayerCost]:
    """Per-unit LayerCost for the stacked LM layout (paper Eq. 17/18).

    Units in paper order (gradient of unit 1 lands last):
    [embed, stage_1..stage_n, (tail), head+final_norm]."""
    embed_p = _tree_size(param_shapes["embed"])
    stage_p = _tree_size(param_shapes["stages"]) // cfg.n_stages
    norm_p = _tree_size(param_shapes["final_norm"])
    head_p = norm_p + (0 if cfg.tie_embeddings else _tree_size(param_shapes["head"]))
    tail_p = _tree_size(param_shapes["tail"]) if "tail" in param_shapes else 0

    def cost(name, p, bwd, fwd):
        return LayerCost(
            name=name,
            params=p,
            grad_bytes=max(1, p * comm_dtype_bytes // model_shards),
            bwd_flops=bwd,
            fwd_flops=fwd,
        )

    t = tokens_per_device
    units = [cost("embed", embed_p, 2.0 * t * cfg.d_model, 2.0 * t * cfg.d_model)]
    active = 1.0
    if cfg.moe is not None:
        # only top-k of E experts run per token
        active = cfg.moe.top_k / cfg.moe.n_experts
        # attn part of the stage is dense; approximate with the active mix
        active = 0.25 + 0.75 * active if active < 1 else 1.0
    for i in range(cfg.n_stages):
        units.append(
            cost(f"stage_{i}", stage_p, 4.0 * stage_p * t * active, 2.0 * stage_p * t * active)
        )
    if tail_p:
        units.append(cost("tail", tail_p, 4.0 * tail_p * t, 2.0 * tail_p * t))
    head_flops_p = norm_p + cfg.d_model * cfg.vocab  # tied: head matmul still runs
    units.append(cost("head", head_p, 4.0 * head_flops_p * t, 2.0 * head_flops_p * t))
    return units


def build_schedule(
    method: str,
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    bucket_bytes: int = 25 * 2**20,
) -> Schedule:
    """Compatibility shim over the planning registry.

    Scheduler selection lives in ``planning.registry`` — new code should
    call ``planning.build_schedule(policy, ...)`` / ``get_policy`` directly.
    """
    return _registry_build_schedule(
        method, costs, ar_model, hw=hw, bucket_bytes=bucket_bytes
    )


def group_issue_events(
    schedule: Schedule,
    n_stages: int,
    segments: tuple[tuple[int, int], ...],
    has_tail: bool,
) -> dict[Any, tuple[int, ...]]:
    """Map each backward event to the schedule groups it completes.

    Events key the DAG step's backward walk: ``"head"``, ``"tail"``,
    ``("seg", j)`` (the ``j``-th scan segment), ``"embed"`` — in that
    execution order.  Group ``gi`` (backward issue order) appears under
    the event that computes the gradient of its *lowest* unit ``lo``
    (the last member gradient to land, paper Eq. 6): the embed event for
    ``lo == 1``, the segment containing stage ``lo - 2`` for stage
    units, the tail/head events otherwise.  Every group appears exactly
    once — the partition covers all units.
    """
    group_spans = tuple(reversed(schedule.groups))
    n_units = schedule.num_layers
    tail_unit = n_stages + 2 if has_tail else None
    out: dict[Any, list[int]] = {}
    for gi, (lo, _hi) in enumerate(group_spans):
        if lo == 1:
            event: Any = "embed"
        elif lo <= 1 + n_stages:
            s = lo - 2
            event = None
            for j, (start, stop) in enumerate(segments):
                if start <= s < stop:
                    event = ("seg", j)
                    break
            if event is None:
                raise ValueError(
                    f"group {group_spans[gi]} starts at stage {s} but no scan "
                    f"segment in {segments} contains it"
                )
        elif tail_unit is not None and lo == tail_unit:
            event = "tail"
        elif lo == n_units:
            event = "head"
        else:
            raise ValueError(f"group {group_spans[gi]} has no issue event")
        out.setdefault(event, []).append(gi)
    assert sum(len(v) for v in out.values()) == len(group_spans)
    return {k: tuple(v) for k, v in out.items()}


@dataclasses.dataclass
class MGWFBPEngine:
    """Plan + sync bundle for one (arch, mesh) pair.

    The schedule, scan segmentation, cost vector, and provenance all live
    in the frozen ``plan``; the engine adds the executable pieces (the
    bucketed sync closure and the shard_map train step).
    """

    cfg: ArchConfig
    plan: Plan
    sync: Any
    dp_axes: tuple[str, ...]
    sync_config: SyncConfig = SyncConfig()

    @property
    def schedule(self) -> Schedule:
        return self.plan.schedule

    @property
    def segments(self) -> tuple[tuple[int, int], ...]:
        return self.plan.segments

    @property
    def stateful(self) -> bool:
        """True when the sync carries error-feedback state: the train step
        then takes and returns the residual pytree."""
        return self.sync_config.compression == "bf16_ef"

    def dp_world(self, mesh) -> int:
        return int(np.prod([mesh.shape[ax] for ax in self.dp_axes]))

    def init_residual(self, params: Pytree, mesh=None) -> Pytree | None:
        """Zero f32 error-feedback residual (``compression='bf16_ef'``),
        None for stateless compression.

        The residual is *per-device* state (each device carries the
        quantization error of its own local gradient contribution), so
        every leaf gets a leading DP axis of the mesh's data-parallel
        world size — sharded over ``dp_axes`` through the train step and
        stored whole in checkpoints (a restart at a different world size
        fails the shape check and re-initializes, like any elastic
        restart).  ``mesh=None`` means world size 1.
        """
        if not self.stateful:
            return None
        world = self.dp_world(mesh) if mesh is not None else 1
        return jax.tree.map(
            lambda x: jnp.zeros((world, *x.shape), jnp.float32), params
        )

    @classmethod
    def build(
        cls,
        cfg: ArchConfig,
        param_shapes: Pytree,
        *,
        dp_axes: tuple[str, ...],
        ar_model: AllReduceModel | None = None,
        tokens_per_device: int | None = None,
        hw: Hardware = TPU_V5E,
        policy: str | None = None,
        method: str | None = None,  # legacy alias for ``policy``
        sync_config: SyncConfig = SyncConfig(),
        model_shards: int = 1,
        plan: Plan | None = None,
        cost_source: CostSource | None = None,
    ) -> "MGWFBPEngine":
        """Build from an existing ``plan``, or derive one from a cost
        source + policy (the planning lifecycle's first three legs)."""
        if plan is not None:
            requested = policy or method
            if requested is not None:
                from ..planning import resolve_policy_name

                if resolve_policy_name(requested) != plan.policy:
                    raise ValueError(
                        f"plan was built with policy {plan.policy!r}; drop the "
                        f"policy argument to reuse it, or re-plan with {requested!r}"
                    )
        if plan is None:
            if ar_model is None:
                raise ValueError("either a plan or an ar_model is required")
            comm_bytes = (
                jnp.dtype(sync_config.comm_dtype).itemsize
                if sync_config.compression is None
                else 2
            )
            layout = stacked_lm_layout(
                param_shapes, cfg.n_stages,
                comm_dtype_bytes=comm_bytes, model_shards=model_shards,
            )
            if cost_source is None:
                if tokens_per_device is None:
                    raise ValueError("tokens_per_device is required for analytic costs")
                cost_source = AnalyticCosts(
                    costs=tuple(
                        lm_unit_costs(
                            cfg, param_shapes, tokens_per_device,
                            hw=hw, model_shards=model_shards,
                            comm_dtype_bytes=comm_bytes,
                        )
                    ),
                    hw=hw,
                )
            plan = build_plan(
                layout,
                cost_source.layer_costs(),
                ar_model,
                policy=policy or method or "mg_wfbp",
                hw=cost_source.hw,
                n_scan_stages=cfg.n_stages,
                cost_source=cost_source.name,
                provenance={"arch": cfg.name},
            )
        if plan.n_scan_stages not in (None, cfg.n_stages):
            raise ValueError(
                f"plan was built for {plan.n_scan_stages} scan stages, "
                f"arch {cfg.name} has {cfg.n_stages}"
            )
        sync = make_gradient_sync(plan.layout, plan.schedule, dp_axes, sync_config)
        return cls(
            cfg=cfg, plan=plan, sync=sync, dp_axes=dp_axes, sync_config=sync_config
        )

    def with_plan(self, plan: Plan) -> "MGWFBPEngine":
        """Same engine, different plan (rebuilds the sync closure)."""
        return MGWFBPEngine.build(
            self.cfg, None, dp_axes=self.dp_axes,
            sync_config=self.sync_config, plan=plan,
        )

    def replan(
        self,
        measured: CostSource,
        threshold: float = 0.15,
        policy: str | None = None,
    ) -> tuple["MGWFBPEngine", bool]:
        """Online re-planning hook: returns (engine, replanned).

        When measured costs drift beyond ``threshold`` the policy reruns
        and a new engine (new sync + segments) is returned; the caller
        must rebuild its train step (the scan segmentation changed).
        """
        new_plan, changed = replan_if_drifted(
            self.plan, measured, threshold=threshold, policy=policy
        )
        if not changed:
            return self, False
        return self.with_plan(new_plan), True

    def make_train_step(
        self, optimizer: Optimizer, mesh, *, lr: float = 3e-4,
        issue: str = "post", recorder=None,
    ):
        """Shard-map train step: manual DP axes, auto model axis.

        Stateless sync: ``step(params, opt_state, batch) -> (params,
        opt_state, metrics)``.  With ``compression='bf16_ef'`` the
        error-feedback residual threads through: ``step(params, opt_state,
        residual, batch) -> (params, opt_state, residual, metrics)`` —
        seed it with ``init_residual(params, mesh)`` and checkpoint it
        beside the optimizer state so EF survives restarts.  The residual
        is per-device state: its leaves carry a leading DP axis sharded
        over ``dp_axes`` (each device reads and writes only its own
        slice), never falsely claimed replicated.

        ``issue`` selects the communication issue order
        (``core.timeline.MODES`` maps onto it: ``'dag'`` executes what
        ``mode='overlap'`` prices, ``'post'`` what ``'serialized'``
        prices):

        * ``'post'`` — the historical step: one ``value_and_grad`` over
          the whole model, then every group's all-reduce;
        * ``'dag'`` — the WFBP DAG step: the forward records one
          ``jax.vjp`` pullback per unit event (embed / scan segment /
          tail / head), backward walks them in reverse, and each
          schedule group's merged all-reduce is issued *at the event
          where its last gradient lands* — program order, not compiler
          luck, puts the wire inside backward.  Group ``g``'s psum
          depends only on gradients already computed when it issues, so
          its wire time hides behind the backward of groups ``g+1..``.

        ``recorder`` (a ``profiler.TraceRecorder``) plants data-dependent
        span markers: ``bwd_*`` around each backward event and
        ``wfbp_group*`` around each group's reduction — the spans
        ``profiler.overlap_report`` turns into a measured overlap
        fraction.
        """
        if issue not in ("post", "dag"):
            raise ValueError(f"unknown issue order {issue!r}; known: ('post', 'dag')")
        cfg = self.cfg
        P = jax.sharding.PartitionSpec

        batch_spec = {"targets": P(self.dp_axes, None)}
        if cfg.input_mode == "embeds":
            batch_spec["embeds"] = P(self.dp_axes, None, None)
        else:
            batch_spec["tokens"] = P(self.dp_axes, None)

        sync = self.sync
        if recorder is not None:
            # rebuild the sync closure with markers woven around each psum
            sync = make_gradient_sync(
                self.plan.layout, self.plan.schedule, self.dp_axes,
                self.sync_config, recorder=recorder,
            )

        if issue == "dag":
            return self._make_dag_step(
                optimizer, mesh, lr=lr, sync=sync, recorder=recorder,
                batch_spec=batch_spec,
            )

        def grads_and_loss(params, batch):
            def loss(p):
                return loss_fn(p, batch, cfg, segments=self.segments)

            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            if recorder is not None:
                dev = device_index(self.dp_axes)
                # one whole-backward span: opens once the loss exists,
                # closes when the last (embed) gradient lands
                recorder.span_begin("bwd_backward", l, device=dev)
                recorder.span_end("bwd_backward", grads["embed"], device=dev)
            return (l, metrics), grads

        if self.stateful:
            # residual leaves carry a leading DP axis; inside the manual
            # region each device sees its own (1, ...) slice
            res_spec = P(self.dp_axes)

            def body_ef(params, opt_state, residual, batch):
                (l, metrics), grads = grads_and_loss(params, batch)
                local_res = jax.tree.map(lambda r: r[0], residual)
                grads, new_res = sync(grads, local_res)
                new_residual = jax.tree.map(lambda r: r[None], new_res)
                new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
                l = jax.lax.pmean(l, self.dp_axes)
                return new_params, new_opt, new_residual, {"loss": l}

            smapped = shard_map(
                body_ef,
                mesh=mesh,
                in_specs=(P(), P(), res_spec, batch_spec),
                out_specs=(P(), P(), res_spec, P()),
                axis_names=set(self.dp_axes),
                check_vma=False,
            )
            return jax.jit(smapped, donate_argnums=(0, 1, 2))

        def body(params, opt_state, batch):
            (l, metrics), grads = grads_and_loss(params, batch)
            grads = sync(grads)
            new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
            l = jax.lax.pmean(l, self.dp_axes)
            return new_params, new_opt, {"loss": l}

        smapped = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), batch_spec),
            out_specs=(P(), P(), P()),
            axis_names=set(self.dp_axes),
            check_vma=False,
        )
        return jax.jit(smapped, donate_argnums=(0, 1))

    def _make_dag_step(self, optimizer, mesh, *, lr, sync, recorder, batch_spec):
        """The DAG-scheduled step body (see ``make_train_step``)."""
        cfg = self.cfg
        segments = self.segments
        if segments is None:
            raise ValueError("issue='dag' needs a plan with scan segments")
        events = group_issue_events(
            self.schedule, cfg.n_stages, segments, has_tail=bool(cfg.tail_pattern)
        )
        P = jax.sharding.PartitionSpec

        def dag_grads(params, batch, residual):
            """Staged fwd -> backward walk with in-backward group issue.

            Returns ``(reduced_grads, residual, loss, metrics)``."""
            embed_fn, seg_fns, tail_fn, head_fn = staged_loss_fns(cfg, batch, segments)
            dev = device_index(self.dp_axes) if recorder is not None else 0

            def mark_b(name, dep):
                if recorder is not None:
                    recorder.span_begin(name, dep, device=dev)

            def mark_e(name, dep):
                if recorder is not None:
                    recorder.span_end(name, dep, device=dev)

            # ---- forward: one vjp pullback per unit event --------------
            x, pb_embed = jax.vjp(embed_fn, params["embed"])
            seg_pbs, aux_parts = [], []
            for (start, stop), seg_fn in zip(segments, seg_fns):
                seg_p = jax.tree.map(lambda a: a[start:stop], params["stages"])
                (x, aux), pb = jax.vjp(seg_fn, seg_p, x)
                seg_pbs.append(pb)
                aux_parts.append(aux)
            pb_tail = None
            if tail_fn is not None:
                (x, aux), pb_tail = jax.vjp(tail_fn, params["tail"], x)
                aux_parts.append(aux)
            aux_total = sum(aux_parts)
            head_p = {"final_norm": params["final_norm"]}
            if not cfg.tie_embeddings:
                head_p["head"] = params["head"]
            l, pb_head, metrics = jax.vjp(
                head_fn, head_p, params["embed"], x, aux_total, has_aux=True
            )

            # ---- backward: walk pullbacks in reverse, issuing each
            # group's all-reduce the moment its last gradient lands.
            # ``acc`` collects raw per-event gradients (group psums read
            # only already-computed paths — the issue point is program
            # order, not a compiler artifact); ``out`` collects the
            # reduced write-backs (every path is covered by exactly one
            # group, so starting from zeros is fully overwritten).
            acc = dict(jax.tree.map(jnp.zeros_like, params))
            out = jax.tree.map(jnp.zeros_like, params)
            res = residual

            def issue_ready(event, out, res):
                for gi in events.get(event, ()):
                    out, res = sync.sync_group(gi, acc, out, res)
                return out, res

            mark_b("bwd_head", l)
            d_head_p, d_embed_head, dx, daux = pb_head(jnp.ones_like(l))
            mark_e("bwd_head", (d_head_p, dx))
            acc["final_norm"] = d_head_p["final_norm"]
            if not cfg.tie_embeddings:
                acc["head"] = d_head_p["head"]
            out, res = issue_ready("head", out, res)

            if pb_tail is not None:
                mark_b("bwd_tail", dx)
                d_tail_p, dx = pb_tail((dx, daux))
                mark_e("bwd_tail", (d_tail_p, dx))
                acc["tail"] = d_tail_p
                out, res = issue_ready("tail", out, res)

            for j in range(len(segments) - 1, -1, -1):
                start, stop = segments[j]
                mark_b(f"bwd_seg{j}", dx)
                d_seg_p, dx = seg_pbs[j]((dx, daux))
                mark_e(f"bwd_seg{j}", (d_seg_p, dx))
                acc["stages"] = jax.tree.map(
                    lambda g, d: g.at[start:stop].set(d), acc["stages"], d_seg_p
                )
                out, res = issue_ready(("seg", j), out, res)

            mark_b("bwd_embed", dx)
            (d_embed_lookup,) = pb_embed(dx)
            mark_e("bwd_embed", d_embed_lookup)
            acc["embed"] = d_embed_head + d_embed_lookup
            out, res = issue_ready("embed", out, res)
            return out, res, l, metrics

        if self.stateful:
            res_spec = P(self.dp_axes)

            def body_ef(params, opt_state, residual, batch):
                local_res = jax.tree.map(lambda r: r[0], residual)
                grads, new_res, l, metrics = dag_grads(params, batch, local_res)
                new_residual = jax.tree.map(lambda r: r[None], new_res)
                new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
                l = jax.lax.pmean(l, self.dp_axes)
                return new_params, new_opt, new_residual, {"loss": l}

            smapped = shard_map(
                body_ef,
                mesh=mesh,
                in_specs=(P(), P(), res_spec, batch_spec),
                out_specs=(P(), P(), res_spec, P()),
                axis_names=set(self.dp_axes),
                check_vma=False,
            )
            return jax.jit(smapped, donate_argnums=(0, 1, 2))

        def body(params, opt_state, batch):
            grads, _, l, metrics = dag_grads(params, batch, None)
            new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
            l = jax.lax.pmean(l, self.dp_axes)
            return new_params, new_opt, {"loss": l}

        smapped = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), batch_spec),
            out_specs=(P(), P(), P()),
            axis_names=set(self.dp_axes),
            check_vma=False,
        )
        return jax.jit(smapped, donate_argnums=(0, 1))
