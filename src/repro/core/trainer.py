"""The MG-WFBP training engine (Tier 2): explicit, scheduled DP gradient
communication inside ``jax.shard_map``.

Pipeline (paper Algorithm 2, compiler-expressed):

  1. profile  — per-unit gradient sizes + backward times from the arch
                config (analytic Eq. 18 costs, or HLO-profiled segments);
  2. schedule — Algorithm 1 (``mg_wfbp``), the exact DP (``dp_optimal``),
                or the WFBP / SyncEASGD / fixed-bucket baselines;
  3. execute  — the layer scan is segmented on the schedule's bucket
                boundaries and gradients are reduced with one variadic
                all-reduce per bucket (zero-copy merge), all inside
                ``shard_map`` with the DP axes manual and the model axis
                left to GSPMD.

The schedule is recomputed whenever N changes (elastic restart) — it is
a pure function of (arch, mesh, α–β model), never stored in checkpoints.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import loss_fn
from ..models.common import ArchConfig
from ..optim.optimizers import Optimizer
from .bucketing import layer_buckets_for_scan
from .comm_model import AllReduceModel
from .cost_model import Hardware, LayerCost, TPU_V5E
from .schedule import (
    Schedule,
    dp_optimal_schedule,
    evaluate_schedule,
    fixed_bucket_schedule,
    mg_wfbp_schedule,
    synceasgd_schedule,
    wfbp_schedule,
)
from .sync import SyncConfig, make_stacked_lm_sync

Pytree = Any


def _tree_size(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def lm_unit_costs(
    cfg: ArchConfig,
    param_shapes: Pytree,
    tokens_per_device: int,
    hw: Hardware = TPU_V5E,
    comm_dtype_bytes: int = 4,
    model_shards: int = 1,
) -> list[LayerCost]:
    """Per-unit LayerCost for the stacked LM layout (paper Eq. 17/18).

    Units in paper order (gradient of unit 1 lands last):
    [embed, stage_1..stage_n, (tail), head+final_norm]."""
    embed_p = _tree_size(param_shapes["embed"])
    stage_p = _tree_size(param_shapes["stages"]) // cfg.n_stages
    norm_p = _tree_size(param_shapes["final_norm"])
    head_p = norm_p + (0 if cfg.tie_embeddings else _tree_size(param_shapes["head"]))
    tail_p = _tree_size(param_shapes["tail"]) if "tail" in param_shapes else 0

    def cost(name, p, bwd, fwd):
        return LayerCost(
            name=name,
            params=p,
            grad_bytes=max(1, p * comm_dtype_bytes // model_shards),
            bwd_flops=bwd,
            fwd_flops=fwd,
        )

    t = tokens_per_device
    units = [cost("embed", embed_p, 2.0 * t * cfg.d_model, 2.0 * t * cfg.d_model)]
    active = 1.0
    if cfg.moe is not None:
        # only top-k of E experts run per token
        active = cfg.moe.top_k / cfg.moe.n_experts
        # attn part of the stage is dense; approximate with the active mix
        active = 0.25 + 0.75 * active if active < 1 else 1.0
    for i in range(cfg.n_stages):
        units.append(
            cost(f"stage_{i}", stage_p, 4.0 * stage_p * t * active, 2.0 * stage_p * t * active)
        )
    if tail_p:
        units.append(cost("tail", tail_p, 4.0 * tail_p * t, 2.0 * tail_p * t))
    head_flops_p = norm_p + cfg.d_model * cfg.vocab  # tied: head matmul still runs
    units.append(cost("head", head_p, 4.0 * head_flops_p * t, 2.0 * head_flops_p * t))
    return units


def build_schedule(
    method: str,
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    bucket_bytes: int = 25 * 2**20,
) -> Schedule:
    L = len(costs)
    if method == "mg_wfbp":
        return mg_wfbp_schedule(costs, ar_model, hw)
    if method == "dp_optimal":
        return dp_optimal_schedule(costs, ar_model, hw)
    if method == "wfbp":
        return evaluate_schedule(wfbp_schedule(L), costs, ar_model, hw)
    if method == "synceasgd":
        return evaluate_schedule(synceasgd_schedule(L), costs, ar_model, hw)
    if method == "fixed":
        return evaluate_schedule(
            fixed_bucket_schedule(costs, bucket_bytes), costs, ar_model, hw
        )
    raise ValueError(method)


@dataclasses.dataclass
class MGWFBPEngine:
    """Schedule + segment + sync bundle for one (arch, mesh) pair."""

    cfg: ArchConfig
    schedule: Schedule
    segments: tuple[tuple[int, int], ...]
    sync: Any
    dp_axes: tuple[str, ...]

    @classmethod
    def build(
        cls,
        cfg: ArchConfig,
        param_shapes: Pytree,
        *,
        dp_axes: tuple[str, ...],
        ar_model: AllReduceModel,
        tokens_per_device: int,
        hw: Hardware = TPU_V5E,
        method: str = "mg_wfbp",
        sync_config: SyncConfig = SyncConfig(),
        model_shards: int = 1,
    ) -> "MGWFBPEngine":
        costs = lm_unit_costs(
            cfg, param_shapes, tokens_per_device,
            hw=hw, model_shards=model_shards,
            comm_dtype_bytes=jnp.dtype(sync_config.comm_dtype).itemsize
            if sync_config.compression is None
            else 2,
        )
        schedule = build_schedule(method, costs, ar_model, hw)
        if method in ("wfbp",):
            # WFBP communicates every unit separately -> every stage is its
            # own scan segment (compile cost grows with L; that is the
            # point of comparing against it).
            segments = tuple((i, i + 1) for i in range(cfg.n_stages))
        else:
            segments = layer_buckets_for_scan(schedule, cfg.n_stages)
        # NB: the stacked sync buckets purely by the schedule's groups —
        # wfbp/synceasgd arrive here as all-singleton / single-group
        # schedules, so no separate strategy switch is needed.
        sync = make_stacked_lm_sync(
            schedule,
            cfg.n_stages,
            dp_axes,
            config=sync_config,
            has_tail=bool(cfg.tail_pattern),
        )
        return cls(
            cfg=cfg, schedule=schedule, segments=segments, sync=sync, dp_axes=dp_axes
        )

    def make_train_step(self, optimizer: Optimizer, mesh, *, lr: float = 3e-4):
        """Shard-map train step: manual DP axes, auto model axis."""
        cfg = self.cfg
        P = jax.sharding.PartitionSpec

        def body(params, opt_state, batch):
            def loss(p):
                return loss_fn(p, batch, cfg, segments=self.segments)

            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            grads = self.sync(grads)
            new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
            l = jax.lax.pmean(l, self.dp_axes)
            return new_params, new_opt, {"loss": l}

        batch_spec = {"targets": P(self.dp_axes, None)}
        if cfg.input_mode == "embeds":
            batch_spec["embeds"] = P(self.dp_axes, None, None)
        else:
            batch_spec["tokens"] = P(self.dp_axes, None)

        smapped = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), batch_spec),
            out_specs=(P(), P(), P()),
            axis_names=set(self.dp_axes),
            check_vma=False,
        )
        return jax.jit(smapped, donate_argnums=(0, 1))
