"""Backward/forward per-layer compute-time model (paper Eq. 18, Paleo-style).

The paper models the backward time of layer ``l`` as a function of its
parameter count, the device throughput ``G`` and "other factors" θ::

    t_b^(l) = T_b(p^(l), G, θ)                                    (Eq. 18)

We make this concrete with a per-layer *roofline* estimate:

    t = max(flops / (peak_flops * mxu_eff),  bytes / (hbm_bw * hbm_eff))

FLOPs and bytes per layer come from one of two sources:

  * analytic:   flops = ``flops_per_param_token * p * tokens_local``
                (6 for fwd+bwd, 4 for bwd only, 2 for fwd; +attention terms
                supplied by the caller when relevant);
  * measured:   exact per-layer numbers extracted from a compiled HLO
                segment (``core/profiler.py``) — the JAX analogue of the
                paper benchmarking the first few iterations.

Hardware presets carry the constants given in the project brief
(TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM) and a K80 preset used to
reproduce the paper's own experiments.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Per-chip hardware constants for roofline-style time estimates."""

    name: str
    peak_flops: float  # FLOP/s at the training dtype
    hbm_bw: float  # B/s
    mxu_eff: float = 0.6  # achievable fraction of peak on dense matmul
    hbm_eff: float = 0.8  # achievable fraction of peak DRAM bandwidth

    def compute_time(self, flops: float, bytes_accessed: float = 0.0) -> float:
        """Roofline time for one op/layer on one chip."""
        t_flops = flops / (self.peak_flops * self.mxu_eff)
        t_bytes = bytes_accessed / (self.hbm_bw * self.hbm_eff) if bytes_accessed else 0.0
        return max(t_flops, t_bytes)


#: TPU v5e, bf16 — constants from the project brief.
TPU_V5E = Hardware(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9)

#: Nvidia K80 (one GK210 die), fp32 — the paper's GPU.  ~4.37 TFLOP/s fp32
#: boost, 240 GB/s.  mxu_eff=0.33 is a typical K80-era cuDNN CNN efficiency.
NVIDIA_K80 = Hardware(
    name="nvidia_k80", peak_flops=4.37e12, hbm_bw=240e9, mxu_eff=0.33, hbm_eff=0.6
)

#: Calibrated variant used to reproduce the paper's cluster: the paper runs
#: two GK210 dies per node (halving per-die batch) and reports faster
#: per-layer backward times than the analytic conv-flops model; fitting the
#: single free throughput parameter against the paper's measured 8-node
#: MG-WFBP gains (1.2x vs WFBP, 1.36x vs SyncEASGD) gives mxu_eff ~= 1.0 of
#: one die's nominal peak.  All paper-reproduction tables use this preset;
#: the calibration is recorded in EXPERIMENTS.md.
K80_CALIBRATED = Hardware(
    name="nvidia_k80_calibrated", peak_flops=4.37e12, hbm_bw=240e9, mxu_eff=1.0, hbm_eff=0.6
)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Static per-layer record fed to the scheduler.

    Attributes:
      name:        human-readable layer name (diagnostics only).
      params:      number of learnable scalars in the layer ``p^(l)``.
      grad_bytes:  size of the gradient *message* this layer contributes to
                   the data-parallel all-reduce.  Usually
                   ``params * comm_dtype_bytes / model_shards`` — model-axis
                   sharding (FSDP/TP/EP) divides the DP message.
      bwd_flops:   backward FLOPs for this layer (per chip).
      bwd_bytes:   HBM bytes touched in backward (per chip); 0 = flops-bound.
      fwd_flops:   forward FLOPs (per chip), used for t_f.
      fwd_bytes:   HBM bytes touched in forward (per chip).
    """

    name: str
    params: int
    grad_bytes: int
    bwd_flops: float
    bwd_bytes: float = 0.0
    fwd_flops: float = 0.0
    fwd_bytes: float = 0.0

    def t_b(self, hw: Hardware) -> float:
        return hw.compute_time(self.bwd_flops, self.bwd_bytes)

    def t_f(self, hw: Hardware) -> float:
        return hw.compute_time(self.fwd_flops, self.fwd_bytes)


def lm_layer_costs(
    layer_params: list[tuple[str, int]],
    tokens_per_chip: int,
    hw: Hardware = TPU_V5E,
    comm_dtype_bytes: int = 4,
    model_shards: int = 1,
    bwd_flops_per_param_token: float = 4.0,
    fwd_flops_per_param_token: float = 2.0,
    extra_bwd_flops: dict[str, float] | None = None,
    extra_fwd_flops: dict[str, float] | None = None,
    activation_bytes: dict[str, float] | None = None,
) -> list[LayerCost]:
    """Analytic LayerCost list for a parameterized model.

    ``layer_params`` is ordered layer 1..L (forward order), exactly the
    paper's ``p = [p^(1), ..., p^(L)]``.  ``extra_*_flops`` lets callers add
    non-parametric compute (attention score matmuls) per layer name.
    """
    extra_b = extra_bwd_flops or {}
    extra_f = extra_fwd_flops or {}
    act_bytes = activation_bytes or {}
    out = []
    for name, p in layer_params:
        bwd = bwd_flops_per_param_token * p * tokens_per_chip + extra_b.get(name, 0.0)
        fwd = fwd_flops_per_param_token * p * tokens_per_chip + extra_f.get(name, 0.0)
        out.append(
            LayerCost(
                name=name,
                params=p,
                grad_bytes=max(1, p * comm_dtype_bytes // model_shards),
                bwd_flops=bwd,
                bwd_bytes=act_bytes.get(name, 0.0),
                fwd_flops=fwd,
                fwd_bytes=act_bytes.get(name, 0.0),
            )
        )
    return out
