"""Gradient-merge schedules: MG-WFBP (paper Algorithm 1) and baselines.

A schedule is a partition of layers ``1..L`` into contiguous groups (see
``core.timeline``).  The paper represents the same object as the set 𝕄 of
*merged-gradient layers*: ``l ∈ 𝕄`` means layer ``l``'s gradients ride with
layer ``l-1`` (operator ``(l) ⊕ (l-1)``, Definition 1).  Both views are
provided, with converters.

Implemented schedulers
----------------------
``wfbp_schedule``        — no merging (one all-reduce per layer)        [10,12]
``synceasgd_schedule``   — single-layer communication (merge all)       [15]
``fixed_bucket_schedule``— size-threshold bucketing (PyTorch-DDP /
                           Horovod tensor-fusion style)                 [19,24]
``mg_wfbp_schedule``     — paper Algorithm 1 / Theorem 1 (merge layer l
                           iff avail(l-1) − τ_c(l) < a), O(L²), run once
``optimal_schedule``     — exact exhaustive minimum over all 2^(L-1)
                           contiguous partitions (small L; used by tests
                           to validate Theorem 1 and as a beyond-paper
                           exact option for coarse layer grouping)
``dp_optimal_schedule``  — beyond-paper: exact optimum in O(L²) time via a
                           Bellman recursion on the channel-free time (see
                           note below)

A note on Theorem 1
-------------------
The paper claims Algorithm 1 is optimal.  Property-testing against
exhaustive enumeration (see ``tests/test_schedule.py``) shows the greedy
is *not* optimal in general — merging layer ``l`` can delay the merged
message enough to hurt *later* (lower-index) groups, which the local
exchange argument in the paper's proof (conditions C.1–C.3 compare only
adjacent terms) does not capture.  Measured on 3000 random instances the
greedy loses ~24% of the time, with worst-case t_iter 20% above optimal;
in the paper's own regime (many small uniform layers, comm-bound) the gap
is ~0.  ``dp_optimal_schedule`` restores exact optimality in O(L²) time,
still a one-time pre-training cost.
"""

from __future__ import annotations

import dataclasses
import itertools

from .comm_model import AllReduceModel
from .cost_model import Hardware, LayerCost, TPU_V5E
from .timeline import TimelineResult, comm_avail_times, evaluate


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A gradient-merge schedule over L layers."""

    groups: tuple[tuple[int, int], ...]  # ascending contiguous (lo, hi), 1-based
    method: str
    result: TimelineResult | None = None  # filled by schedulers that evaluate

    @property
    def num_layers(self) -> int:
        return self.groups[-1][1]

    @property
    def merged_set(self) -> frozenset[int]:
        """The paper's 𝕄: every non-lowest member of each group."""
        m = set()
        for lo, hi in self.groups:
            m.update(range(lo + 1, hi + 1))
        return frozenset(m)

    @property
    def bucket_sizes(self) -> tuple[int, ...]:
        """Group sizes in forward order (used to segment the layer scan)."""
        return tuple(hi - lo + 1 for lo, hi in self.groups)

    def describe(self) -> str:
        gs = ", ".join(f"[{lo}..{hi}]" for lo, hi in self.groups)
        extra = ""
        if self.result is not None:
            extra = f"  t_iter={self.result.t_iter * 1e3:.3f}ms exposed_comm={self.result.t_comm_exposed * 1e3:.3f}ms"
        return f"{self.method}: {len(self.groups)} groups {gs}{extra}"


def groups_from_merged_set(merged: frozenset[int], L: int) -> tuple[tuple[int, int], ...]:
    """Convert the paper's 𝕄 into contiguous groups."""
    groups = []
    lo = 1
    for l in range(2, L + 1):
        if l not in merged:
            groups.append((lo, l - 1))
            lo = l
    groups.append((lo, L))
    return tuple(groups)


def wfbp_schedule(L: int) -> Schedule:
    """WFBP: every layer is its own message (𝕄 = ∅)."""
    return Schedule(groups=tuple((l, l) for l in range(1, L + 1)), method="wfbp")


def synceasgd_schedule(L: int) -> Schedule:
    """SyncEASGD single-layer communication: one message after backward."""
    return Schedule(groups=((1, L),), method="synceasgd")


def fixed_bucket_schedule(costs: list[LayerCost], bucket_bytes: int) -> Schedule:
    """DDP/Horovod-style size-threshold fusion, filled in backward order."""
    L = len(costs)
    groups_rev: list[tuple[int, int]] = []
    hi = L
    acc = 0
    for l in range(L, 0, -1):
        acc += costs[l - 1].grad_bytes
        if acc >= bucket_bytes or l == 1:
            groups_rev.append((l, hi))
            hi = l - 1
            acc = 0
    return Schedule(groups=tuple(reversed(groups_rev)), method=f"fixed_{bucket_bytes}B")


def mg_wfbp_schedule(
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    t_f: float | None = None,
    mode: str = "overlap",
) -> Schedule:
    """Paper Algorithm 1: find all merged-gradient layers 𝕄.

    Runs once before training (O(L²)); merge layer ``l`` into ``l-1`` iff

        τ_b^(l-2) − τ_c^(l) < a                                  (Eq. 27)

    where τ_b^(l-2) = avail(l-1) is when layer l-1's gradient is ready and
    τ_c^(l) is the communication start of layer l under merges so far.
    ``mode`` substitutes the availability vector (``timeline.MODES``):
    under ``serialized`` every gradient becomes communicable only at the
    end of backward, so the greedy merges everything — the algorithm
    degenerates to SyncEASGD, which is exactly right when no overlap is
    possible (startup ``a`` is then paid once).
    """
    L = len(costs)
    if t_f is None:
        t_f = sum(c.t_f(hw) for c in costs)

    # 1-based working arrays (index 0 unused)
    p = [0] + [c.grad_bytes for c in costs]
    tc = [0.0] + [ar_model(c.grad_bytes) for c in costs]
    avail = comm_avail_times(costs, hw, t_f, mode)

    def calc_comm_start() -> list[float]:
        tau_c = [0.0] * (L + 1)
        tau_c[L] = avail[L]
        for l in range(L - 1, 0, -1):
            tau_c[l] = max(tau_c[l + 1] + tc[l + 1], avail[l])
        return tau_c

    merged: set[int] = set()
    tau_c = calc_comm_start()
    for l in range(L, 1, -1):
        # avail of layer l-1's gradient: τ_b^(l-2)  (== avail[l-1])
        ready_prev = avail[l - 1]
        if ready_prev - tau_c[l] < ar_model.a:
            # MERGE(l): layer l rides with layer l-1
            p[l - 1] += p[l]
            p[l] = 0
            tc[l] = 0.0
            tc[l - 1] = ar_model(p[l - 1])
            tau_c = calc_comm_start()
            merged.add(l)

    groups = groups_from_merged_set(frozenset(merged), L)
    res = evaluate(list(groups), costs, ar_model, hw, t_f, mode=mode)
    return Schedule(groups=groups, method="mg_wfbp", result=res)


def optimal_schedule(
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    t_f: float | None = None,
    max_layers: int = 22,
    mode: str = "overlap",
) -> Schedule:
    """Exact minimum-t_iter schedule by exhaustive partition enumeration.

    2^(L-1) candidates — only for modest L (tests, coarse block grouping).
    Ties are broken toward fewer groups (cheaper startup, fewer fusion
    barriers at equal modeled time).
    """
    L = len(costs)
    if L > max_layers:
        raise ValueError(f"exhaustive search over {L} layers is 2^{L - 1} candidates")
    if t_f is None:
        t_f = sum(c.t_f(hw) for c in costs)

    best: tuple[float, int, tuple[tuple[int, int], ...]] | None = None
    best_res = None
    for cuts in itertools.product((False, True), repeat=L - 1):
        groups = []
        lo = 1
        for l, cut in enumerate(cuts, start=2):
            if cut:
                groups.append((lo, l - 1))
                lo = l
        groups.append((lo, L))
        res = evaluate(groups, costs, ar_model, hw, t_f, mode=mode)
        key = (res.t_iter, len(groups), tuple(groups))
        if best is None or key < best:
            best = key
            best_res = res
    assert best is not None
    return Schedule(groups=best[2], method="optimal_exhaustive", result=best_res)


# ---------------------------------------------------------------------------
# Beyond-paper: exact DP
# ---------------------------------------------------------------------------


def dp_optimal_schedule(
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    t_f: float | None = None,
    mode: str = "overlap",
) -> Schedule:
    """Exact minimum-t_iter schedule in O(L^2) time (beyond-paper).

    Key observation: once the layers communicated so far are fixed as a
    partition, the only state the future depends on is the scalar
    channel-free time ``c``; every later group applies the nondecreasing
    map ``c -> max(c, avail) + T_ar(payload)``, so a smaller prefix finish
    can never hurt any continuation.  Hence

        D(k) = min_{0 <= j < k}  max(D(j), avail_bwd(k)) + T_ar(P(j+1..k))

    over *backward positions* k (k = 1 is the paper's layer L) is an exact
    Bellman recursion, with D(L) = optimal t_iter.  This restores the
    optimality that the paper's greedy Algorithm 1 only attains in its
    benign regime (see module docstring) at the same one-time cost.  The
    recursion is mode-agnostic: ``mode`` only swaps the availability
    vector (``timeline.comm_avail_times``), so the DP stays exact for the
    serialized issue order too (where it provably merges everything —
    equal avail makes one group dominate).
    """
    L = len(costs)
    if t_f is None:
        t_f = sum(c.t_f(hw) for c in costs)
    avail_fwd = comm_avail_times(costs, hw, t_f, mode)  # 1-based by fwd layer

    # backward position k <-> forward layer l = L + 1 - k
    avail = [0.0] * (L + 1)
    pre = [0] * (L + 1)  # prefix payload bytes over backward positions
    for k in range(1, L + 1):
        l = L + 1 - k
        avail[k] = avail_fwd[l]
        pre[k] = pre[k - 1] + costs[l - 1].grad_bytes

    D = [0.0] * (L + 1)
    parent = [0] * (L + 1)
    for k in range(1, L + 1):
        best, arg = float("inf"), 0
        for j in range(k):
            v = max(D[j], avail[k]) + ar_model(pre[k] - pre[j])
            if v < best - 1e-18:
                best, arg = v, j
        D[k], parent[k] = best, arg

    # Reconstruct groups (backward positions), convert to forward layers.
    groups = []
    k = L
    while k > 0:
        j = parent[k]
        # backward positions j+1..k == forward layers L+1-k .. L-j
        groups.append((L + 1 - k, L - j))
        k = j
    groups = tuple(sorted(groups))
    res = evaluate(list(groups), costs, ar_model, hw, t_f, mode=mode)
    return Schedule(groups=groups, method="dp_optimal", result=res)


def evaluate_schedule(
    schedule: Schedule,
    costs: list[LayerCost],
    ar_model: AllReduceModel,
    hw: Hardware = TPU_V5E,
    t_f: float | None = None,
    mode: str = "overlap",
) -> Schedule:
    """Attach a TimelineResult to a schedule produced without evaluation."""
    res = evaluate(list(schedule.groups), costs, ar_model, hw, t_f, mode=mode)
    return dataclasses.replace(schedule, result=res)
